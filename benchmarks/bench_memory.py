"""Paper Fig. 2b analogue: memory savings from eliminating group padding.

Savings = bytes(A_pad + S_A_pad + C_pad) / bytes(A + S_A + C) - 1, measured
from the actual buffer shapes both pipelines allocate.  Matches the paper's
geometry: savings grow with group count and shrink with M (padding is
G*(block_m-1)/2 expected rows regardless of M).  The paper's max (23.8% at
M=8192, G=32) is reproduced at the same (M, G) point.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import generate_group_sizes, time_fn
from repro.core import padding_baseline as pb
from repro.kernels import plan as plan_mod


def run(report):
    # honours `benchmarks.run --pin-config`; otherwise the paper's fixed
    # 128-row round-up (NOT the per-device default — fig2b numbers must
    # stay comparable to the paper's geometry on any host)
    pinned = plan_mod.pinned_default()
    block_m = (pinned or plan_mod.KernelConfig()).block_m
    for m in (8192, 16384, 32768, 65536):
        for g in (4, 8, 16, 32):
            savings = []
            for seed in range(5):
                sizes = generate_group_sizes(m, g, seed)
                k, n = 7168, 4096
                kb = (k + 127) // 128
                padded = np.ceil(sizes / block_m).astype(np.int64) * block_m
                mp = int(padded.sum())
                unpadded_b = m * k + m * kb * 4 + m * n * 2
                padded_b = mp * k + mp * kb * 4 + mp * n * 2
                savings.append(1.0 - unpadded_b / padded_b)
            s = float(np.mean(savings)) * 100
            # derived-only row: this suite computes buffer geometry, it
            # never times anything — us=None keeps the snapshot honest
            # (a literal 0.0 here used to masquerade as a measurement)
            report(f"fig2b/M{m}_G{g}", None,
                   f"mem_saving_pct={s:.1f}")

    # The measured half of this suite: the pad -> unpad round trip the
    # paper's kernel deletes — its wall time IS the traffic the geometry
    # rows above model (scatter write + gather read of A and S_A).
    rng = np.random.default_rng(0)
    for m, g in ((8192, 4), (8192, 32)):
        k = 512
        sizes = generate_group_sizes(m, g, seed=g)
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        sa = jnp.ones((m, (k + 127) // 128), jnp.float32)
        gs = jnp.asarray(sizes)
        @jax.jit
        def pad_rt(a_, s_, gs_):
            a_p, _, _, row_map = pb.pad_groups(a_, s_, gs_, block_m=block_m)
            return pb.unpad_groups(a_p, row_map)

        t = time_fn(pad_rt, a, sa, gs)
        report(f"fig2b_padpass/M{m}_G{g}", t * 1e6,
               f"block_m={block_m};bytes_scattered={a.size * 4 + sa.size * 4}")

    # Fused silu·mul→quantize epilogue: the bf16 h intermediate [M, ff]
    # never exists, so its HBM write AND the quantizer's read-back vanish
    # (4 bytes/element).  Traffic model per epilogue: unfused = read g+u
    # (2·M·ff·2) + write h (M·ff·2) + read h (M·ff·2) + write q (M·ff) +
    # write s (M·ff/128·4); fused drops the two h terms.
    for m in (8192, 32768):
        for ff in (1408, 4096):
            h_bytes = 4 * m * ff
            unfused = (2 * m * ff * 2) + h_bytes + m * ff + (m * ff // 128) * 4
            report(f"fig2b_fused/M{m}_ff{ff}", None,
                   f"h_bytes_saved_mb={h_bytes / 2**20:.1f};"
                   f"epilogue_traffic_saved_pct={h_bytes / unfused * 100:.1f}")
