"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--only fig2a,...]
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig2a,fig2b,equivalence,moe_layer")
    args = ap.parse_args()

    from benchmarks import (bench_equivalence, bench_grouped_gemm,
                            bench_memory, bench_moe_layer)
    suites = {
        "fig2a": bench_grouped_gemm.run,
        "fig2b": bench_memory.run,
        "equivalence": bench_equivalence.run,
        "moe_layer": bench_moe_layer.run,
    }
    wanted = (args.only.split(",") if args.only else list(suites))

    print("name,us_per_call,derived")

    def report(name, us, derived):
        print(f"{name},{us:.1f},{derived}", flush=True)

    for key in wanted:
        suites[key](report)


if __name__ == "__main__":
    main()
