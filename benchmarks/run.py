"""Benchmark harness — the full pinned suite, one key per paper
table/figure or operator family.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--only fig2a,...] [--smoke]
      [--pin-config BMxBNxBK] [--backend NAME] [--json PATH]

Suites: ``fig2a`` (fwd fp8 vs padded baseline), ``gemm_bf16`` (the true
bf16 registry path), ``wgrad`` (both precisions + the old-vs-new
multi-tile schedule rows with modeled operand-HBM-byte columns),
``quantize`` (tilewise + fused act_quant), ``gemm_quant`` (quantizing
epilogue), ``decode`` (tiny-M serving pool), ``fig2b`` (padding memory
geometry + the measured pad-pass round trip), ``equivalence`` (bitwise
gate), ``moe_layer``, ``gemm_hotpath``.

``--smoke`` shrinks every suite to CI-feasible shapes whose row names are
a strict SUBSET of the full suite's — a smoke snapshot diffs cleanly
against a committed full one via ``scripts/bench_diff.py``.

``--pin-config`` installs a pinned ``KernelConfig`` as the process-wide
default (every suite's GEMMs resolve to it); without it, suites that tune
go through the TilePlan autotuner pool.  ``--json`` additionally writes
the rows as a machine-readable snapshot (the bench-snapshot protocol:
commit the file as ``BENCH_<date>.json`` so perf regressions diff — each
row carries ``measured: true/false`` and the resolved dispatch backend,
so ``bench_diff.py`` can separate measured regressions from model drift).
"""
from __future__ import annotations

import argparse
import datetime
import json
import platform


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig2a,gemm_bf16,wgrad,quantize,"
                         "gemm_quant,decode,fig2b,equivalence,moe_layer,"
                         "gemm_hotpath")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes per suite (row names stay a subset "
                         "of the full suite's)")
    ap.add_argument("--pin-config", default=None, metavar="BMxBNxBK",
                    help="pin tile shapes, e.g. 256x128x128 (skips the "
                         "autotuner pool)")
    ap.add_argument("--backend", default=None,
                    help="dispatch backend pin (alone it implies the "
                         "default tile shapes)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON snapshot")
    args = ap.parse_args()

    from repro.kernels import plan as plan_mod
    if args.pin_config:
        bm, bn, bk = (int(v) for v in args.pin_config.lower().split("x"))
        plan_mod.set_default_config(plan_mod.KernelConfig(
            block_m=bm, block_n=bn, block_k=bk, backend=args.backend))
    elif args.backend:
        plan_mod.set_default_config(
            plan_mod.KernelConfig(backend=args.backend))

    from benchmarks import (bench_equivalence, bench_gemm_hotpath,
                            bench_grouped_gemm as bg, bench_memory,
                            bench_moe_layer)

    smoke = args.smoke
    be = args.backend

    # full runs prepend the smoke shapes so a --smoke snapshot's row
    # names stay a strict subset of a committed full snapshot's
    def suite_fig2a(report):
        bg.bench_cases(
            report,
            bg.SMOKE_CASES if smoke else bg.SMOKE_CASES + bg.CASES,
            backend=be)

    def suite_gemm_bf16(report):
        bg.bench_gemm_bf16_cases(
            report,
            bg.SMOKE_CASES if smoke else bg.SMOKE_CASES + bg.CASES[:4],
            backend=be)

    def suite_wgrad(report):
        cases = bg.SMOKE_CASES if smoke else bg.SMOKE_CASES + bg.CASES[:4]
        bg.bench_wgrad_cases(report, cases, backend=be)
        bg.bench_wgrad_fp8_cases(report, cases, backend=be)
        bg.bench_wgrad_multitile_cases(
            report,
            bg.WGRAD_KERNEL_SMOKE if smoke else bg.WGRAD_KERNEL_CASES)

    def suite_quantize(report):
        cases = bg.SMOKE_CASES if smoke else bg.SMOKE_CASES + bg.CASES[:4]
        bg.bench_quantize_cases(report, cases, backend=be)
        bg.bench_act_quant_cases(report, cases, backend=be)

    def suite_gemm_quant(report):
        bg.bench_gemm_quant_cases(
            report,
            bg.SMOKE_CASES if smoke else bg.SMOKE_CASES + bg.CASES[:4],
            backend=be)

    def suite_decode(report):
        cases = bg.DECODE_CASES[:1] if smoke else bg.DECODE_CASES
        bg.bench_decode_cases(report, cases, backend=be,
                              measure_autotune=not smoke)

    suites = {
        "fig2a": suite_fig2a,
        "gemm_bf16": suite_gemm_bf16,
        "wgrad": suite_wgrad,
        "quantize": suite_quantize,
        "gemm_quant": suite_gemm_quant,
        "decode": suite_decode,
        "fig2b": bench_memory.run,
        "equivalence": bench_equivalence.run,
        "moe_layer": lambda report: bench_moe_layer.run(report, smoke=smoke),
        "gemm_hotpath": lambda report: bench_gemm_hotpath.run(
            report, backend=be or "xla_ragged", smoke=smoke),
    }
    wanted = (args.only.split(",") if args.only else list(suites))

    print("name,us_per_call,derived")
    rows = []

    def report(name, us, derived, backend=None, extra=None):
        # us=None marks a derived-only row (geometry/bytes math, nothing
        # timed): the CSV shows an explicit blank and the snapshot omits
        # the timing key instead of recording a fake 0.0 measurement —
        # `measured` makes the distinction machine-readable per row
        row = {"name": name, "measured": us is not None}
        if backend is not None:
            row["backend"] = backend
        if us is None:
            print(f"{name},,{derived}", flush=True)
        else:
            print(f"{name},{us:.1f},{derived}", flush=True)
            row["us_per_call"] = round(us, 1)
        row["derived"] = derived
        if extra:
            row.update(extra)
        rows.append(row)

    for key in wanted:
        suites[key](report)

    if args.json:
        from repro.kernels import dispatch
        from repro.kernels.plan import _device_kind
        # the resolved (gemm, fp8) auto choice — what `backend: null`
        # used to hide; an explicit --backend records itself verbatim
        try:
            backend_resolved = dispatch.resolve(("gemm", "fp8"),
                                                args.backend)
        except Exception as e:              # record the refusal, not null
            backend_resolved = f"unavailable: {e}"
        default_cfg = plan_mod.pinned_default() or plan_mod.KernelConfig()
        snapshot = {
            "date": datetime.date.today().isoformat(),
            "suites": wanted,
            "smoke": smoke,
            "device": _device_kind(),
            "platform": platform.platform(),
            "pin_config": args.pin_config or
                f"bm{default_cfg.block_m}xbn{default_cfg.block_n}"
                f"xbk{default_cfg.block_k}(default)",
            "backend": args.backend or "auto",
            "backend_resolved": backend_resolved,
            # per-op count of CONFIG_POOL entries the static resource
            # model eliminated before measurement (kernels/resources.py)
            "pool_pruned": plan_mod.prune_stats(),
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(snapshot, f, indent=2)
            f.write("\n")
        print(f"# wrote {len(rows)} rows to {args.json}", flush=True)


if __name__ == "__main__":
    main()
