"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--only fig2a,...]
      [--pin-config BMxBNxBK] [--backend NAME] [--json PATH]

``--pin-config`` installs a pinned ``KernelConfig`` as the process-wide
default (every suite's GEMMs resolve to it); without it, suites that tune
go through the TilePlan autotuner pool.  ``--json`` additionally writes
the rows as a machine-readable snapshot (the bench-snapshot protocol:
commit the file as ``BENCH_<date>.json`` so perf regressions diff).
"""
from __future__ import annotations

import argparse
import datetime
import json
import platform


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig2a,fig2b,equivalence,moe_layer,"
                         "gemm_hotpath")
    ap.add_argument("--pin-config", default=None, metavar="BMxBNxBK",
                    help="pin tile shapes, e.g. 256x128x128 (skips the "
                         "autotuner pool)")
    ap.add_argument("--backend", default=None,
                    help="dispatch backend pin (alone it implies the "
                         "default tile shapes)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON snapshot")
    args = ap.parse_args()

    from repro.kernels import plan as plan_mod
    if args.pin_config:
        bm, bn, bk = (int(v) for v in args.pin_config.lower().split("x"))
        plan_mod.set_default_config(plan_mod.KernelConfig(
            block_m=bm, block_n=bn, block_k=bk, backend=args.backend))
    elif args.backend:
        plan_mod.set_default_config(
            plan_mod.KernelConfig(backend=args.backend))

    from benchmarks import (bench_equivalence, bench_gemm_hotpath,
                            bench_grouped_gemm, bench_memory,
                            bench_moe_layer)
    suites = {
        "fig2a": bench_grouped_gemm.run,
        "fig2b": bench_memory.run,
        "equivalence": bench_equivalence.run,
        "moe_layer": bench_moe_layer.run,
        "gemm_hotpath": bench_gemm_hotpath.run,
    }
    wanted = (args.only.split(",") if args.only else list(suites))

    print("name,us_per_call,derived")
    rows = []

    def report(name, us, derived):
        # us=None marks a derived-only row (geometry/bytes math, nothing
        # timed): the CSV shows an explicit blank and the snapshot omits
        # the timing key instead of recording a fake 0.0 measurement
        if us is None:
            print(f"{name},,{derived}", flush=True)
            rows.append({"name": name, "derived": derived})
        else:
            print(f"{name},{us:.1f},{derived}", flush=True)
            rows.append({"name": name, "us_per_call": round(us, 1),
                         "derived": derived})

    for key in wanted:
        suites[key](report)

    if args.json:
        from repro.kernels.plan import _device_kind
        snapshot = {
            "date": datetime.date.today().isoformat(),
            "suites": wanted,
            "device": _device_kind(),
            "platform": platform.platform(),
            "pin_config": args.pin_config,
            "backend": args.backend,
            # per-op count of CONFIG_POOL entries the static resource
            # model eliminated before measurement (kernels/resources.py)
            "pool_pruned": plan_mod.prune_stats(),
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(snapshot, f, indent=2)
            f.write("\n")
        print(f"# wrote {len(rows)} rows to {args.json}", flush=True)


if __name__ == "__main__":
    main()
