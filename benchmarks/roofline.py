"""Aggregate dry-run JSONs into the §Dry-run / §Roofline tables.

  PYTHONPATH=src python -m benchmarks.roofline [--dir benchmarks/results/dryrun]

Emits a markdown table per mesh: the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and per-device memory.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ARCHS
from repro.configs.base import SHAPES, cell_is_runnable
from repro.kernels.plan import DEVICE_SPECS

# single source of device numbers: the TilePlan autotuner's cost model
# (repro.kernels.plan.DEVICE_SPECS) and this table must agree
HBM_PER_CHIP = DEVICE_SPECS["tpu v5e"].hbm_bytes


def load(dirname):
    recs = {}
    variants = []
    for path in glob.glob(os.path.join(dirname, "*.json")):
        r = json.load(open(path))
        name = os.path.basename(path)[:-5]
        if name.endswith(("_single", "_multi")):
            key = (r["arch"], r["shape"], r.get("mesh", "?"), "bf16")
            recs[key] = r
        else:
            variants.append((name, r))
    return recs, sorted(variants)


def fmt_table(recs, mesh, out):
    out.append(f"\n### Mesh {mesh}\n")
    out.append("| arch | shape | compute s | memory s | collective s | "
               "dominant | useful flops | mem/chip GB | fits |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for arch in ARCHS:
        for shape in SHAPES:
            if not cell_is_runnable(arch, shape):
                out.append(f"| {arch} | {shape} | — | — | — | skipped "
                           f"(O(S²) full attention @512k, DESIGN §5) | — | — | — |")
                continue
            r = recs.get((arch, shape, mesh, "bf16"))
            if r is None:
                out.append(f"| {arch} | {shape} | MISSING | | | | | | |")
                continue
            if not r.get("ok"):
                out.append(f"| {arch} | {shape} | FAILED: "
                           f"{r.get('error','?')[:60]} | | | | | | |")
                continue
            rf = r["roofline"]
            m = r["memory"]
            dev_bytes = (m.get("argument_bytes") or 0) + \
                (m.get("temp_bytes") or 0)
            fits = "Y" if dev_bytes < HBM_PER_CHIP else "NO"
            out.append(
                f"| {arch} | {shape} | {rf['compute_s']:.4f} | "
                f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
                f"{rf['dominant'].replace('_s','')} | "
                f"{rf['useful_flops_ratio']:.2f} | "
                f"{dev_bytes/1e9:.2f} | {fits} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/results/dryrun")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    recs, variants = load(args.dir)
    out = ["## Roofline (derived from compiled dry-run artifacts)"]
    for mesh in ("16x16", "2x16x16"):
        if any(k[2] == mesh for k in recs):
            fmt_table(recs, mesh, out)
    if variants:
        out.append("\n### §Perf variants (non-default configs)\n")
        out.append("| artifact | compute s | memory s | collective s | "
                   "useful |")
        out.append("|---|---|---|---|---|")
        for name, r in variants:
            if not r.get("ok"):
                continue
            rf = r["roofline"]
            out.append(f"| {name} | {rf['compute_s']:.4f} | "
                       f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
                       f"{rf['useful_flops_ratio']:.2f} |")
    text = "\n".join(out)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    print(text)


if __name__ == "__main__":
    main()
