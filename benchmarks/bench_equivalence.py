"""Paper §3.2 numerical-equivalence table: the padding-free kernel must be
BITWISE identical to (pad -> aligned grouped GEMM -> unpad) on valid rows.

Runs the Pallas kernel in interpret mode (CPU-executable TPU semantics)
against the padded pipeline through the same kernel.  Dims scaled down for
interpret-mode speed; group structure follows the paper's generator.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch, ref
from benchmarks.common import generate_group_sizes, time_fn


def run(report):
    for m, g in ((512, 4), (1024, 8), (768, 16)):
        sizes = generate_group_sizes(m, g, seed=g)
        rng = np.random.default_rng(g)
        k = n = 256
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((g, k, n)), jnp.float32)
        a8, sa = ref.quantize_tilewise_ref(a)
        b8, sb = jax.vmap(ref.quantize_blockwise_ref)(b)
        gs = jnp.asarray(sizes)

        t = time_fn(lambda: dispatch.grouped_gemm_fp8(
            a8, sa, b8, sb, gs, backend="pallas_interpret",
            out_dtype=jnp.bfloat16), iters=2, warmup=1)
        ours = dispatch.grouped_gemm_fp8(a8, sa, b8, sb, gs,
                                         backend="pallas_interpret",
                                         out_dtype=jnp.bfloat16)
        base = dispatch.grouped_gemm_fp8(a8, sa, b8, sb, gs,
                                         backend="padded_baseline",
                                         out_dtype=jnp.bfloat16)
        bitwise = bool(np.array_equal(np.asarray(ours, np.float32),
                                      np.asarray(base, np.float32)))
        report(f"equivalence/M{m}_G{g}", t * 1e6,
               f"bitwise_identical={bitwise}")
        assert bitwise, "numerical equivalence violated"
