"""DEPRECATED shim: the HLO analyzer lives in ``repro.launch.hlo_analysis``.

This module exists so historical ``import hlo_analysis`` /
``from hlo_analysis import analyze`` call sites (benchmark scripts, old
notebooks) keep working; it re-exports the single source of truth and
adds nothing.  New code must import ``repro.launch.hlo_analysis``
directly — a test pins that both import paths resolve to the *same*
function objects, so the two can never drift apart again.
"""
from repro.launch.hlo_analysis import (  # noqa: F401
    Computation,
    Op,
    analyze,
    find_padding_ops,
    parse_module,
)

__all__ = ["Computation", "Op", "analyze", "find_padding_ops",
           "parse_module"]
