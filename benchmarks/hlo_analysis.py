"""Shim: the loop-aware HLO analyzer lives in repro.launch.hlo_analysis."""
from repro.launch.hlo_analysis import analyze, parse_module  # noqa: F401
