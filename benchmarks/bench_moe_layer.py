"""Layer-level benchmark: padding-free MoE block vs GShard-style
capacity-padded dense dispatch (the padding regime TPU systems use when no
ragged kernel is available).  The paper's insight at the layer level.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.moe import MoEConfig, _capacity, init_moe_params, moe_apply
from benchmarks.common import time_fn


def _gshard_dense(params, x, cfg: MoEConfig):
    """Capacity-padded batched-einsum dispatch (baseline)."""
    t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = int(np.ceil(t * k / e * cfg.capacity_factor / 128) * 128)
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    w, ids = jax.lax.top_k(probs, k)
    onehot = jax.nn.one_hot(ids, e, dtype=jnp.float32)       # [T,k,E]
    pos = jnp.cumsum(onehot.reshape(t * k, e), 0) * onehot.reshape(t * k, e)
    slot = (pos - 1).max(-1).astype(jnp.int32)
    eid = ids.reshape(-1)
    keep = slot < cap
    xe = jnp.zeros((e, cap, d), x.dtype).at[
        jnp.where(keep, eid, 0), jnp.where(keep, slot, cap - 1)].set(
        jnp.repeat(x, k, 0) * keep[:, None].astype(x.dtype))
    g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out = jnp.zeros((t, d), jnp.float32).at[
        jnp.repeat(jnp.arange(t), k)].add(
        jnp.where(keep[:, None], y[eid, jnp.minimum(slot, cap - 1)]
                  * w.reshape(-1)[:, None], 0.0))
    return out.astype(x.dtype)


def run(report, *, smoke: bool = False):
    cfg = MoEConfig(num_experts=16, top_k=4, d_model=512, d_ff_expert=256,
                    num_shared_experts=1, precision="bf16")
    params = init_moe_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    # smoke keeps the T=1024 row only — row names stay a subset of the
    # full suite's so bench_diff can match them across snapshots
    for t in ((1024,) if smoke else (1024, 4096)):
        x = jax.random.normal(jax.random.PRNGKey(1), (t, cfg.d_model),
                              jnp.bfloat16)
        f_ours = jax.jit(lambda p, x: moe_apply(p, x, cfg)[0])
        f_base = jax.jit(functools.partial(_gshard_dense, cfg=cfg))
        t_ours = time_fn(f_ours, params, x)
        t_base = time_fn(f_base, params, x)
        report(f"moe_layer/T{t}_E{cfg.num_experts}",
               t_ours * 1e6,
               f"paddingfree_vs_gshard_speedup="
               f"{(t_base - t_ours) / t_base * 100:.1f}pct")

    # Fused-epilogue section: under precision="fp8" the routed experts'
    # AND the shared FFN's silu·mul+quantize run as one (act_quant, fp8)
    # pass, so the layer never materializes its bf16 h intermediates —
    # write + read-back (4 bytes/element) saved per FFN per layer.
    for t in (1024, 4096):
        cap = _capacity(t * cfg.top_k, 1, cfg.capacity_factor)
        routed = 4 * cap * cfg.d_ff_expert
        shared = 4 * t * cfg.d_ff_expert * cfg.num_shared_experts
        # derived-only row (bytes geometry, nothing timed): us=None
        report(f"moe_layer_fused/T{t}_E{cfg.num_experts}", None,
               f"h_bytes_saved_mb={(routed + shared) / 2**20:.1f};"
               f"routed_mb={routed / 2**20:.1f};"
               f"shared_mb={shared / 2**20:.1f}")
