"""Shared benchmark utilities, incl. the paper's M^g generator (App. C.1)."""
from __future__ import annotations

import time

import jax
import numpy as np


def generate_group_sizes(m: int, g: int, seed: int = 0) -> np.ndarray:
    """Paper appendix C.1: random group dims summing exactly to M."""
    rng = np.random.default_rng(seed)
    v = rng.integers(0, 2 * (m // g) + 1, g).astype(np.float64)
    if v.sum() == 0:
        v[:] = 1.0
    v = np.floor(v * (m / v.sum())).astype(np.int64)
    v[-1] += m - v.sum()
    assert v.sum() == m and (v >= 0).all()
    return v.astype(np.int32)


def time_fn(fn, *args, iters: int = 5, warmup: int = 2):
    """Median wall time (seconds) of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))
