"""fp8 hot-path suite: the GEMMs a fused-producer training step runs.

Three row families:

  * ``fwd``      — the forward grouped GEMM in both operand precisions
                   (fp8 + tile scales vs bf16 ragged_dot), same shape.
  * ``producer`` — the gate/up projection as ONE fused
                   ``grouped_gemm_quant`` vs the unfused GEMM -> quantize
                   composition.  Derived columns carry the HBM bytes the
                   fusion removes (the wide output's write plus the
                   quantizer's read-back: 4 bytes/element) and the fused
                   output's actual footprint (fp8 payload + 1x128 scales).
  * ``quantize`` — the standalone tilewise quantizer on the producer's
                   input rows, for scale against the producer rows.

The xla_* backends compose the producer from the same two ops, so their
fused-vs-unfused time delta is noise; the *bytes* columns are the
backend-independent content, and the pallas path (interpret here, TPU on
device) is where the time delta becomes real.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_grouped_gemm import (_make_inputs, _ours, _ours_quant,
                                           _select_config, _unfused_quant)
from benchmarks.common import time_fn
from repro.kernels import dispatch
from repro.kernels.plan import KernelConfig

CASES = [(2048, 256, 256, 8), (2048, 512, 512, 8)]
# interpret-mode-feasible shape for the Pallas producer row
PALLAS_CASES = [(256, 128, 128, 4)]


def _bf16_inputs(m, k, n, g, seed):
    rng = np.random.default_rng(seed)
    from benchmarks.common import generate_group_sizes
    sizes = generate_group_sizes(m, g, seed)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((g, k, n)), jnp.bfloat16)
    return x, w, jnp.asarray(sizes)


def run(report, *, backend="xla_ragged", smoke: bool = False):
    cfg_bf16 = KernelConfig()
    cases = CASES[:1] if smoke else CASES

    for m, n, k, g in cases:
        cfg = _select_config(m, k, n, g, backend, measure=True)
        a8, sa, b8, sb, gs, _ = _make_inputs(m, k, n, g, seed=m + g + n)
        t_fp8 = time_fn(_ours, a8, sa, b8, sb, gs, cfg)
        x, w, gs_b = _bf16_inputs(m, k, n, g, seed=m + g + n)
        t_bf16 = time_fn(
            lambda x_, w_, gs_: dispatch.grouped_gemm_bf16(
                x_, w_, gs_, config=cfg_bf16), x, w, gs_b)
        report(f"gemm_hotpath/fwd/M{m}_N{n}_K{k}_G{g}",
               t_fp8 * 1e6,
               f"config=bm{cfg.block_m}xbn{cfg.block_n}xbk{cfg.block_k}"
               f"@{cfg.backend or 'auto'};bf16_us={t_bf16 * 1e6:.1f}",
               backend=dispatch.resolve(("gemm", "fp8"), cfg.backend))

    # producer epilogue: fused grouped_gemm_quant vs the unfused
    # composition — xla rows for the bytes math at training shapes,
    # one pallas_interpret row where the fusion is a real kernel
    prod_cases = [(be, case) for be in (backend,) for case in cases]
    prod_cases += [("pallas_interpret", case) for case in PALLAS_CASES
                   if dispatch.availability("pallas_interpret")[0]]
    for be, (m, n, k, g) in prod_cases:
        cfg = _select_config(m, k, n, g, be, measure=True, op="gemm_quant")
        a8, sa, b8, sb, gs, _ = _make_inputs(m, k, n, g, seed=m + g + n)
        t_fused = time_fn(_ours_quant, a8, sa, b8, sb, gs, cfg)
        t_unfused = time_fn(_unfused_quant, a8, sa, b8, sb, gs, cfg)
        nb = (n + 127) // 128
        saved = 4 * m * n
        fused_out = m * n + m * nb * 4
        report(f"gemm_hotpath/producer/M{m}_N{n}_K{k}_G{g}@{be}",
               t_fused * 1e6,
               f"config=bm{cfg.block_m}xbn{cfg.block_n}xbk{cfg.block_k};"
               f"unfused_us={t_unfused * 1e6:.1f};"
               f"producer_bytes_saved={saved};"
               f"fused_out_bytes={fused_out}",
               backend=dispatch.resolve(("gemm_quant", "fp8"), be))

    for m, n, k, g in cases:
        rng = np.random.default_rng(m)
        x = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        t_q = time_fn(lambda x_: dispatch.quantize_tilewise(x_), x)
        report(f"gemm_hotpath/quantize/M{m}_K{n}",
               t_q * 1e6,
               f"bytes_in={x.size * 4};bytes_out={m * n + m * (n // 128) * 4}")
