"""Paper Fig. 2a analogue: padding-free grouped GEMM vs explicit-padding
baseline (pad A+S_A -> aligned grouped GEMM -> unpad).

On this CPU container both pipelines run through the same XLA backend, so
the measured delta isolates exactly what the paper eliminates: the padding
pass's memory traffic + the padded tiles' extra work.  Alongside wall time
we report the *derived* quantities that transfer to any backend: padded
rows, extra bytes moved, extra M-tiles computed.

Dims are scaled down from the paper's sweep (M 8k-64k, N/K 3-8k on H800)
to CPU-feasible sizes; the padding-overhead *ratios* are preserved because
they depend only on (M/G)/block_m.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import padding_baseline as pb
from repro.kernels import ops, ref
from benchmarks.common import generate_group_sizes, time_fn

BLOCK_M = 128


def _make_inputs(m, k, n, g, seed):
    sizes = generate_group_sizes(m, g, seed)
    rng = np.random.default_rng(seed + 1)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((g, k, n)), jnp.float32)
    a8, sa = ref.quantize_tilewise_ref(a)
    b8, sb = jax.vmap(ref.quantize_blockwise_ref)(b)
    return a8, sa, b8, sb, jnp.asarray(sizes), sizes


@functools.partial(jax.jit, static_argnames=("padded_m",))
def _baseline(a8, sa, b8, sb, gs, padded_m):
    return pb.grouped_gemm_fp8_padded(a8, sa, b8, sb, gs,
                                      backend="xla_ragged",
                                      padded_m=padded_m)


@jax.jit
def _ours(a8, sa, b8, sb, gs):
    return ops.grouped_gemm_fp8(a8, sa, b8, sb, gs, backend="xla_ragged")


def run(report):
    cases = []
    for m in (2048, 8192):
        for g in (4, 8, 16, 32):
            for nk in (256, 512):
                cases.append((m, nk, nk, g))
    for m, n, k, g in cases:
        a8, sa, b8, sb, gs, sizes = _make_inputs(m, k, n, g, seed=m + g + n)
        padded_m = int(np.ceil((m + g * (BLOCK_M - 1)) / BLOCK_M) * BLOCK_M)
        t_base = time_fn(_baseline, a8, sa, b8, sb, gs, padded_m)
        t_ours = time_fn(_ours, a8, sa, b8, sb, gs)
        accel = (t_base - t_ours) / t_base * 100.0
        ov = pb.padding_overhead_bytes(sizes, k, sa.shape[1], BLOCK_M)
        pad_tiles = int(np.sum(np.ceil(sizes / BLOCK_M)))
        min_tiles = int(np.ceil(m / BLOCK_M))
        report(f"fig2a/M{m}_N{n}_K{k}_G{g}",
               t_ours * 1e6,
               f"accel_pct={accel:.1f};pad_rows={ov['pad_rows']};"
               f"pad_extra_bytes={ov['a_bytes'] + ov['sa_bytes']};"
               f"tiles={pad_tiles}vs{min_tiles + g - 1}")
