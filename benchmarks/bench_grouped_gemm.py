"""Paper Fig. 2a analogue: padding-free grouped GEMM vs explicit-padding
baseline (pad A+S_A -> aligned grouped GEMM -> unpad).

On this CPU container both pipelines run through the same XLA backend, so
the measured delta isolates exactly what the paper eliminates: the padding
pass's memory traffic + the padded tiles' extra work.  Alongside wall time
we report the *derived* quantities that transfer to any backend: padded
rows, extra bytes moved, extra M-tiles computed.

Tile shapes come from the TilePlan autotuner (``repro.kernels.plan``):
each case selects a ``KernelConfig`` from the block-shape pool (cached in
the JSON autotune cache) and the report names the chosen config.

Dims are scaled down from the paper's sweep (M 8k-64k, N/K 3-8k on H800)
to CPU-feasible sizes; the padding-overhead *ratios* are preserved because
they depend only on (M/G)/block_m.

Standalone usage (the CI smoke gate):

  PYTHONPATH=src python -m benchmarks.bench_grouped_gemm --smoke \
      --backend pallas_interpret
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import padding_baseline as pb
from repro.kernels import dispatch, ref
from repro.kernels import plan as plan_mod
from benchmarks.common import generate_group_sizes, time_fn


def _make_inputs(m, k, n, g, seed):
    sizes = generate_group_sizes(m, g, seed)
    rng = np.random.default_rng(seed + 1)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((g, k, n)), jnp.float32)
    a8, sa = ref.quantize_tilewise_ref(a)
    b8, sb = jax.vmap(ref.quantize_blockwise_ref)(b)
    return a8, sa, b8, sb, jnp.asarray(sizes), sizes


@functools.partial(jax.jit, static_argnames=("padded_m", "config"))
def _baseline(a8, sa, b8, sb, gs, padded_m, config):
    return pb.grouped_gemm_fp8_padded(a8, sa, b8, sb, gs, config=config,
                                      padded_m=padded_m)


@functools.partial(jax.jit, static_argnames=("config",))
def _ours(a8, sa, b8, sb, gs, config):
    return dispatch.grouped_gemm_fp8(a8, sa, b8, sb, gs, config=config)


@functools.partial(jax.jit, static_argnames=("config",))
def _ours_quant(a8, sa, b8, sb, gs, config):
    return dispatch.grouped_gemm_quant(a8, sa, b8, sb, gs, config=config)


@functools.partial(jax.jit, static_argnames=("config",))
def _unfused_quant(a8, sa, b8, sb, gs, config):
    y = dispatch.grouped_gemm_fp8(a8, sa, b8, sb, gs, config=config)
    return dispatch.quantize_tilewise(y.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("config",))
def _wgrad(x, dy, gs, config):
    return dispatch.grouped_gemm_wgrad(x, dy, gs, config=config)


@functools.partial(jax.jit, static_argnames=("config",))
def _wgrad_fp8(x8, sx, d8, sd, gs, config):
    return dispatch.grouped_gemm_wgrad_fp8(x8, sx, d8, sd, gs, config=config)


@functools.partial(jax.jit, static_argnames=("config",))
def _ours_bf16(x, w, gs, config):
    return dispatch.grouped_gemm_bf16(x, w, gs, config=config)


def _cfg_str(cfg) -> str:
    s = f"bm{cfg.block_m}xbn{cfg.block_n}xbk{cfg.block_k}"
    if cfg.n_span != 1 or cfg.k_span != 1:
        s += f"xns{cfg.n_span}xks{cfg.k_span}"
    return s


def _span_variant(cfg, k, n):
    """Widest wgrad span variant of ``cfg`` whose effective tiles still
    divide (K, N) — the multi-tile schedule the bytes columns compare
    against the single-tile one.  Prefers symmetric spans (both operands
    reused), then K-only, then N-only; spans=1 when nothing fits."""
    for ns, ks in ((4, 4), (4, 1), (1, 4), (2, 2), (2, 1), (1, 2)):
        c = cfg.with_(n_span=ns, k_span=ks)
        if c.compatible(k, n, family="wgrad"):
            return c
    return cfg.with_(n_span=1, k_span=1)


def _wgrad_bytes_cols(m, k, n, g, cfg, precision) -> str:
    """The tentpole's proof columns: modeled operand HBM bytes under the
    single-tile schedule (every (k, n) grid cell re-fetches both M-dim
    operand tiles) vs the chosen/widest multi-tile schedule (x fetched
    once per N super-tile, dy once per K super-tile)."""
    single = plan_mod.wgrad_operand_bytes(
        m, k, n, g, cfg.with_(n_span=1, k_span=1), precision=precision)
    span_cfg = cfg if (cfg.n_span != 1 or cfg.k_span != 1) \
        else _span_variant(cfg, k, n)
    span = plan_mod.wgrad_operand_bytes(m, k, n, g, span_cfg,
                                        precision=precision)
    return (f"operand_bytes_single={single};"
            f"operand_bytes_span={span};"
            f"span_cfg=ns{span_cfg.n_span}xks{span_cfg.k_span}")


def _select_config(m, k, n, g, backend, *, measure, op="gemm"):
    """Tile-shape selection for one case: an installed pin
    (``benchmarks.run --pin-config`` / ``plan.set_default_config``) wins;
    tile-free backends keep the paper's fixed per-device geometry (their
    GEMM ignores tiles — only the *baseline's* padding math would drift,
    breaking comparability of the pad-overhead ratios); otherwise pool
    selection through the autotuner (persists to the JSON cache; a second
    run reloads the same choice without re-measuring).  ``op`` picks the
    autotune family so the gemm and wgrad sections select — and report —
    the same backend under the same pin semantics."""
    pinned = plan_mod.pinned_default()
    if pinned is not None:
        return pinned if pinned.backend is not None or backend is None \
            else pinned.with_(backend=backend)
    if dispatch.backend_ignores_tiles(backend):
        # the paper's fixed 128-row geometry (like fig2b), NOT the
        # per-device default — keeps pad-overhead ratios comparable
        return plan_mod.KernelConfig().with_(backend=backend)
    return plan_mod.autotune(m, k, n, g, backend=backend, measure=measure,
                             op=op)


def _autotune_note() -> str:
    """Derived-column suffix describing the most recent pool selection:
    how many entries the static resource model pruned before ranking and
    how many measurements failed-and-were-skipped (satellite of the
    resource-lint layer: the report shows the model working)."""
    rep = plan_mod.last_autotune_report()
    if not rep:
        return ""
    note = f";pool_pruned={len(rep.get('pruned', []))}"
    skipped = rep.get("skipped", [])
    if skipped:
        note += f";measure_skipped={len(skipped)}"
    return note


def bench_cases(report, cases, *, backend=None, measure_autotune=True):
    for m, n, k, g in cases:
        cfg = _select_config(m, k, n, g, backend, measure=measure_autotune)
        note = _autotune_note()
        block_m = cfg.block_m
        a8, sa, b8, sb, gs, sizes = _make_inputs(m, k, n, g, seed=m + g + n)
        padded_m = int(np.ceil((m + g * (block_m - 1)) / block_m) * block_m)
        t_base = time_fn(_baseline, a8, sa, b8, sb, gs, padded_m, cfg)
        t_ours = time_fn(_ours, a8, sa, b8, sb, gs, cfg)
        accel = (t_base - t_ours) / t_base * 100.0
        ov = pb.padding_overhead_bytes(sizes, k, sa.shape[1], block_m)
        pad_tiles = int(np.sum(np.ceil(sizes / block_m)))
        min_tiles = int(np.ceil(m / block_m))
        report(f"fig2a/M{m}_N{n}_K{k}_G{g}",
               t_ours * 1e6,
               f"config={_cfg_str(cfg)}"
               f"@{cfg.backend or 'auto'};"
               f"accel_pct={accel:.1f};pad_rows={ov['pad_rows']};"
               f"pad_extra_bytes={ov['a_bytes'] + ov['sa_bytes']};"
               f"tiles={pad_tiles}vs{min_tiles + g - 1}{note}",
               backend=dispatch.resolve(("gemm", "fp8"), cfg.backend))


def bench_gemm_quant_cases(report, cases, *, backend=None,
                           measure_autotune=True):
    """The producer-side quantizing epilogue (``op="gemm_quant"``): the
    gate/up GEMM emits its fp8 payload + 1x128 scales straight from the
    store phase vs the unfused GEMM -> quantize composition on the same
    shape.  The derived columns carry the HBM bytes the fusion removes —
    the wide output's write plus the quantizer's read-back, 4
    bytes/element — and the fused output's actual footprint."""
    for m, n, k, g in cases:
        cfg = _select_config(m, k, n, g, backend, measure=measure_autotune,
                             op="gemm_quant")
        note = _autotune_note()
        a8, sa, b8, sb, gs, _ = _make_inputs(m, k, n, g, seed=m + g + n)
        t_fused = time_fn(_ours_quant, a8, sa, b8, sb, gs, cfg)
        t_unfused = time_fn(_unfused_quant, a8, sa, b8, sb, gs, cfg)
        nb = (n + 127) // 128
        saved = 4 * m * n                     # bf16 write + read-back
        fused_out = m * n + m * nb * 4        # fp8 payload + f32 scales
        report(f"gemm_quant/M{m}_N{n}_K{k}_G{g}",
               t_fused * 1e6,
               f"config={_cfg_str(cfg)}"
               f"@{cfg.backend or 'auto'};"
               f"unfused_us={t_unfused * 1e6:.1f};"
               f"producer_bytes_saved={saved};"
               f"fused_out_bytes={fused_out}{note}",
               backend=dispatch.resolve(("gemm_quant", "fp8"), cfg.backend))


def bench_wgrad_cases(report, cases, *, backend=None, measure_autotune=True):
    """The backward's ragged contraction ``dw[g] = x_g^T @ dy_g`` — the
    GEMM the wgrad registry kernelizes (previously only XLA's
    ``ragged_wgrad``).  Reports the registry path's time plus the
    xla_ragged fallback's for the same shape, so the report shows what
    the second operation family buys."""
    rng = np.random.default_rng(0)
    for m, n, k, g in cases:
        cfg = _select_config(m, k, n, g, backend, measure=measure_autotune,
                             op="wgrad")
        note = _autotune_note()
        sizes = generate_group_sizes(m, g, seed=m + g)
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)
        dy = jnp.asarray(rng.standard_normal((m, n)), jnp.bfloat16)
        gs = jnp.asarray(sizes)
        t_ours = time_fn(_wgrad, x, dy, gs, cfg)
        # fallback comparison — skipped when the primary config already IS
        # the fallback (measuring the same dispatch twice says nothing)
        resolved = dispatch.resolve_wgrad_backend(cfg.backend)
        t_ragged = time_fn(_wgrad, x, dy, gs,
                           cfg.with_(backend="xla_ragged")) \
            if (resolved != "xla_ragged"
                and dispatch.wgrad_availability("xla_ragged")[0]) \
            else float("nan")
        report(f"wgrad/M{m}_N{n}_K{k}_G{g}",
               t_ours * 1e6,
               f"config={_cfg_str(cfg)}"
               f"@{resolved};xla_ragged_us={t_ragged * 1e6:.1f};"
               f"{_wgrad_bytes_cols(m, k, n, g, cfg, 'bf16')}{note}",
               backend=resolved)


def bench_wgrad_fp8_cases(report, cases, *, backend=None,
                          measure_autotune=True):
    """The all-fp8 step's wgrad (arXiv 2505.20524): same ragged
    contraction, fp8 operands + 1x128 tile scales dequantized per visit.
    Reports the fp8 registry path's time plus the bf16 wgrad's for the
    same shape — the delta is what halving the contraction's operand
    bytes buys (and costs in per-visit rescale VPU work)."""
    rng = np.random.default_rng(0)
    for m, n, k, g in cases:
        cfg = _select_config(m, k, n, g, backend, measure=measure_autotune,
                             op="wgrad_fp8")
        note = _autotune_note()
        # the bf16 baseline times under ITS OWN tuned tiles — timing it
        # under the fp8-tuned config would conflate tile-shape choice
        # with operand precision in the reported delta
        cfg_bf16 = _select_config(m, k, n, g, backend,
                                  measure=measure_autotune, op="wgrad")
        sizes = generate_group_sizes(m, g, seed=m + g)
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        dy = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        x8, sx = ref.quantize_tilewise_ref(x)
        d8, sd = ref.quantize_tilewise_ref(dy)
        gs = jnp.asarray(sizes)
        t_ours = time_fn(_wgrad_fp8, x8, sx, d8, sd, gs, cfg)
        t_bf16 = time_fn(_wgrad, x.astype(jnp.bfloat16),
                         dy.astype(jnp.bfloat16), gs, cfg_bf16)
        resolved = dispatch.resolve_wgrad_backend(cfg.backend,
                                                  precision="fp8")
        report(f"wgrad_fp8/M{m}_N{n}_K{k}_G{g}",
               t_ours * 1e6,
               f"config={_cfg_str(cfg)}"
               f"@{resolved};bf16_wgrad_us={t_bf16 * 1e6:.1f};"
               f"{_wgrad_bytes_cols(m, k, n, g, cfg, 'fp8')}{note}",
               backend=resolved)


def bench_quantize_cases(report, cases, *, backend=None,
                         measure_autotune=True):
    """The quantizer's tile height through the same pool/roofline/cache
    machinery (``op="quantize"``, a first-class OpKey of the registry).
    Output is tile-height independent — the report compares the tuned
    height's wall time against the kernel's built-in default on the same
    payload."""
    rng = np.random.default_rng(0)
    seen = set()   # rows are keyed (M, K); n/g don't reach the quantizer
    for m, n, k, g in cases:
        if (m, k) in seen:
            continue
        seen.add((m, k))
        cfg = plan_mod.autotune(m, k, 0, 0, backend=backend,
                                measure=measure_autotune, op="quantize")
        note = _autotune_note()
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        t_tuned = time_fn(
            lambda x_: dispatch.quantize_tilewise(x_, backend=cfg.backend,
                                                  config=cfg), x)
        t_default = time_fn(
            lambda x_: dispatch.quantize_tilewise(x_, backend=cfg.backend),
            x)
        report(f"quantize/M{m}_K{k}",
               t_tuned * 1e6,
               f"config=bm{cfg.block_m}@{cfg.backend or 'auto'};"
               f"kernel_default_us={t_default * 1e6:.1f}{note}",
               backend=dispatch.resolve(("quantize", "fp8"), cfg.backend))


def bench_act_quant_cases(report, cases, *, backend=None,
                          measure_autotune=True):
    """The fused SwiGLU epilogue ``silu(g)*u -> 1x128 fp8`` through the
    ``(act_quant, fp8)`` operator vs the unfused activation -> quantize
    composition on the same rows — the suite-level row for the seam
    ``moe_apply(precision="fp8")`` runs per expert FFN."""
    rng = np.random.default_rng(0)
    seen = set()   # rows are keyed (M, K); n/g don't reach the epilogue
    for m, n, k, g in cases:
        if (m, k) in seen:
            continue
        seen.add((m, k))
        cfg = plan_mod.autotune(m, k, 0, 0, backend=backend,
                                measure=measure_autotune, op="act_quant")
        note = _autotune_note()
        ga = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        ua = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        t_fused = time_fn(
            lambda g_, u_: dispatch.act_quantize(g_, u_, backend=cfg.backend,
                                                 config=cfg), ga, ua)
        t_unfused = time_fn(
            lambda g_, u_: dispatch.quantize_tilewise(
                jax.nn.silu(g_) * u_, backend=cfg.backend), ga, ua)
        report(f"act_quant/M{m}_K{k}",
               t_fused * 1e6,
               f"config=bm{cfg.block_m}@{cfg.backend or 'auto'};"
               f"unfused_us={t_unfused * 1e6:.1f};"
               f"h_bytes_saved={4 * m * k}{note}",
               backend=dispatch.resolve(("act_quant", "fp8"), cfg.backend))


def bench_gemm_bf16_cases(report, cases, *, backend=None,
                          measure_autotune=True):
    """The true-bf16 registry path (``op="gemm_bf16"``): the Pallas visit
    schedule on bf16 operands where available, ``ragged_dot`` otherwise.
    Reports the registry path's time plus the xla_ragged baseline's on
    the same shape — on kernel backends the delta shows what sharing OUR
    schedule across precisions buys the fp8-vs-bf16 comparison."""
    rng = np.random.default_rng(0)
    for m, n, k, g in cases:
        cfg = _select_config(m, k, n, g, backend, measure=measure_autotune,
                             op="gemm_bf16")
        note = _autotune_note()
        sizes = generate_group_sizes(m, g, seed=m + g)
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)
        w = jnp.asarray(rng.standard_normal((g, k, n)), jnp.bfloat16)
        gs = jnp.asarray(sizes)
        t_ours = time_fn(_ours_bf16, x, w, gs, cfg)
        resolved = dispatch.resolve(("gemm", "bf16"), cfg.backend,
                                    tile=(cfg, m, k, n))
        t_ragged = time_fn(_ours_bf16, x, w, gs,
                           cfg.with_(backend="xla_ragged")) \
            if resolved != "xla_ragged" else float("nan")
        report(f"gemm_bf16/M{m}_N{n}_K{k}_G{g}",
               t_ours * 1e6,
               f"config={_cfg_str(cfg)}"
               f"@{resolved};xla_ragged_us={t_ragged * 1e6:.1f}{note}",
               backend=resolved)


def bench_wgrad_multitile_cases(report, cases, *, precisions=("bf16", "fp8")):
    """Old-vs-new wgrad schedule on the SAME kernel backend
    (``pallas_interpret`` — the CPU-measurable twin of the TPU kernel):
    times the single-tile grid against the widest feasible multi-tile
    span and reports both modeled operand-byte columns next to both
    measurements.  This is the acceptance row for the VMEM-residency
    tentpole: bytes strictly lower, time no worse."""
    rng = np.random.default_rng(0)
    for m, n, k, g in cases:
        sizes = generate_group_sizes(m, g, seed=m + g)
        gs = jnp.asarray(sizes)
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        dy = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        base = plan_mod.KernelConfig().with_(backend="pallas_interpret")
        span_cfg = _span_variant(base, k, n)
        for prec in precisions:
            if prec == "fp8":
                x8, sx = ref.quantize_tilewise_ref(x)
                d8, sd = ref.quantize_tilewise_ref(dy)
                t_single = time_fn(_wgrad_fp8, x8, sx, d8, sd, gs, base)
                t_span = time_fn(_wgrad_fp8, x8, sx, d8, sd, gs, span_cfg)
            else:
                t_single = time_fn(_wgrad, x.astype(jnp.bfloat16),
                                   dy.astype(jnp.bfloat16), gs, base)
                t_span = time_fn(_wgrad, x.astype(jnp.bfloat16),
                                 dy.astype(jnp.bfloat16), gs, span_cfg)
            b_single = plan_mod.wgrad_operand_bytes(m, k, n, g, base,
                                                    precision=prec)
            b_span = plan_mod.wgrad_operand_bytes(m, k, n, g, span_cfg,
                                                  precision=prec)
            report(f"wgrad_multitile/{prec}/M{m}_N{n}_K{k}_G{g}",
                   t_span * 1e6,
                   f"config={_cfg_str(span_cfg)}@pallas_interpret;"
                   f"single_tile_us={t_single * 1e6:.1f};"
                   f"operand_bytes_single={b_single};"
                   f"operand_bytes_span={b_span};"
                   f"bytes_saved_pct={(1 - b_span / b_single) * 100:.1f}",
                   backend="pallas_interpret")


def bench_decode_cases(report, cases, *, backend=None, measure_autotune=False):
    """Serving's tiny-M regime: a decode step's grouped GEMM has
    M = batch*top_k rows TOTAL, constant across steps.  Selection runs
    through the decode pool (``op="decode"``, block_m<=16 entries) — the
    path `serve.Engine` resolves once at construction — and the report
    compares it against the training-shaped per-device default config on
    the same shape, so the delta shows what the decode-specialized tile
    height buys at M in {1, 8, 16}."""
    for m, n, k, g in cases:
        cfg = plan_mod.decode_config(m, k, n, g, backend=backend,
                                     measure=measure_autotune)
        note = _autotune_note()
        a8, sa, b8, sb, gs, _ = _make_inputs(m, k, n, g, seed=m + g + n)
        t_dec = time_fn(_ours, a8, sa, b8, sb, gs, cfg)
        cfg_train = plan_mod.KernelConfig().with_(backend=cfg.backend)
        t_train = time_fn(_ours, a8, sa, b8, sb, gs, cfg_train)
        report(f"decode/M{m}_N{n}_K{k}_G{g}",
               t_dec * 1e6,
               f"config={_cfg_str(cfg)}"
               f"@{cfg.backend or 'auto'};tiny_m=1;"
               f"default_bm{cfg_train.block_m}_us={t_train * 1e6:.1f}{note}",
               backend=dispatch.resolve(("gemm", "fp8"), cfg.backend))


CASES = [(m, nk, nk, g) for m in (2048, 8192) for g in (4, 8, 16, 32)
         for nk in (256, 512)]
SMOKE_CASES = [(256, 128, 128, 4)]   # tiny: interpret-mode friendly
# decode-step shapes: M = batch*top_k routed rows in total
DECODE_CASES = [(1, 256, 256, 4), (8, 256, 256, 4), (16, 256, 256, 4)]
# interpret-mode-feasible shapes for the old-vs-new wgrad schedule rows;
# the smoke list is a strict subset so bench_diff finds common row names
WGRAD_KERNEL_CASES = [(256, 256, 256, 4), (512, 512, 512, 4)]
WGRAD_KERNEL_SMOKE = [(256, 256, 256, 4)]


def run(report):
    bench_cases(report, CASES, backend="xla_ragged")
    bench_wgrad_cases(report, CASES[:4], backend="xla_ragged")
    bench_wgrad_fp8_cases(report, CASES[:4], backend="xla_ragged")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny shape (CI gate for the bench entry "
                         "points + the autotune cache round trip)")
    ap.add_argument("--decode", action="store_true",
                    help="tiny-M serving shapes (M in {1, 8, 16}) through "
                         "the decode-specialized pool (block_m<=16)")
    ap.add_argument("--gemm-quant", action="store_true",
                    help="the producer-side quantizing epilogue "
                         "(op=gemm_quant) vs the unfused GEMM->quantize "
                         "composition")
    ap.add_argument("--backend", default=None,
                    help="dispatch backend (default: auto-resolved)")
    args = ap.parse_args()

    print("name,us_per_call,derived")

    def report(name, us, derived, **_):
        print(f"{name},{us:.1f},{derived}", flush=True)

    if args.decode:
        bench_decode_cases(report, DECODE_CASES, backend=args.backend,
                           measure_autotune=not args.smoke)
        return
    if args.gemm_quant:
        bench_gemm_quant_cases(report,
                               SMOKE_CASES if args.smoke else CASES[:4],
                               backend=args.backend, measure_autotune=True)
        return
    if args.smoke:
        # measured pool selection even on plan-consuming backends — the
        # shape is tiny, and it exercises selection + cache persistence
        # for ALL op families (gemm + wgrad + wgrad_fp8 keys)
        bench_cases(report, SMOKE_CASES, backend=args.backend,
                    measure_autotune=True)
        bench_wgrad_cases(report, SMOKE_CASES, backend=args.backend,
                          measure_autotune=True)
        bench_wgrad_fp8_cases(report, SMOKE_CASES, backend=args.backend,
                              measure_autotune=True)
        bench_quantize_cases(report, SMOKE_CASES, backend=args.backend,
                             measure_autotune=True)
    else:
        bench_cases(report, CASES, backend=args.backend)
        bench_wgrad_cases(report, CASES, backend=args.backend)
        bench_wgrad_fp8_cases(report, CASES, backend=args.backend)
        bench_quantize_cases(report, CASES[:4], backend=args.backend)


if __name__ == "__main__":
    main()
