"""End-to-end MoE training with the paper's padding-free fp8 grouped GEMM.

  PYTHONPATH=src python examples/train_moe.py --steps 40 --precision fp8

Trains a reduced deepseek-moe (fine-grained experts — the paper's target
workload) and reports the padding the grouped GEMM avoided each step.
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model_zoo import make_model
from repro.optim import adamw
from repro.train.trainer import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--precision", default="bf16", choices=["bf16", "fp8"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = smoke_config("deepseek-moe-16b")
    cfg = dataclasses.replace(cfg, precision=args.precision,
                              dtype=jnp.float32,
                              gemm_backend="xla_exact"
                              if args.precision == "fp8" else None)
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    opt_cfg = adamw.OptConfig(lr=1e-3, total_steps=args.steps,
                              warmup_steps=5, use_master=False)
    opt_state = adamw.init_opt_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(model.loss, opt_cfg),
                      donate_argnums=(0, 1))
    data = SyntheticLM(DataConfig(seed=0, batch_size=args.batch,
                                  seq_len=args.seq), cfg)

    # padding the baseline WOULD have added (per MoE layer, per step):
    e = cfg.moe.num_experts
    tokens = args.batch * args.seq * cfg.moe.top_k
    exp_pad_rows = e * (128 - 1) / 2          # expected pad rows @ block 128
    print(f"precision={args.precision}  experts={e} top_k={cfg.moe.top_k}")
    print(f"grouped GEMM rows/step/layer: {tokens} "
          f"(padding baseline would add ~{exp_pad_rows:.0f} rows "
          f"= {exp_pad_rows / tokens * 100:.1f}% waste)")

    first = last = None
    for step in range(args.steps):
        params, opt_state, m = step_fn(params, opt_state,
                                       data.batch_at(step))
        if step == 0:
            first = float(m["loss"])
        last = float(m["loss"])
        if step % 10 == 0:
            print(f"step {step:3d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.2f}")
    print(f"loss: {first:.3f} -> {last:.3f}")
    assert last < first, "training did not reduce loss"


if __name__ == "__main__":
    main()
