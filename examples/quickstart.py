"""Quickstart: train a small dense LM for a few steps, then generate.

  PYTHONPATH=src python examples/quickstart.py [--steps 60]

Uses the public API only: configs registry -> model zoo -> trainer ->
serving engine.
"""
import argparse
import sys

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model_zoo import make_model, synthetic_batch
from repro.optim import adamw
from repro.serve.engine import Engine
from repro.train.trainer import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="qwen3-1.7b")
    args = ap.parse_args(argv)

    import dataclasses
    cfg = dataclasses.replace(smoke_config(args.arch), dtype=jnp.float32)
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name} (reduced): {n_params/1e6:.1f}M params")

    opt_cfg = adamw.OptConfig(lr=1e-3, total_steps=args.steps,
                              warmup_steps=5, use_master=False)
    opt_state = adamw.init_opt_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(model.loss, opt_cfg),
                      donate_argnums=(0, 1))

    data = SyntheticLM(DataConfig(seed=0, batch_size=8, seq_len=128), cfg)
    first = last = None
    for step in range(args.steps):
        params, opt_state, m = step_fn(params, opt_state,
                                       data.batch_at(step))
        if step == 0:
            first = float(m["loss"])
        last = float(m["loss"])
        if step % 10 == 0:
            print(f"step {step:3d}  loss {float(m['loss']):.4f}")
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")

    engine = Engine(model, params, max_new_tokens=12)
    batch = synthetic_batch(jax.random.PRNGKey(7), cfg, 32, 2)
    res = engine.generate(batch)
    print("generated tokens:", res.tokens[0].tolist())
    assert last < first, "training did not reduce loss"


if __name__ == "__main__":
    main()
