"""Batched serving: prefill a batch of prompts, decode with per-request
sampling; exercises the KV-cache (and recurrent-state) serving path.

  PYTHONPATH=src python examples/serve_decode.py --arch recurrentgemma-2b
"""
import argparse

import jax

from repro.configs import smoke_config
from repro.models.model_zoo import make_model, synthetic_batch
from repro.serve.engine import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch)
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = Engine(model, params, max_new_tokens=args.max_new,
                    temperature=args.temperature)

    batch = synthetic_batch(jax.random.PRNGKey(1), cfg, args.prompt_len,
                            args.batch)
    res = engine.generate(batch, key=jax.random.PRNGKey(42))
    for i in range(args.batch):
        print(f"request {i}: {res.tokens[i].tolist()}")
    print(f"{int(res.num_generated.sum())} tokens generated "
          f"({cfg.name}, {'recurrent' if cfg.family in ('ssm', 'hybrid') else 'KV-cache'} decode)")


if __name__ == "__main__":
    main()
