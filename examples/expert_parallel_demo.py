"""Expert parallelism demo on 8 simulated devices.

Shows the padding-free MoE layer running under shard_map with experts
sharded 8-ways, verifying EP output == single-device output, and printing
the collectives XLA emitted.

  PYTHONPATH=src python examples/expert_parallel_demo.py
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")

import re

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.moe import (MoEConfig, init_moe_params, moe_apply,
                            shard_moe_params)


def main():
    assert len(jax.devices()) >= 8
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = MoEConfig(num_experts=8, top_k=2, d_model=256, d_ff_expert=128,
                    num_shared_experts=1, capacity_factor=8.0)
    params = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 128, 256))

    # single-device reference
    y_ref, aux = moe_apply(params, x.reshape(-1, 256), cfg)
    y_ref = y_ref.reshape(x.shape)

    ep = 4  # experts 8 / model axis 4 -> 2 experts per shard
    pspecs = shard_moe_params(params, cfg, ep)
    xspec = P("data", None, None)

    def local_fn(p, xl):
        rank = jax.lax.axis_index("model")
        b, s, d = xl.shape
        y, aux = moe_apply(p, xl.reshape(b * s, d), cfg, ep_rank=rank,
                           ep_size=ep, axis_name="model")
        return y.reshape(b, s, d)

    fn = jax.jit(shard_map(local_fn, mesh=mesh,
                           in_specs=(pspecs, xspec), out_specs=xspec,
                           check_vma=False))
    y_ep = fn(params, x)

    err = float(jnp.max(jnp.abs(y_ep - y_ref)))
    rel = err / max(float(jnp.max(jnp.abs(y_ref))), 1e-6)
    print(f"EP(4-way) vs single-device max |err|: {err:.2e} (rel {rel:.2e})")
    # relative criterion: the EP reduction reassociates bf16 partial sums,
    # so the tolerable absolute error scales with the output magnitude
    assert rel < 1e-3

    hlo = fn.lower(params, x).compile().as_text()
    colls = re.findall(r"(all-reduce|all-gather|reduce-scatter|"
                       r"all-to-all|collective-permute)\(", hlo)
    from collections import Counter
    print("collectives emitted:", dict(Counter(colls)))
    print("OK: padding-free MoE is EP-sharded and numerically faithful")


if __name__ == "__main__":
    main()
