"""Compat layer + grouped-GEMM dispatch registry.

Covers the ISSUE-1 acceptance surface:
  * capability probes are monkeypatchable and drive backend selection —
    each backend is selected (auto) or refused (explicit request) per the
    probed environment, with a reasoned error instead of AttributeError;
  * the two wgrad formulations (``ragged_dot_general`` vs the
    transpose-of-``ragged_dot`` fallback) agree numerically with each
    other and with a dense one-hot oracle;
  * every CPU-runnable backend produces matching outputs on the
    equivalence fixtures, including a dispatch-level re-run of the paper's
    bitwise padded-baseline equivalence claim.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import compat
from repro.kernels import dispatch, ref


# ---------------------------------------------------------------------------
# compat probes + shard_map
# ---------------------------------------------------------------------------

def test_probes_return_bool():
    for probe in (compat.has_tpu, compat.has_ragged_dot,
                  compat.has_ragged_dot_general, compat.has_shard_map_in_jax):
        assert isinstance(probe(), bool)


def test_tpu_compiler_params_constructs():
    p = compat.tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    assert isinstance(p, compat.TPUCompilerParams)


def test_shard_map_check_vma_translated():
    """compat.shard_map accepts the modern ``check_vma=`` kwarg on every
    JAX (0.4.x spells it ``check_rep``)."""
    mesh = jax.make_mesh((1,), ("x",))
    from jax.sharding import PartitionSpec as P
    fn = compat.shard_map(lambda a: a * 2, mesh=mesh, in_specs=P("x"),
                          out_specs=P("x"), check_vma=False)
    out = fn(jnp.arange(4.0))
    np.testing.assert_array_equal(np.asarray(out), [0.0, 2.0, 4.0, 6.0])


def test_cost_analysis_normalized_to_dict():
    compiled = jax.jit(lambda x: x @ x).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    cost = compat.cost_analysis(compiled)
    assert isinstance(cost, dict)


# ---------------------------------------------------------------------------
# wgrad formulations
# ---------------------------------------------------------------------------

def _wgrad_oracle(x, dy, sizes):
    g = len(sizes)
    dw = np.zeros((g, x.shape[1], dy.shape[1]), np.float32)
    off = 0
    for i, n in enumerate(sizes):
        dw[i] = np.asarray(x[off:off + n], np.float32).T @ \
            np.asarray(dy[off:off + n], np.float32)
        off += n
    return dw


@pytest.mark.parametrize("sizes", [(5, 7, 4), (40, 0, 57), (0, 0, 16)])
def test_ragged_wgrad_matches_dense_oracle(sizes):
    rng = np.random.default_rng(sum(sizes))
    m = sum(sizes)
    x = jnp.asarray(rng.standard_normal((m, 16)), jnp.float32)
    dy = jnp.asarray(rng.standard_normal((m, 8)), jnp.float32)
    gs = jnp.asarray(sizes, jnp.int32)
    dw = compat.ragged_wgrad(x, dy, gs, num_groups=len(sizes))
    np.testing.assert_allclose(np.asarray(dw), _wgrad_oracle(x, dy, sizes),
                               rtol=1e-5, atol=1e-5)


def test_wgrad_formulations_agree():
    """Pin numerical agreement between the ragged_dot_general spelling and
    the transpose-of-ragged_dot fallback.  When this JAX lacks
    ``ragged_dot_general`` the fallback is compared against the dense
    oracle (bitwise-level f32 tolerance) so the pin still bites."""
    sizes = (33, 1, 0, 62)
    rng = np.random.default_rng(0)
    m = sum(sizes)
    x = jnp.asarray(rng.standard_normal((m, 32)), jnp.bfloat16)
    dy = jnp.asarray(rng.standard_normal((m, 24)), jnp.bfloat16)
    gs = jnp.asarray(sizes, jnp.int32)
    via_transpose = compat._ragged_wgrad_via_transpose(
        x, dy, gs, num_groups=len(sizes))
    if compat.has_ragged_dot_general():
        dn = jax.lax.RaggedDotDimensionNumbers(
            dot_dimension_numbers=(((0,), (0,)), ((), ())),
            lhs_ragged_dimensions=[0],
            rhs_group_dimensions=[])
        direct = jax.lax.ragged_dot_general(
            x, dy, gs, dn, preferred_element_type=jnp.float32)
    else:
        direct = jnp.asarray(_wgrad_oracle(x.astype(jnp.float32),
                                           dy.astype(jnp.float32), sizes))
    np.testing.assert_allclose(np.asarray(via_transpose),
                               np.asarray(direct), rtol=1e-5, atol=1e-5)


def test_ragged_dot_dense_fallback_matches_primitive(monkeypatch):
    sizes = (3, 9, 4)
    rng = np.random.default_rng(2)
    m = sum(sizes)
    x = jnp.asarray(rng.standard_normal((m, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((len(sizes), 16, 8)), jnp.float32)
    gs = jnp.asarray(sizes, jnp.int32)
    real = compat.ragged_dot(x, w, gs, preferred_element_type=jnp.float32)
    monkeypatch.setattr(compat, "has_ragged_dot", lambda: False)
    fallback = compat.ragged_dot(x, w, gs,
                                 preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(fallback), np.asarray(real),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# backend selection / refusal
# ---------------------------------------------------------------------------

def test_registry_exposes_expected_backends():
    names = dispatch.backend_names()
    for required in ("pallas", "pallas_interpret", "xla_ragged",
                     "xla_exact", "padded_baseline"):
        assert required in names


def test_auto_prefers_pallas_on_tpu(monkeypatch):
    monkeypatch.setattr(compat, "has_tpu", lambda: True)
    assert dispatch.resolve_backend("auto") == "pallas"


def test_auto_prefers_xla_ragged_on_cpu(monkeypatch):
    monkeypatch.setattr(compat, "has_tpu", lambda: False)
    monkeypatch.setattr(compat, "has_ragged_dot", lambda: True)
    assert dispatch.resolve_backend("auto") == "xla_ragged"


def test_auto_falls_back_to_interpret(monkeypatch):
    monkeypatch.setattr(compat, "has_tpu", lambda: False)
    monkeypatch.setattr(compat, "has_ragged_dot", lambda: False)
    assert dispatch.resolve_backend("auto") == "pallas_interpret"


def test_none_backend_means_auto(monkeypatch):
    monkeypatch.setattr(compat, "has_tpu", lambda: False)
    assert dispatch.resolve_backend(None) == dispatch.resolve_backend("auto")


def test_pallas_refused_without_tpu(monkeypatch):
    monkeypatch.setattr(compat, "has_tpu", lambda: False)
    with pytest.raises(dispatch.BackendUnavailableError) as ei:
        dispatch.resolve_backend("pallas")
    assert "TPU" in str(ei.value)
    assert ei.value.backend == "pallas"


def test_xla_ragged_refused_without_ragged_dot(monkeypatch):
    monkeypatch.setattr(compat, "has_ragged_dot", lambda: False)
    for name in ("xla_ragged", "xla_exact"):
        with pytest.raises(dispatch.BackendUnavailableError):
            dispatch.resolve_backend(name)


def test_unknown_backend_raises_valueerror():
    with pytest.raises(ValueError, match="unknown backend"):
        dispatch.resolve_backend("cuda")


def test_xla_alias_resolves_to_xla_ragged():
    assert dispatch.resolve_backend("xla") == "xla_ragged"


def test_default_backend_override_roundtrip():
    try:
        dispatch.set_default_backend("pallas_interpret")
        assert dispatch.resolve_backend("auto") == "pallas_interpret"
    finally:
        dispatch.set_default_backend(None)


def test_backend_matrix_reports_reasons():
    matrix = dispatch.backend_matrix()
    assert matrix["pallas_interpret"]["available"]
    for row in matrix.values():
        assert isinstance(row["available"], bool)
        if not row["available"]:
            assert row["reason"]


# ---------------------------------------------------------------------------
# cross-backend equivalence fixtures
# ---------------------------------------------------------------------------

SIZES = [100, 0, 37, 163, 129]
K, N = 256, 128


@pytest.fixture(scope="module")
def quantized_inputs():
    rng = np.random.default_rng(3)
    m = sum(SIZES)
    a = jnp.asarray(rng.standard_normal((m, K)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((len(SIZES), K, N)), jnp.float32)
    a8, sa = ref.quantize_tilewise_ref(a)
    b8, sb = jax.vmap(ref.quantize_blockwise_ref)(b)
    return a, b, a8, sa, b8, sb, jnp.asarray(SIZES, jnp.int32)


def test_padded_baseline_bitwise_vs_interpret(quantized_inputs):
    """ISSUE-1: interpret-mode dispatch re-run of the paper's central
    claim — padding-free output is bitwise identical to
    pad -> aligned GEMM -> unpad."""
    _, _, a8, sa, b8, sb, gs = quantized_inputs
    ours = dispatch.grouped_gemm_fp8(a8, sa, b8, sb, gs,
                                     backend="pallas_interpret",
                                     out_dtype=jnp.bfloat16)
    base = dispatch.grouped_gemm_fp8(a8, sa, b8, sb, gs,
                                     backend="padded_baseline",
                                     out_dtype=jnp.bfloat16)
    assert np.array_equal(np.asarray(ours, np.float32),
                          np.asarray(base, np.float32))


def test_all_cpu_backends_match(quantized_inputs):
    _, _, a8, sa, b8, sb, gs = quantized_inputs
    outs = {
        name: np.asarray(dispatch.grouped_gemm_fp8(
            a8, sa, b8, sb, gs, backend=name, out_dtype=jnp.float32))
        for name in ("pallas_interpret", "xla_ragged", "xla_exact",
                     "padded_baseline", "auto")
    }
    anchor = outs["xla_exact"]
    # exact-accumulation backends agree tightly; the bf16-dequantized
    # xla_ragged path carries fp8->bf16 input rounding over K=256
    for name in ("pallas_interpret", "padded_baseline"):
        np.testing.assert_allclose(outs[name], anchor, rtol=1e-5, atol=1e-4,
                                   err_msg=name)
    np.testing.assert_allclose(outs["xla_ragged"], anchor, rtol=5e-2,
                               atol=0.35)
    # "auto" is exactly whatever concrete backend it resolves to
    np.testing.assert_array_equal(outs["auto"],
                                  outs[dispatch.resolve_backend("auto")])


def test_highlevel_grouped_gemm_entry(quantized_inputs):
    a, b, a8, sa, b8, sb, gs = quantized_inputs
    y = dispatch.grouped_gemm(a, b, gs, backend="pallas_interpret",
                              out_dtype=jnp.float32)
    y_ref = dispatch.grouped_gemm_fp8(a8, sa, b8, sb, gs,
                                      backend="pallas_interpret",
                                      out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


def test_run_with_unavailable_backend_is_reasoned(monkeypatch,
                                                  quantized_inputs):
    _, _, a8, sa, b8, sb, gs = quantized_inputs
    monkeypatch.setattr(compat, "has_tpu", lambda: False)
    with pytest.raises(dispatch.BackendUnavailableError):
        dispatch.grouped_gemm_fp8(a8, sa, b8, sb, gs, backend="pallas")
