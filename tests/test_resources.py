"""Tests for the static kernel-resource model (kernels/resources.py) and
its plan.py integrations: family-aware KernelConfig.validate, autotune's
static pool pruning, skipped-with-reason measurement, and the
resource-model-versioned cache key."""
import json
import os

import pytest

from repro.kernels import plan as plan_mod
from repro.kernels import resources as res
from repro.kernels.plan import KernelConfig


# ---------------------------------------------------------------------------
# tile arithmetic + footprints
# ---------------------------------------------------------------------------

def test_tile_bytes_rounds_to_lane_and_sublane():
    # cols pad to 128 lanes; rows to the dtype's sublane granularity
    assert res.tile_bytes(8, 128, 4) == 8 * 128 * 4
    assert res.tile_bytes(8, 100, 4) == 8 * 128 * 4
    assert res.tile_bytes(5, 128, 4) == 8 * 128 * 4      # f32: 8 rows
    assert res.tile_bytes(5, 128, 2) == 16 * 128 * 2     # bf16: 16 rows
    assert res.tile_bytes(5, 128, 1) == 32 * 128 * 1     # fp8: 32 rows


def test_gemm_footprint_matches_hand_arithmetic():
    # bm=128, bn=128, bk=128 at K=N=4096: kb=nb=32
    fp = res.footprint("gemm", {"block_m": 128, "block_n": 128,
                                "block_k": 128}, m=8192, k=4096, n=4096)
    a = 128 * 128 * 1
    s_a = 128 * 128 * 4          # 32 cols pad to 128 lanes
    b = 128 * 128 * 1
    s_b = 32 * 128 * 4           # rows 32 (f32 sublane 8), cols pad
    out = 128 * 128 * 2
    acc = 128 * 128 * 4
    assert fp["total_single"] == a + s_a + b + s_b + out + acc
    assert fp["total"] == 2 * (a + s_a + b + s_b + out) + acc


def test_gemm_quant_footprint_swaps_wide_output_for_payload_and_scales():
    kw = dict(m=8192, k=4096, n=4096)
    cfg = {"block_m": 128, "block_n": 128, "block_k": 128}
    plain = res.footprint("gemm", cfg, **kw)
    quant = res.footprint("gemm_quant", cfg, **kw)
    assert "out_payload" in quant["buffers"]
    assert "out_scales" in quant["buffers"]
    assert "out_tile" not in quant["buffers"]
    # the payload halves the bf16 output write, but the (bm, 1) f32 scale
    # tile lane-pads to 128 columns — the model must charge that padding
    assert quant["buffers"]["out_payload"] < plain["buffers"]["out_tile"]
    assert quant["buffers"]["out_scales"] == 2 * 128 * 128 * 4


def test_wgrad_fp8_footprint_adds_scale_rows():
    kw = dict(m=8192, k=4096, n=4096)
    cfg = {"block_m": 128, "block_n": 128, "block_k": 128}
    bf16 = res.footprint("wgrad", cfg, wgrad_precision="bf16", **kw)
    fp8 = res.footprint("wgrad", cfg, wgrad_precision="fp8", **kw)
    assert "s_x_row" in fp8["buffers"] and "s_x_row" not in bf16["buffers"]


def test_quantize_footprint_applies_the_kernel_tile_clamp():
    # the quantize kernel clamps block_m to max(8, m)
    tall = res.footprint("quantize", {"block_m": 512, "block_n": 128,
                                      "block_k": 128}, m=16, k=2048, n=0)
    short = res.footprint("quantize", {"block_m": 16, "block_n": 128,
                                       "block_k": 128}, m=16, k=2048, n=0)
    assert tall["total"] == short["total"]


def test_act_quant_models_the_extra_producer_input():
    kw = dict(m=8192, k=2048, n=2048)
    cfg = {"block_m": 128, "block_n": 128, "block_k": 128}
    one = res.footprint("quantize", cfg, **kw)
    two = res.footprint("act_quant", cfg, **kw)
    # two bf16 inputs equal one f32 input in bytes; totals match here but
    # the buffer breakdown must show the fused pass reads two operands
    assert two["buffers"]["in_rows"] == 2 * 128 * 2048 * 2 * 2
    assert one["buffers"]["in_rows"] == 128 * 2048 * 4 * 2


def test_vmem_budget_prefix_matching():
    assert res.vmem_budget("TPU v5 lite") == 16 * 2**20
    assert res.vmem_budget("tpu v5e") == 16 * 2**20
    assert res.vmem_budget("tpu v4") == 32 * 2**20
    assert res.vmem_budget("cpu") == 16 * 2**20
    assert res.vmem_budget("unknown accelerator") == 16 * 2**20


def test_infeasible_reason_cases():
    shape = dict(m=8192, k=4096, n=4096)
    budget = res.vmem_budget("tpu v5e")
    ok = res.infeasible_reason(
        "gemm", {"block_m": 128, "block_n": 128, "block_k": 128},
        vmem_bytes=budget, **shape)
    assert ok is None
    misaligned = res.infeasible_reason(
        "gemm", {"block_m": 128, "block_n": 96, "block_k": 128},
        vmem_bytes=budget, **shape)
    assert "misaligned" in misaligned
    degenerate = res.infeasible_reason(
        "gemm", {"block_m": 512, "block_n": 128, "block_k": 128},
        vmem_bytes=budget, m=256, k=4096, n=4096)
    assert "degenerate" in degenerate
    over = res.infeasible_reason(
        "gemm", {"block_m": 8192, "block_n": 128, "block_k": 128},
        vmem_bytes=budget, m=16384, k=4096, n=4096)
    assert "VMEM" in over


def test_degeneracy_keeps_the_smallest_decode_tile_at_m1():
    # bm=8 must survive m=1 (the smallest pool tile IS the selection);
    # bm=16 is prunable (half the fetch does the same work)
    assert res.degeneracy_issues({"block_m": 8, "block_n": 128,
                                  "block_k": 128}, m=1, k=256, n=256) == []
    assert res.degeneracy_issues({"block_m": 16, "block_n": 128,
                                  "block_k": 128}, m=1, k=256, n=256)


# ---------------------------------------------------------------------------
# KernelConfig.validate budget check
# ---------------------------------------------------------------------------

def test_validate_raises_with_computed_footprint_for_infeasible_config():
    cfg = KernelConfig(block_m=8192, block_n=512, block_k=512)
    with pytest.raises(ValueError, match="VMEM"):
        cfg.validate(16384, 4096, 4096)


def test_validate_passes_pool_configs_at_training_shapes():
    for cfg in plan_mod.CONFIG_POOL:
        assert cfg.validate(8192, 4096, 4096) is cfg
    for cfg in plan_mod.CONFIG_POOL:
        assert cfg.validate(8192, 4096, 4096, family="gemm_quant") is cfg


# ---------------------------------------------------------------------------
# autotune static pruning + skipped-with-reason measurement
# ---------------------------------------------------------------------------

def _tmp_cache(tmp_path):
    return str(tmp_path / "tileplan_cache.json")


def test_autotune_statically_prunes_degenerate_pool_entry(tmp_path):
    # acceptance pin: at the CI smoke shape (M=256) the bm=512 pool entry
    # is statically infeasible and must never be ranked or measured
    plan_mod.clear_cache_memo()
    plan_mod.reset_prune_stats()
    cfg = plan_mod.autotune(256, 128, 128, 4, backend="xla_ragged",
                            measure=False, cache_path=_tmp_cache(tmp_path))
    rep = plan_mod.last_autotune_report()
    assert cfg.block_m < 512
    assert len(rep["pruned"]) >= 1
    assert any(c["block_m"] == 512 for c, _ in rep["pruned"])
    assert all("degenerate" in r or "VMEM" in r for _, r in rep["pruned"])
    assert plan_mod.prune_stats().get("gemm", 0) >= 1


def test_autotune_pruned_config_never_reaches_measurement(tmp_path,
                                                          monkeypatch):
    measured = []
    real = plan_mod._measure_candidate

    def spy(config, *a, **kw):
        measured.append(config.block_m)
        return real(config, *a, **kw)

    monkeypatch.setattr(plan_mod, "_measure_candidate", spy)
    plan_mod.clear_cache_memo()
    plan_mod.autotune(256, 128, 128, 4, backend="pallas_interpret",
                      measure=True, cache_path=_tmp_cache(tmp_path))
    assert measured, "interpret path must actually measure"
    assert 512 not in measured


def test_autotune_measurement_failure_is_skipped_not_fatal(tmp_path,
                                                           monkeypatch):
    real = plan_mod._measure_candidate

    def flaky(config, *a, **kw):
        if config.block_m == 128:
            raise RuntimeError("synthetic compile failure")
        return real(config, *a, **kw)

    monkeypatch.setattr(plan_mod, "_measure_candidate", flaky)
    plan_mod.clear_cache_memo()
    cache = _tmp_cache(tmp_path)
    cfg = plan_mod.autotune(256, 128, 128, 4, backend="pallas_interpret",
                            measure=True, cache_path=cache)
    assert cfg.block_m != 128
    rep = plan_mod.last_autotune_report()
    assert any("synthetic compile failure" in r for _, r in rep["skipped"])
    # the skip reason persists in the cache entry
    with open(cache) as f:
        entries = json.load(f)["entries"]
    (entry,) = [e for e in entries.values() if e["op"] == "gemm"]
    assert entry["skipped"] and entry["source"] == "measured"


def test_autotune_all_measurements_failing_falls_back_to_cost_model(
        tmp_path, monkeypatch):
    def always_fail(config, *a, **kw):
        raise RuntimeError("no backend")

    monkeypatch.setattr(plan_mod, "_measure_candidate", always_fail)
    plan_mod.clear_cache_memo()
    cfg = plan_mod.autotune(256, 128, 128, 4, backend="pallas_interpret",
                            measure=True, cache_path=_tmp_cache(tmp_path))
    assert cfg is not None
    assert plan_mod.last_autotune_report()["source"] == "cost_model"


# ---------------------------------------------------------------------------
# cache-key versioning (satellite bugfix)
# ---------------------------------------------------------------------------

def test_cache_key_is_namespaced_by_resource_model_version():
    key = plan_mod.cache_key("cpu", "xla_ragged", 256, 128, 128, 4)
    assert key.endswith(f"|rm{res.RESOURCE_MODEL_VERSION}")
    key_wgrad = plan_mod.cache_key("cpu", "xla_ragged", 256, 128, 128, 4,
                                   op="wgrad")
    assert f"|wgrad|rm{res.RESOURCE_MODEL_VERSION}" in key_wgrad


def test_old_format_cache_entries_are_ignored_not_crashed_on(tmp_path):
    # a cache written before the resource-model namespace: its key has no
    # |rm suffix, so it can never be served — autotune re-tunes and the
    # old entry survives the merge untouched
    cache = _tmp_cache(tmp_path)
    stale_key = "cpu|xla_ragged|M256|K128|N128|G4"
    stale = {"version": 1, "entries": {stale_key: {
        "config": {"block_m": 512, "block_n": 128, "block_k": 128,
                   "backend": "xla_ragged", "out_dtype": None},
        "seconds": 1.0, "source": "measured", "pool_size": 6,
        "op": "gemm"}}}
    with open(cache, "w") as f:
        json.dump(stale, f)
    plan_mod.clear_cache_memo()
    cfg = plan_mod.autotune(256, 128, 128, 4, backend="xla_ragged",
                            measure=False, cache_path=cache)
    # the stale (now statically-infeasible) selection must NOT be served
    assert cfg.block_m != 512
    with open(cache) as f:
        entries = json.load(f)["entries"]
    assert stale_key in entries            # preserved, not clobbered
    new_key = plan_mod.cache_key("cpu", "xla_ragged", 256, 128, 128, 4)
    assert new_key in entries


def test_prune_stats_reset():
    plan_mod.reset_prune_stats()
    assert plan_mod.prune_stats() == {}
