"""The true-Pallas ``(gemm, bf16)`` registry entry: bitwise parity with
its accumulation-order oracle over ragged shapes, tolerance agreement
with the ``ragged_dot`` baseline, exact zero-fill contracts, and the
same registration/tile-fallback semantics as every other plan consumer.

Why the oracle, not ``ragged_dot``, carries the bitwise claim: XLA's
``ragged_dot`` lowering splits the K reduction differently per output-row
segment, so its f32 sums differ from per-tile MXU dots in the last ulp
(~1e-4 of output bits flip even after the bf16 cast).  ``gmm_bf16_
xla_exact`` replays the kernel's exact reduction order — one dense f32
dot per (group, 128-wide K block) — and dense-dot M-tiling is
bitwise-stable, so kernel-vs-oracle equality is exact while
kernel-vs-ragged_dot is a (tight) tolerance check."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import compat
from repro.kernels import dispatch
from repro.kernels import plan as plan_mod
from repro.kernels.dispatch import gmm_bf16_xla_exact
from repro.kernels.grouped_gemm_kernel import gmm_pallas_bf16
from repro.kernels.plan import KernelConfig

# ragged: balanced, empty group + sum<M capacity tail, all-empty,
# single group, multi-M-tile block_m=256 walk
CASES = [
    ([128, 128, 128, 128], 512, 256, 256, 128),
    ([200, 0, 150, 100], 512, 256, 256, 128),
    ([0, 0, 0], 256, 128, 128, 128),
    ([300], 384, 128, 256, 128),
    ([100, 300, 50], 512, 384, 256, 256),
]


def _inputs(sizes, m, k, n, seed=0):
    g = len(sizes)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((g, k, n)), jnp.float32)
    return x, w, jnp.asarray(sizes, jnp.int32)


@pytest.mark.parametrize("sizes,m,k,n,bm", CASES)
def test_bitwise_matches_exact_oracle(sizes, m, k, n, bm):
    x, w, gs = _inputs(sizes, m, k, n)
    out = gmm_pallas_bf16(x, w, gs, num_groups=len(sizes), block_m=bm,
                          interpret=True)
    ref = gmm_bf16_xla_exact(x, w, gs)
    assert out.dtype == ref.dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(out).view(np.uint16),
                          np.asarray(ref).view(np.uint16)), \
        "bf16 Pallas kernel diverged bitwise from its reduction-order oracle"


@pytest.mark.parametrize("sizes,m,k,n,bm", CASES)
def test_close_to_ragged_dot_baseline(sizes, m, k, n, bm):
    x, w, gs = _inputs(sizes, m, k, n)
    out = gmm_pallas_bf16(x, w, gs, num_groups=len(sizes), block_m=bm,
                          interpret=True).astype(jnp.float32)
    rd = compat.ragged_dot(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                           gs, preferred_element_type=jnp.float32
                           ).astype(jnp.bfloat16).astype(jnp.float32)
    total = int(sum(sizes))
    np.testing.assert_allclose(np.asarray(out[:total]),
                               np.asarray(rd[:total]),
                               rtol=2e-2, atol=2e-2)


def test_tail_rows_exact_zero():
    x, w, gs = _inputs([60, 30], 256, 128, 128)   # sum=90 << m
    out = gmm_pallas_bf16(x, w, gs, num_groups=2, interpret=True)
    assert np.all(np.asarray(out[90:], np.float32) == 0.0)


def test_m_zero_short_circuit():
    x, w, gs = _inputs([0, 0], 0, 128, 128)
    out = gmm_pallas_bf16(x, w, gs, num_groups=2, interpret=True)
    assert out.shape == (0, 128) and out.dtype == jnp.bfloat16


def test_k_mismatch_raises():
    x, w, gs = _inputs([128, 128], 256, 128, 128)
    with pytest.raises(ValueError, match="disagree on K"):
        gmm_pallas_bf16(x, w[:, :64, :], gs, num_groups=2, interpret=True)


def test_registry_entries():
    names = dispatch.op_backend_names(("gemm", "bf16"))
    assert {"pallas", "pallas_interpret", "xla_ragged",
            "xla_exact"} <= set(names)
    table = dispatch._OPERATORS[dispatch.OpKey("gemm", "bf16")]
    for name in ("pallas", "pallas_interpret"):
        assert table[name].uses_plan and table[name].uses_tiles
    # interpret + oracle are runnable everywhere (CPU CI)
    assert dispatch.op_availability(("gemm", "bf16"),
                                    "pallas_interpret")[0]
    assert dispatch.op_availability(("gemm", "bf16"), "xla_exact")[0]


def test_dispatch_pallas_interpret_matches_oracle_backend():
    x, w, gs = _inputs([200, 0, 150, 100], 512, 256, 256)
    out = dispatch.grouped_gemm_bf16(x, w, gs, backend="pallas_interpret",
                                     out_dtype=jnp.bfloat16)
    ref = dispatch.grouped_gemm_bf16(x, w, gs, backend="xla_exact",
                                     out_dtype=jnp.bfloat16)
    assert np.array_equal(np.asarray(out).view(np.uint16),
                          np.asarray(ref).view(np.uint16))


def test_tile_fallback_semantics():
    """Auto-resolved kernels whose tiles don't divide (K, N) fall back to
    a tile-free entry; explicit requests raise — the same policy as every
    other registry citizen."""
    cfg = KernelConfig(block_n=128, block_k=128)
    # N=192 indivisible: auto falls back
    name = dispatch.resolve(("gemm", "bf16"), None, tile=(cfg, 256, 128, 192))
    assert name in ("xla_ragged", "xla_exact")
    with pytest.raises(ValueError):
        dispatch.resolve(("gemm", "bf16"), "pallas_interpret",
                         tile=(cfg.with_(backend="pallas_interpret"),
                               256, 128, 192))


def test_autotune_gemm_bf16_op(tmp_path):
    cache = str(tmp_path / "cache.json")
    cfg = plan_mod.autotune(256, 128, 128, 4, measure=True, op="gemm_bf16",
                            backend="pallas_interpret", cache_path=cache)
    assert (cfg.n_span, cfg.k_span) == (1, 1)
    assert cfg.backend == "pallas_interpret"
    rep = plan_mod.last_autotune_report()
    assert rep["op"] == "gemm_bf16" and rep["source"] == "measured"


def test_contract_facts_cover_bf16_gemm():
    facts = dispatch.op_contract_facts()
    f = facts[dispatch.OpKey("gemm", "bf16")]
    assert f["entry_point"] == "grouped_gemm_bf16"
    assert f["padding_free"] is True
