"""TilePlan subsystem: KernelConfig validation, plan-once/run-many reuse,
the block-shape pool autotuner + its persistent cache, and the empty-group
edge cases of the metadata schedule.

The two load-bearing pins:

  * ``test_moe_fwd_bwd_builds_metadata_exactly_once`` — one MoE
    forward+backward builds group metadata ONCE (counting monkeypatch),
    i.e. the plan is genuinely shared across gate/up/down + dgrads;
  * ``test_moe_fp8_bitwise_golden`` — outputs/grads on
    ``pallas_interpret`` are bitwise-identical to the pre-refactor
    implementation (golden values captured at the parent commit).
"""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.moe import MoEConfig, _capacity, init_moe_params, moe_apply
from repro.kernels import dispatch, ref
from repro.kernels import plan as plan_mod
from repro.kernels.grouped_gemm_kernel import gmm_pallas
from repro.kernels.plan import (CONFIG_POOL, KernelConfig, autotune,
                                candidate_pool, estimate_cost_s,
                                make_group_metadata, make_tile_plan)


# ---------------------------------------------------------------------------
# KernelConfig
# ---------------------------------------------------------------------------

def test_kernel_config_static_validation():
    with pytest.raises(ValueError):
        KernelConfig(block_n=64)          # lane width
    with pytest.raises(ValueError):
        KernelConfig(block_k=100)         # quant tile
    with pytest.raises(ValueError):
        KernelConfig(block_m=12)          # sublane


def test_kernel_config_shape_validation():
    cfg = KernelConfig()
    with pytest.raises(ValueError):
        cfg.validate(100, 100, 128)       # K % block_k
    with pytest.raises(ValueError):
        cfg.validate(100, 128, 100)       # N % block_n
    assert cfg.validate(100, 128, 128) is cfg
    assert cfg.compatible(256, 256) and not cfg.compatible(100, 128)


def test_kernel_config_roundtrip_and_default():
    cfg = KernelConfig(block_m=256, backend="pallas_interpret",
                       out_dtype=jnp.float32)
    assert KernelConfig.from_dict(cfg.to_dict()) == cfg
    # per-device defaults always produce a legal config
    for kind in ("cpu", "TPU v5e", "TPU v4", "weird-accelerator"):
        KernelConfig.default(kind).validate(64, 256, 256)


def test_default_config_seam():
    pinned = KernelConfig(block_m=512)
    with plan_mod.default_config(pinned):
        assert plan_mod.get_default_config() == pinned
        assert plan_mod.resolve_config(None).block_m == 512
        # explicit config and per-call overrides win over the default
        assert plan_mod.resolve_config(KernelConfig()).block_m == 128
        assert plan_mod.resolve_config(
            None, backend="xla_exact").backend == "xla_exact"
    assert plan_mod.get_default_config().block_m != 512


# ---------------------------------------------------------------------------
# TilePlan construction + reuse
# ---------------------------------------------------------------------------

def _quantized(sizes, k, n, seed=0):
    rng = np.random.default_rng(seed)
    m = int(np.sum(sizes))
    a8, sa = ref.quantize_tilewise_ref(
        jnp.asarray(rng.standard_normal((m, k)), jnp.float32))
    b8, sb = jax.vmap(ref.quantize_blockwise_ref)(
        jnp.asarray(rng.standard_normal((len(sizes), k, n)), jnp.float32))
    return a8, sa, b8, sb, jnp.asarray(sizes, jnp.int32)


def test_tile_plan_matches_inline_metadata():
    gs = jnp.asarray([100, 0, 37, 163], jnp.int32)
    plan = make_tile_plan(gs, 300, block_m=128)
    offs, gids, tids = make_group_metadata(gs, 300, 128, 4)
    np.testing.assert_array_equal(np.asarray(plan.group_offsets),
                                  np.asarray(offs))
    np.testing.assert_array_equal(np.asarray(plan.group_ids),
                                  np.asarray(gids))
    np.testing.assert_array_equal(np.asarray(plan.m_tile_ids),
                                  np.asarray(tids))
    assert plan.num_tiles == 3 and plan.max_visits == 6
    assert int(plan.total_rows()) == 300


def test_tile_plan_is_pytree():
    gs = jnp.asarray([8, 8], jnp.int32)
    plan = make_tile_plan(gs, 16, block_m=8)
    leaves, treedef = jax.tree_util.tree_flatten(plan)
    assert len(leaves) == 3
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.block_m == 8 and rebuilt.m == 16


def test_plan_mismatch_rejected():
    gs = jnp.asarray([64, 64], jnp.int32)
    a8, sa, b8, sb, gs = _quantized([64, 64], 128, 128)
    plan = make_tile_plan(gs, 128, block_m=64)
    with pytest.raises(ValueError, match="TilePlan built for"):
        gmm_pallas(a8, sa, b8, sb, gs, out_dtype=jnp.float32,
                   interpret=True, plan=plan)   # kernel block_m=128


@pytest.mark.parametrize("sizes", [[100, 0, 37, 163], [1, 1, 1, 1],
                                   [0, 0, 512], [5, 250, 3, 127, 129]])
def test_precomputed_plan_bitwise_equals_plan_free(sizes):
    a8, sa, b8, sb, gs = _quantized(sizes, 256, 128, seed=sum(sizes))
    plan = make_tile_plan(gs, int(np.sum(sizes)), block_m=128)
    free = dispatch.grouped_gemm_fp8(a8, sa, b8, sb, gs,
                                     backend="pallas_interpret",
                                     out_dtype=jnp.float32)
    planned = dispatch.grouped_gemm_fp8(a8, sa, b8, sb, gs,
                                        backend="pallas_interpret",
                                        out_dtype=jnp.float32, plan=plan)
    np.testing.assert_array_equal(np.asarray(free), np.asarray(planned))


# ---------------------------------------------------------------------------
# Empty-group edge cases (satellite: num_real == 0)
# ---------------------------------------------------------------------------

def test_metadata_all_groups_empty_is_safe():
    gs = jnp.zeros((4,), jnp.int32)
    offs, gids, tids = make_group_metadata(gs, 256, 128, 4)
    assert np.asarray(offs).tolist() == [0] * 5
    # zero real visits: every visit is a padding visit pinned to group 0
    # (whose row range is empty) sweeping the tail tiles so the kernel
    # zero-fills the whole buffer; nothing negative / out of range
    assert np.all(np.asarray(gids) == 0)
    tids = np.asarray(tids)
    assert np.all((tids >= 0) & (tids < 2))
    # the sweep covers every tile (both tiles of the 256-row buffer)
    assert set(tids.tolist()) == {0, 1}


def test_metadata_padding_visits_sweep_tail_tiles():
    """sum(group_sizes) < M: the padding visits walk the tiles beyond the
    last owned row (so the kernel's store zero-fills them) instead of
    replicating the last real visit."""
    gs = jnp.asarray([60, 30], jnp.int32)         # total=90, 2 tiles of 128
    offs, gids, tids = make_group_metadata(gs, 256, 128, 2)
    real = [(int(g), int(t)) for g, t in zip(gids, tids)]
    # real visits: both groups in tile 0; the one padding visit covers
    # tail tile 1 (keeping the last real group id — empty range there)
    assert real == [(0, 0), (1, 0), (1, 1)]


def test_metadata_m_zero_is_safe():
    gs = jnp.zeros((3,), jnp.int32)
    offs, gids, tids = make_group_metadata(gs, 0, 128, 3)
    assert np.all(np.asarray(gids) >= 0) and np.all(np.asarray(tids) >= 0)


def test_gmm_all_zero_group_sizes_returns_zeros():
    a8, sa, b8, sb, _ = _quantized([128, 128], 128, 128)
    gs0 = jnp.zeros((2,), jnp.int32)
    out = gmm_pallas(a8, sa, b8, sb, gs0, out_dtype=jnp.float32,
                     interpret=True)
    assert out.shape == (256, 128)
    assert np.all(np.asarray(out) == 0.0)


def test_gmm_m_zero_returns_empty():
    a8, sa, b8, sb, _ = _quantized([128], 128, 128)
    out = gmm_pallas(a8[:0], sa[:0], b8, sb, jnp.zeros((1,), jnp.int32),
                     out_dtype=jnp.float32, interpret=True)
    assert out.shape == (0, 128)


# ---------------------------------------------------------------------------
# MoE: plan-once/run-many + bitwise golden vs pre-refactor
# ---------------------------------------------------------------------------

def _moe_fixture():
    cfg = MoEConfig(num_experts=4, top_k=2, d_model=128, d_ff_expert=128,
                    num_shared_experts=1, precision="fp8",
                    backend="pallas_interpret")
    params = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    return cfg, params, x


def _moe_loss(cfg):
    def loss(p, x):
        y, _ = moe_apply(p, x, cfg)
        return jnp.sum(y * jnp.cos(jnp.arange(y.size).reshape(y.shape))), y
    return loss


def test_moe_fwd_bwd_builds_metadata_exactly_once(monkeypatch):
    """One moe_apply forward+backward = ONE group-metadata build per
    group structure: the routed TilePlan is constructed per routing
    decision and shared by the gate/up/down forward GEMMs and both
    dgrads in the custom VJP; the shared-expert FFN (fp8 since the
    precision bugfix) adds exactly one G=1 plan of its own."""
    cfg, params, x = _moe_fixture()
    calls = []
    inner = plan_mod.make_group_metadata

    def counting(*a, **kw):
        calls.append(a)
        return inner(*a, **kw)

    monkeypatch.setattr(plan_mod, "make_group_metadata", counting)
    loss = _moe_loss(cfg)
    jax.grad(lambda p: loss(p, x)[0])(params)   # fresh fwd+bwd trace
    assert len(calls) == 2, \
        f"expected one routed + one shared metadata build, saw {len(calls)}"
    assert [c[3] for c in calls] == [cfg.num_experts, 1]


# Golden values pin the fp8 MoE fwd+bwd bitwise so refactors stay pure
# plumbing.  Recaptured once for the init_moe_params key-split bugfix
# (splitting 7 keys instead of 6 redraws every param — the distributions
# are unchanged, the draws are not), and again for the shared-expert
# precision bugfix (the shared FFN now runs fp8 under precision="fp8"
# instead of silently staying bf16 — only shared_* grad norms and the
# forward sums moved).  The fused silu·mul→quantize epilogue landing in
# the same PR was verified bitwise-neutral: router/w_gate/w_up/w_down
# grad norms are unchanged from the previous goldens.
_GOLDEN_FWD_SUM = 12.953460693359375
_GOLDEN_LOSS = -12.785236358642578
_GOLDEN_Y00 = -0.022556953132152557
_GOLDEN_GRADNORMS = {
    "router": 178.5314483642578,
    "shared_down": 415.6036376953125,
    "shared_gate": 450.72210693359375,
    "shared_up": 436.6423034667969,
    "w_down": 271.82525634765625,
    "w_gate": 289.45892333984375,
    "w_up": 267.9383544921875,
}


@pytest.mark.slow
def test_moe_fp8_bitwise_golden():
    cfg, params, x = _moe_fixture()
    (l, y), g = jax.value_and_grad(_moe_loss(cfg), has_aux=True)(params, x)
    assert float(jnp.sum(y.astype(jnp.float32))) == _GOLDEN_FWD_SUM
    assert float(l) == _GOLDEN_LOSS
    assert float(y[0, 0]) == _GOLDEN_Y00
    for name, want in _GOLDEN_GRADNORMS.items():
        assert float(jnp.linalg.norm(g[name])) == want, name


def test_capacity_respects_block_m_alignment():
    # non-default tile heights must drive the capacity round-up
    assert _capacity(49152, 16, 2.0) == 6144            # 128-aligned default
    assert _capacity(49152, 16, 2.0, align=256) == 6144  # already aligned
    assert _capacity(1000, 4, 2.0, align=64) % 64 == 0
    assert _capacity(1000, 4, 2.0, align=512) == 512
    # the clamp itself is aligned now: tiny decode shapes round up to one
    # tile instead of returning the unaligned slot count
    assert _capacity(48, 16, 2.0, align=256) == 256


def test_moe_with_nondefault_kernel_config_runs():
    cfg, params, x = _moe_fixture()
    import dataclasses
    cfg = dataclasses.replace(
        cfg, kernel_config=KernelConfig(block_m=64,
                                        backend="pallas_interpret"))
    y, aux = moe_apply(params, x, cfg)
    assert bool(jnp.isfinite(y).all())


# ---------------------------------------------------------------------------
# Pool + cost model + autotuner cache
# ---------------------------------------------------------------------------

def test_candidate_pool_filters_legality():
    cands = candidate_pool(256, 128)
    assert cands and all(c.compatible(256, 128) for c in cands)
    assert all(c.block_n == 128 for c in cands)     # N=128 excludes bn=256
    # the pool spans the training tile heights AND the decode-specialized
    # tiny-M entries (block_m=8/16, serving's per-step grouped GEMM)
    assert {c.block_m for c in candidate_pool(512, 512)} == \
        {8, 16, 64, 128, 256, 512}
    assert {c.block_m for c in plan_mod.DECODE_POOL} == {8, 16}


def test_candidate_pool_requires_transposed_legality():
    """The fp8 VJP dgrad runs the transposed GEMM under the same config:
    a (K=128, N=256)-forward-legal block_n=256 entry would crash every
    backward (N'=128 % 256 != 0) and must not be selectable."""
    for c in candidate_pool(128, 256):
        assert c.compatible(256, 128), c            # transposed orientation
    assert any(c.block_n == 256 for c in CONFIG_POOL
               if c.compatible(128, 256))           # ...though fwd-legal
    # and the full train path holds for an autotuned rectangular shape
    from repro.core.grouped_gemm import grouped_linear
    cfg = candidate_pool(128, 256)[0].with_(backend="pallas_interpret")
    x = jnp.ones((32, 128), jnp.float32)
    w = jnp.ones((2, 128, 256), jnp.float32)
    gs = jnp.asarray([20, 12], jnp.int32)
    jax.grad(lambda x_: jnp.sum(grouped_linear(
        x_, w, gs, precision="fp8", config=cfg)))(x)   # must not raise


def test_cost_model_prefers_fewer_boundary_tiles():
    # many tiny groups -> small block_m wins (fewer inflated visits);
    # one huge group -> visit counts equalize and taller tiles never lose
    small = estimate_cost_s(4096, 512, 512, 64, KernelConfig(block_m=64))
    big = estimate_cost_s(4096, 512, 512, 64, KernelConfig(block_m=512))
    assert small < big


def test_autotune_persists_and_reloads_identically(tmp_path, monkeypatch):
    """Satellite: write -> load -> identical selection, without
    re-measuring on the cache hit."""
    cache = str(tmp_path / "tileplan_cache.json")
    measured = []
    real = plan_mod._measure_candidate

    def counting(*a, **kw):
        measured.append(a)
        return real(*a, iters=1, warmup=0, **{k: v for k, v in kw.items()
                                              if k not in ("iters", "warmup")})

    monkeypatch.setattr(plan_mod, "_measure_candidate", counting)
    first = autotune(256, 128, 128, 4, backend="pallas_interpret",
                     cache_path=cache, max_candidates=2)
    assert os.path.exists(cache)
    assert measured, "live-backend measurement should have run"

    n_measured = len(measured)
    plan_mod.clear_cache_memo()            # force a re-read from disk
    second = autotune(256, 128, 128, 4, backend="pallas_interpret",
                      cache_path=cache, max_candidates=2)
    assert second == first
    assert len(measured) == n_measured, "cache hit must not re-measure"


def test_autotune_cost_model_only_on_tile_free_backend(tmp_path,
                                                       monkeypatch):
    """xla backends ignore tile shapes -> pure cost-model selection, no
    measurement, still cached."""
    if not dispatch.availability("xla_ragged")[0]:
        pytest.skip("no ragged_dot in this jax")
    cache = str(tmp_path / "c.json")
    monkeypatch.setattr(plan_mod, "_measure_candidate",
                        lambda *a, **kw: pytest.fail("measured a "
                                                     "tile-free backend"))
    cfg = autotune(1024, 256, 256, 8, backend="xla_ragged",
                   cache_path=cache)
    assert cfg.backend == "xla_ragged"
    entries = plan_mod.load_cache(cache)
    (entry,) = entries.values()
    assert entry["source"] == "cost_model"


def test_pinned_out_dtype_honoured_everywhere():
    """A config with a pinned out_dtype must produce that dtype from every
    entry point; with out_dtype=None (the default) grouped_linear keeps
    its historical x.dtype behaviour."""
    from repro.core.grouped_gemm import grouped_linear
    a8, sa, b8, sb, gs = _quantized([40, 24], 128, 128)
    x = jnp.ones((64, 128), jnp.bfloat16)
    w = jnp.ones((2, 128, 128), jnp.bfloat16)
    pinned = KernelConfig(backend="pallas_interpret",
                          out_dtype=jnp.float32)
    assert dispatch.grouped_gemm_fp8(
        a8, sa, b8, sb, gs, config=pinned).dtype == jnp.float32
    assert grouped_linear(x, w, gs, precision="fp8",
                          config=pinned).dtype == jnp.float32
    default = KernelConfig(backend="pallas_interpret")
    assert grouped_linear(x, w, gs, precision="fp8",
                          config=default).dtype == jnp.bfloat16
    assert dispatch.grouped_gemm_fp8(
        a8, sa, b8, sb, gs, config=default).dtype == jnp.bfloat16
    # explicit per-call override beats the pin
    assert grouped_linear(x, w, gs, precision="fp8", config=pinned,
                          out_dtype=jnp.bfloat16).dtype == jnp.bfloat16
    # the bf16 path honours the pin too (and keeps x.dtype without one)
    assert grouped_linear(x, w, gs, precision="bf16",
                          config=pinned).dtype == jnp.float32
    assert grouped_linear(x, w, gs, precision="bf16").dtype == jnp.bfloat16


def test_save_cache_merges_concurrent_writers(tmp_path):
    """Read-modify-write across processes: a save must not drop entries
    another writer persisted since our load."""
    cache = str(tmp_path / "c.json")
    plan_mod.save_cache({"a": {"config": KernelConfig().to_dict()}}, cache)
    # simulate a second process: bypass this process's memoized view
    plan_mod.clear_cache_memo()
    plan_mod.save_cache({"b": {"config": KernelConfig().to_dict()}}, cache)
    plan_mod.clear_cache_memo()
    assert set(plan_mod.load_cache(cache)) == {"a", "b"}


def test_autotune_m_bucketing_shares_entries(tmp_path):
    cache = str(tmp_path / "c.json")
    a = autotune(513, 128, 128, 4, backend="pallas_interpret",
                 cache_path=cache, measure=False)
    b = autotune(1024, 128, 128, 4, backend="pallas_interpret",
                 cache_path=cache, measure=False)
    assert a == b
    assert len(plan_mod.load_cache(cache)) == 1


# ---------------------------------------------------------------------------
# Satellite: quantize_tilewise never refused for a pure-quantization call
# ---------------------------------------------------------------------------

def test_quantize_tilewise_falls_back_to_ref(monkeypatch):
    from repro import compat
    monkeypatch.setattr(compat, "has_tpu", lambda: False)
    dispatch.set_default_backend("pallas")     # unavailable here
    try:
        x = jnp.ones((8, 128), jnp.float32)
        q8, s = dispatch.quantize_tilewise(x)   # must not raise
        qr, sr = ref.quantize_tilewise_ref(x)
        np.testing.assert_array_equal(np.asarray(q8, np.float32),
                                      np.asarray(qr, np.float32))
    finally:
        dispatch.set_default_backend(None)


def test_quantize_tilewise_explicit_unavailable_still_raises(monkeypatch):
    """The ref fallback serves auto-resolution failures only — an
    explicitly requested kernel backend must not be silently stood in."""
    from repro import compat
    monkeypatch.setattr(compat, "has_tpu", lambda: False)
    with pytest.raises(dispatch.BackendUnavailableError):
        dispatch.quantize_tilewise(jnp.ones((8, 128)), backend="pallas")


def test_quantize_blockwise_batched_routes_through_dispatch(monkeypatch):
    """Satellite: the batched (per-expert) weight quantization goes
    through the registry seam like the unbatched form — a future quant
    kernel covers both — with the same refusal semantics."""
    from repro import compat
    from repro.core import quantization as q
    w = jnp.ones((2, 128, 128), jnp.float32)
    q8, s = q.quantize_blockwise_batched(w)
    qr, sr = jax.vmap(ref.quantize_blockwise_ref)(w)
    np.testing.assert_array_equal(np.asarray(q8, np.float32),
                                  np.asarray(qr, np.float32))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))
    monkeypatch.setattr(compat, "has_tpu", lambda: False)
    with pytest.raises(dispatch.BackendUnavailableError):
        q.quantize_blockwise_batched(w, backend="pallas")
    # auto-resolution failure still serves ref (never refuses pure quant)
    dispatch.set_default_backend("pallas")
    try:
        q.quantize_blockwise_batched(w)        # must not raise
    finally:
        dispatch.set_default_backend(None)


def test_explicit_auto_escapes_pinned_backend(monkeypatch):
    """backend='auto' at a call site must re-enter auto-resolution even
    when the installed default pins a concrete (unavailable) backend."""
    from repro import compat
    monkeypatch.setattr(compat, "has_tpu", lambda: False)
    with plan_mod.default_config(KernelConfig(backend="pallas")):
        cfg = plan_mod.resolve_config(None, backend="auto")
        assert cfg.backend is None
        dispatch.resolve_backend(cfg.backend)   # must not raise


def test_autotune_measured_request_upgrades_cost_model_entry(tmp_path,
                                                             monkeypatch):
    cache = str(tmp_path / "c.json")
    seeded = autotune(256, 128, 128, 4, backend="pallas_interpret",
                      cache_path=cache, measure=False)
    assert plan_mod.load_cache(cache)[plan_mod.cache_key(
        plan_mod._device_kind(), "pallas_interpret", 256, 128, 128, 4
    )]["source"] == "cost_model"
    monkeypatch.setattr(plan_mod, "_measure_candidate",
                        lambda c, *a, **kw: 0.0 if c == seeded else 1.0)
    upgraded = autotune(256, 128, 128, 4, backend="pallas_interpret",
                        cache_path=cache, measure=True, max_candidates=2)
    entries = plan_mod.load_cache(cache)
    (entry,) = entries.values()
    assert entry["source"] == "measured"
    # and a further measured request is now a pure cache hit
    monkeypatch.setattr(plan_mod, "_measure_candidate",
                        lambda *a, **kw: pytest.fail("re-measured"))
    again = autotune(256, 128, 128, 4, backend="pallas_interpret",
                     cache_path=cache, measure=True)
    assert again == upgraded
