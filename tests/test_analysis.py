"""Tests for the kernel contract checker (repro.analysis).

Five layers, each exercised both ways: zero findings on the clean tree,
and each known-bad fixture firing exactly its own rule — plus the
coverage property the ISSUE pins: removing a contract expectation
demonstrably lets the matching violation through.
"""
import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (ast_lint, contracts, registry_lint,
                            resource_lint, retrace)
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.findings import Finding, RULES, filter_baselined
from repro.core import quantization
from repro.launch.hlo_analysis import analyze, find_padding_ops

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "analysis")


def _rules(findings):
    return [f.rule_id for f in findings]


# ---------------------------------------------------------------------------
# layer 3: AST lint
# ---------------------------------------------------------------------------

def test_ast_clean_tree_zero_findings():
    assert ast_lint.scan_paths() == []


def test_fixture_direct_kernel_call_fires_exactly_a01():
    fs = ast_lint.scan_file(
        os.path.join(FIXTURES, "bad_direct_kernel_call.py"))
    assert _rules(fs) == ["REPRO-A01"]
    assert "gmm_pallas" in fs[0].message
    assert fs[0].line > 1 and fs[0].path.endswith(
        "bad_direct_kernel_call.py")


def test_fixture_block_literal_fires_exactly_a03():
    fs = ast_lint.scan_file(os.path.join(FIXTURES, "bad_block_literal.py"))
    assert _rules(fs) == ["REPRO-A03"]
    assert "block_n=96" in fs[0].message
    assert "not a multiple of 128" in fs[0].message


def test_fixture_bare_assert_fires_exactly_a02():
    fs = ast_lint.scan_file(
        os.path.join(FIXTURES, "kernels", "bad_bare_assert.py"))
    assert _rules(fs) == ["REPRO-A02"]


def test_kernel_file_asserts_allowed_outside_lint():
    # the same source outside a kernels/ dir is not an A02 violation
    src = "def f(x):\n    assert x\n    return x\n"
    assert ast_lint.scan_source(src, "src/repro/core/whatever.py") == []
    assert _rules(ast_lint.scan_source(
        src, "src/repro/kernels/whatever.py")) == ["REPRO-A02"]


# ---------------------------------------------------------------------------
# layer 2: registry / alignment lint
# ---------------------------------------------------------------------------

def test_registry_clean_tree_zero_findings():
    assert registry_lint.run() == []


# ---------------------------------------------------------------------------
# layer 1: jaxpr contracts
# ---------------------------------------------------------------------------

def _double_quantize(x):
    # WRONG by construction: quantizes the same buffer twice
    q1, s1 = quantization.quantize_tilewise(x)
    q2, s2 = quantization.quantize_tilewise(x)
    return q1, s1, q2, s2


def test_double_quantize_fires_exactly_c01():
    x = jnp.ones((8, 128), jnp.float32)
    c = contracts.Contract(name="test.double_quantize",
                           quantize_count=1,
                           path="tests/test_analysis.py")
    fs = contracts.check_contract(_double_quantize, c, x)
    assert _rules(fs) == ["REPRO-C01"]
    assert "traced 2" in fs[0].message


def test_coverage_property_removing_expectation_lets_fixture_pass():
    # the ISSUE's acceptance: each gate is demonstrably load-bearing —
    # the same violating fn passes once the expectation is removed
    x = jnp.ones((8, 128), jnp.float32)
    c_off = contracts.Contract(name="test.double_quantize.unchecked",
                               quantize_count=None)
    assert contracts.check_contract(_double_quantize, c_off, x) == []


def test_padding_fires_c03_and_zero_width_pad_does_not():
    x = jnp.ones((8, 128), jnp.float32)
    c = contracts.Contract(name="test.pad", forbid_padding=True,
                           path="tests/test_analysis.py")
    grown = contracts.check_contract(
        lambda v: jnp.pad(v, ((0, 5), (0, 0))), c, x)
    assert _rules(grown) == ["REPRO-C03"]
    zero_width = contracts.check_contract(
        lambda v: jnp.pad(v, ((0, 0), (0, 0))), c, x)
    assert zero_width == []


def test_wide_intermediate_fires_c04():
    x = jnp.ones((8, 128), jnp.float32)
    c = contracts.Contract(name="test.wide",
                           forbid_wide_shapes=((8, 128),),
                           path="tests/test_analysis.py")
    fs = contracts.check_contract(lambda v: jax.nn.silu(v) * v, c, x)
    assert "REPRO-C04" in _rules(fs)


def test_registered_linear_fwd_contract_clean():
    reg = contracts.load_registered()
    assert contracts.run_contract(reg["grouped_linear.fp8.fwd"]) == []


def test_every_finding_rule_is_documented():
    reg = contracts.load_registered()
    assert {"grouped_linear.fp8.fwd", "grouped_linear.fp8.grad",
            "grouped_linear_fused.fp8.fwd", "moe_apply.fp8.grad",
            "engine.generate.decode_plan"} <= set(reg)
    for rid in ("REPRO-C01", "REPRO-C03", "REPRO-R05", "REPRO-A01"):
        assert rid in RULES


# ---------------------------------------------------------------------------
# layer 4: kernel-resource lint
# ---------------------------------------------------------------------------

def test_resource_clean_tree_zero_findings():
    assert resource_lint.run() == []


def test_fixture_over_vmem_pool_entry_fires_exactly_v01():
    fs = resource_lint.scan_file(
        os.path.join(FIXTURES, "bad_vmem_pool_entry.json"))
    assert _rules(fs) == ["REPRO-V01"]
    assert "exceeds" in fs[0].message and "budget" in fs[0].message


def test_fixture_misaligned_decode_entry_fires_exactly_v03():
    fs = resource_lint.scan_file(
        os.path.join(FIXTURES, "bad_decode_align_entry.json"))
    assert _rules(fs) == ["REPRO-V03"]
    assert "block_n=96" in fs[0].message


def test_resource_coverage_fixing_the_entry_lets_it_pass():
    # coverage property: the fixture's violation is load-bearing — the
    # same entry with the defect removed produces zero findings
    shape = {"m": 16, "k": 4096, "n": 4096}
    ok = resource_lint.check_entry(
        "gemm", {"block_m": 8, "block_n": 128, "block_k": 128}, shape,
        device="tpu v5e", decode=True)
    assert ok == []


def test_check_entry_sublane_and_quant_alignment_rules():
    shape = {"m": 8192, "k": 4096, "n": 4096}
    v02 = resource_lint.check_entry(
        "gemm", {"block_m": 12, "block_n": 128, "block_k": 128}, shape)
    assert _rules(v02) == ["REPRO-V02"]
    v04 = resource_lint.check_entry(
        "gemm", {"block_m": 128, "block_n": 128, "block_k": 192}, shape)
    assert _rules(v04) == ["REPRO-V04"]


def test_check_entry_degenerate_and_decode_rules():
    # tile wider than the operand: V05
    v05 = resource_lint.check_entry(
        "gemm", {"block_m": 128, "block_n": 512, "block_k": 128},
        {"m": 8192, "k": 4096, "n": 256})
    assert _rules(v05) == ["REPRO-V05"]
    # decode entry taller than any decode step: V06
    v06 = resource_lint.check_entry(
        "gemm", {"block_m": 24, "block_n": 128, "block_k": 128},
        {"m": 16, "k": 4096, "n": 4096}, decode=True)
    assert _rules(v06) == ["REPRO-V06"]


def test_check_entry_pipeline_headroom_fires_v07():
    # fits single-buffered (~11 MiB) but not double-buffered (~18 MiB)
    fs = resource_lint.check_entry(
        "gemm", {"block_m": 8192, "block_n": 128, "block_k": 128},
        {"m": 16384, "k": 4096, "n": 4096}, device="tpu v5e")
    assert _rules(fs) == ["REPRO-V07"]
    # the same entry on the 32 MiB part is feasible
    assert resource_lint.check_entry(
        "gemm", {"block_m": 8192, "block_n": 128, "block_k": 128},
        {"m": 16384, "k": 4096, "n": 4096}, device="tpu v4") == []


# ---------------------------------------------------------------------------
# layer 5: retrace detector
# ---------------------------------------------------------------------------

def test_fixture_shape_varying_loop_fires_exactly_t01():
    fs = retrace.check_fixture(
        os.path.join(FIXTURES, "bad_retrace_loop.py"))
    assert _rules(fs) == ["REPRO-T01"]
    assert "retraced 3" in fs[0].message


def test_retrace_coverage_removing_expectation_lets_fixture_pass():
    # the same shape-varying loop with no declared expectation: clean
    def build():
        def step(x):
            return jnp.sum(x * 2.0)
        fn = jax.jit(step)
        calls = [(jnp.ones((r, 128), jnp.float32),) for r in (8, 16, 24)]
        return fn, calls
    c = retrace.CompileContract(name="test.unchecked", build=build,
                                expected={})
    assert retrace.check_compile_contract(c) == []


def test_retrace_shape_stable_calls_compile_once():
    def build():
        def step(x):
            return jnp.sum(x * 2.0)
        fn = jax.jit(step)
        calls = [(jnp.full((8, 128), float(i)),) for i in range(3)]
        return fn, calls
    c = retrace.CompileContract(name="test.stable", build=build,
                                expected={"step": 1})
    assert retrace.check_compile_contract(c) == []


def test_registered_compile_contracts_present():
    reg = retrace.load_registered()
    assert {"grouped_linear.fp8.retrace",
            "grouped_linear_ffn.fp8.retrace",
            "engine.generate.retrace",
            "padding_baseline.bucket.retrace"} <= set(reg)
    assert reg["engine.generate.retrace"].rule == "REPRO-T02"
    assert reg["padding_baseline.bucket.retrace"].rule == "REPRO-T03"


def test_registered_ffn_retrace_contract_clean():
    # the acceptance pin: repeated shape-stable grouped_linear_ffn
    # fwd+bwd calls compile exactly once
    reg = retrace.load_registered()
    assert retrace.check_compile_contract(
        reg["grouped_linear_ffn.fp8.retrace"]) == []


def test_registered_baseline_bucket_retrace_contract_clean():
    reg = retrace.load_registered()
    assert retrace.check_compile_contract(
        reg["padding_baseline.bucket.retrace"]) == []


# ---------------------------------------------------------------------------
# CLI + baseline
# ---------------------------------------------------------------------------

def test_cli_nonzero_on_fixture_and_baseline_suppresses(tmp_path, capsys):
    fixture = os.path.join(FIXTURES, "bad_block_literal.py")
    rc = analysis_main(["--ast", "--paths", fixture])
    out = capsys.readouterr().out
    assert rc == 1
    assert "REPRO-A03" in out and "bad_block_literal.py" in out

    finding = ast_lint.scan_file(fixture)[0]
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps({"findings": [finding.key()]}))
    rc = analysis_main(["--ast", "--paths", fixture,
                        "--baseline", str(baseline)])
    out = capsys.readouterr().out
    assert rc == 0 and "1 baselined" in out


def test_cli_resources_layer_clean_and_rules_listed(capsys):
    assert analysis_main(["--resources"]) == 0
    capsys.readouterr()
    assert analysis_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in ("REPRO-V01", "REPRO-V07", "REPRO-T01", "REPRO-T03"):
        assert rid in out


def test_baseline_filter_is_line_insensitive():
    f1 = Finding("REPRO-A03", "p.py", 10, "msg")
    f2 = Finding("REPRO-A03", "p.py", 99, "msg")
    assert filter_baselined([f2], {f1.key()}) == []


# ---------------------------------------------------------------------------
# HLO-level padding detection (satellite: launch/hlo_analysis)
# ---------------------------------------------------------------------------

_SYNTH_HLO = """\
ENTRY %main (p0: f32[256,128]) -> f32[264,128] {
  %p0 = f32[256,128] parameter(0)
  %zero = f32[] constant(0)
  %zw = f32[256,128] pad(f32[256,128] %p0, f32[] %zero), padding=0_0x0_0
  %grow = f32[264,128] pad(f32[256,128] %zw, f32[] %zero), padding=0_8x0_0
  ROOT %cp = f32[264,128] copy(f32[264,128] %grow), metadata={op_name="jit(f)/pad"}
}
"""


def test_find_padding_ops_reports_real_pads_not_zero_width():
    hits = find_padding_ops(_SYNTH_HLO)
    ops = {h["op"]: h for h in hits}
    assert "%grow" in ops and ops["%grow"]["opcode"] == "pad"
    assert "%cp" in ops        # copy labelled as a fused pad
    assert "%zw" not in ops    # zero-width pad: XLA no-op, not padding
    # analyze() is unchanged by the new helper
    assert analyze(_SYNTH_HLO)["hbm_bytes"] > 0


def test_benchmarks_hlo_shim_reexports_the_same_objects():
    # satellite: one source of truth — the benchmarks/ shim must expose
    # the SAME function objects as repro.launch.hlo_analysis, so the two
    # historical import paths can never drift apart again
    import benchmarks.hlo_analysis as bh
    import repro.launch.hlo_analysis as lh
    assert bh.analyze is lh.analyze
    assert bh.parse_module is lh.parse_module
    assert bh.find_padding_ops is lh.find_padding_ops


def test_find_padding_ops_on_compiled_programs():
    x = jax.ShapeDtypeStruct((60, 128), jnp.float32)
    padded = jax.jit(lambda v: jnp.pad(v, ((0, 4), (0, 0)))) \
        .lower(x).compile().as_text()
    assert find_padding_ops(padded), "compiled pad program must be flagged"
    clean = jax.jit(lambda v: jnp.tanh(v) @ v.T).lower(x).compile().as_text()
    assert find_padding_ops(clean) == []


# ---------------------------------------------------------------------------
# flash-attention shape guard (satellite: assert -> ValueError)
# ---------------------------------------------------------------------------

def test_flash_attention_gqa_mismatch_raises_value_error():
    from repro.kernels.flash_attention_kernel import flash_attention
    q = jnp.zeros((1, 3, 16, 8), jnp.float32)
    kv = jnp.zeros((1, 2, 16, 8), jnp.float32)
    with pytest.raises(ValueError, match="multiple of Hkv"):
        flash_attention(q, kv, kv)
