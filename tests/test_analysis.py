"""Tests for the kernel contract checker (repro.analysis).

Three layers, each exercised both ways: zero findings on the clean tree,
and each known-bad fixture firing exactly its own rule — plus the
coverage property the ISSUE pins: removing a contract expectation
demonstrably lets the matching violation through.
"""
import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import ast_lint, contracts, registry_lint
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.findings import Finding, RULES, filter_baselined
from repro.core import quantization
from repro.launch.hlo_analysis import analyze, find_padding_ops

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "analysis")


def _rules(findings):
    return [f.rule_id for f in findings]


# ---------------------------------------------------------------------------
# layer 3: AST lint
# ---------------------------------------------------------------------------

def test_ast_clean_tree_zero_findings():
    assert ast_lint.scan_paths() == []


def test_fixture_direct_kernel_call_fires_exactly_a01():
    fs = ast_lint.scan_file(
        os.path.join(FIXTURES, "bad_direct_kernel_call.py"))
    assert _rules(fs) == ["REPRO-A01"]
    assert "gmm_pallas" in fs[0].message
    assert fs[0].line > 1 and fs[0].path.endswith(
        "bad_direct_kernel_call.py")


def test_fixture_block_literal_fires_exactly_a03():
    fs = ast_lint.scan_file(os.path.join(FIXTURES, "bad_block_literal.py"))
    assert _rules(fs) == ["REPRO-A03"]
    assert "block_n=96" in fs[0].message
    assert "not a multiple of 128" in fs[0].message


def test_fixture_bare_assert_fires_exactly_a02():
    fs = ast_lint.scan_file(
        os.path.join(FIXTURES, "kernels", "bad_bare_assert.py"))
    assert _rules(fs) == ["REPRO-A02"]


def test_kernel_file_asserts_allowed_outside_lint():
    # the same source outside a kernels/ dir is not an A02 violation
    src = "def f(x):\n    assert x\n    return x\n"
    assert ast_lint.scan_source(src, "src/repro/core/whatever.py") == []
    assert _rules(ast_lint.scan_source(
        src, "src/repro/kernels/whatever.py")) == ["REPRO-A02"]


# ---------------------------------------------------------------------------
# layer 2: registry / alignment lint
# ---------------------------------------------------------------------------

def test_registry_clean_tree_zero_findings():
    assert registry_lint.run() == []


# ---------------------------------------------------------------------------
# layer 1: jaxpr contracts
# ---------------------------------------------------------------------------

def _double_quantize(x):
    # WRONG by construction: quantizes the same buffer twice
    q1, s1 = quantization.quantize_tilewise(x)
    q2, s2 = quantization.quantize_tilewise(x)
    return q1, s1, q2, s2


def test_double_quantize_fires_exactly_c01():
    x = jnp.ones((8, 128), jnp.float32)
    c = contracts.Contract(name="test.double_quantize",
                           quantize_count=1,
                           path="tests/test_analysis.py")
    fs = contracts.check_contract(_double_quantize, c, x)
    assert _rules(fs) == ["REPRO-C01"]
    assert "traced 2" in fs[0].message


def test_coverage_property_removing_expectation_lets_fixture_pass():
    # the ISSUE's acceptance: each gate is demonstrably load-bearing —
    # the same violating fn passes once the expectation is removed
    x = jnp.ones((8, 128), jnp.float32)
    c_off = contracts.Contract(name="test.double_quantize.unchecked",
                               quantize_count=None)
    assert contracts.check_contract(_double_quantize, c_off, x) == []


def test_padding_fires_c03_and_zero_width_pad_does_not():
    x = jnp.ones((8, 128), jnp.float32)
    c = contracts.Contract(name="test.pad", forbid_padding=True,
                           path="tests/test_analysis.py")
    grown = contracts.check_contract(
        lambda v: jnp.pad(v, ((0, 5), (0, 0))), c, x)
    assert _rules(grown) == ["REPRO-C03"]
    zero_width = contracts.check_contract(
        lambda v: jnp.pad(v, ((0, 0), (0, 0))), c, x)
    assert zero_width == []


def test_wide_intermediate_fires_c04():
    x = jnp.ones((8, 128), jnp.float32)
    c = contracts.Contract(name="test.wide",
                           forbid_wide_shapes=((8, 128),),
                           path="tests/test_analysis.py")
    fs = contracts.check_contract(lambda v: jax.nn.silu(v) * v, c, x)
    assert "REPRO-C04" in _rules(fs)


def test_registered_linear_fwd_contract_clean():
    reg = contracts.load_registered()
    assert contracts.run_contract(reg["grouped_linear.fp8.fwd"]) == []


def test_every_finding_rule_is_documented():
    reg = contracts.load_registered()
    assert {"grouped_linear.fp8.fwd", "grouped_linear.fp8.grad",
            "grouped_linear_fused.fp8.fwd", "moe_apply.fp8.grad",
            "engine.generate.decode_plan"} <= set(reg)
    for rid in ("REPRO-C01", "REPRO-C03", "REPRO-R05", "REPRO-A01"):
        assert rid in RULES


# ---------------------------------------------------------------------------
# CLI + baseline
# ---------------------------------------------------------------------------

def test_cli_nonzero_on_fixture_and_baseline_suppresses(tmp_path, capsys):
    fixture = os.path.join(FIXTURES, "bad_block_literal.py")
    rc = analysis_main(["--ast", "--paths", fixture])
    out = capsys.readouterr().out
    assert rc == 1
    assert "REPRO-A03" in out and "bad_block_literal.py" in out

    finding = ast_lint.scan_file(fixture)[0]
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps({"findings": [finding.key()]}))
    rc = analysis_main(["--ast", "--paths", fixture,
                        "--baseline", str(baseline)])
    out = capsys.readouterr().out
    assert rc == 0 and "1 baselined" in out


def test_baseline_filter_is_line_insensitive():
    f1 = Finding("REPRO-A03", "p.py", 10, "msg")
    f2 = Finding("REPRO-A03", "p.py", 99, "msg")
    assert filter_baselined([f2], {f1.key()}) == []


# ---------------------------------------------------------------------------
# HLO-level padding detection (satellite: launch/hlo_analysis)
# ---------------------------------------------------------------------------

_SYNTH_HLO = """\
ENTRY %main (p0: f32[256,128]) -> f32[264,128] {
  %p0 = f32[256,128] parameter(0)
  %zero = f32[] constant(0)
  %zw = f32[256,128] pad(f32[256,128] %p0, f32[] %zero), padding=0_0x0_0
  %grow = f32[264,128] pad(f32[256,128] %zw, f32[] %zero), padding=0_8x0_0
  ROOT %cp = f32[264,128] copy(f32[264,128] %grow), metadata={op_name="jit(f)/pad"}
}
"""


def test_find_padding_ops_reports_real_pads_not_zero_width():
    hits = find_padding_ops(_SYNTH_HLO)
    ops = {h["op"]: h for h in hits}
    assert "%grow" in ops and ops["%grow"]["opcode"] == "pad"
    assert "%cp" in ops        # copy labelled as a fused pad
    assert "%zw" not in ops    # zero-width pad: XLA no-op, not padding
    # analyze() is unchanged by the new helper
    assert analyze(_SYNTH_HLO)["hbm_bytes"] > 0


def test_find_padding_ops_on_compiled_programs():
    x = jax.ShapeDtypeStruct((60, 128), jnp.float32)
    padded = jax.jit(lambda v: jnp.pad(v, ((0, 4), (0, 0)))) \
        .lower(x).compile().as_text()
    assert find_padding_ops(padded), "compiled pad program must be flagged"
    clean = jax.jit(lambda v: jnp.tanh(v) @ v.T).lower(x).compile().as_text()
    assert find_padding_ops(clean) == []


# ---------------------------------------------------------------------------
# flash-attention shape guard (satellite: assert -> ValueError)
# ---------------------------------------------------------------------------

def test_flash_attention_gqa_mismatch_raises_value_error():
    from repro.kernels.flash_attention_kernel import flash_attention
    q = jnp.zeros((1, 3, 16, 8), jnp.float32)
    kv = jnp.zeros((1, 2, 16, 8), jnp.float32)
    with pytest.raises(ValueError, match="multiple of Hkv"):
        flash_attention(q, kv, kv)
