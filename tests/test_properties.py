"""Hypothesis property tests on the system's core invariants.

Skipped (not errored) when hypothesis isn't installed — CI tier-1 runs on
a bare image; the property sweep is a tier-2 extra.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import padding_baseline as pb
from repro.kernels import dispatch, ref
from repro.kernels.grouped_gemm_kernel import make_group_metadata
from repro.kernels.plan import make_tile_plan

SET = dict(max_examples=25, deadline=None)


@given(st.lists(st.integers(0, 300), min_size=1, max_size=8),
       st.sampled_from([64, 128, 256]))
@settings(**SET)
def test_group_metadata_invariants(sizes, block_m):
    """For ANY ragged group sizes: (1) every row of every group is covered
    by exactly one (group, tile) visit that owns it; (2) visits are sorted
    so same-tile visits are adjacent; (3) visit count <= tiles + G - 1."""
    m = max(sum(sizes), 1)
    g = len(sizes)
    gs = jnp.asarray(sizes, jnp.int32)
    offs, gids, tids = make_group_metadata(gs, m, block_m, g)
    offs = np.asarray(offs)
    gids, tids = np.asarray(gids), np.asarray(tids)
    num_tiles = -(-m // block_m)
    assert len(gids) == num_tiles + g - 1

    # ownership: row r of group gi is covered iff some visit has
    # (gids==gi and tids == r // block_m)
    visits = set(zip(gids.tolist(), tids.tolist()))
    for gi in range(g):
        for r in (offs[gi], offs[gi + 1] - 1):
            if offs[gi] <= r < offs[gi + 1]:
                assert (gi, r // block_m) in visits, (gi, r, sizes)

    # same-tile adjacency (output revisiting constraint of the kernel)
    seen_tiles = {}
    for i, t in enumerate(tids.tolist()):
        if t in seen_tiles:
            assert i - seen_tiles[t] == 1 or tids[i - 1] == t, \
                "non-adjacent revisit"
        seen_tiles[t] = i


@given(st.lists(st.integers(0, 300), min_size=1, max_size=6),
       st.sampled_from([64, 128]))
@settings(max_examples=10, deadline=None)
def test_plan_reuse_bitwise_identical(sizes, block_m):
    """For ANY ragged split (zero groups, boundary-straddling groups,
    single rows): dispatching with a precomputed TilePlan is BITWISE
    identical to the plan-free path, on the plan-consuming interpret
    backend and on the xla_exact oracle (which ignores the plan — the
    plan kwarg must be a pure no-op there)."""
    m = sum(sizes)
    if m == 0:
        return
    k = n = 128
    rng = np.random.default_rng(m + block_m)
    a8, sa = ref.quantize_tilewise_ref(
        jnp.asarray(rng.standard_normal((m, k)), jnp.float32))
    b8, sb = jax.vmap(ref.quantize_blockwise_ref)(
        jnp.asarray(rng.standard_normal((len(sizes), k, n)), jnp.float32))
    gs = jnp.asarray(sizes, jnp.int32)
    plan = make_tile_plan(gs, m, block_m=block_m)
    from repro.kernels.plan import KernelConfig
    cfg = KernelConfig(block_m=block_m)
    for backend in ("pallas_interpret", "xla_exact"):
        if not dispatch.availability(backend)[0]:
            continue
        free = dispatch.grouped_gemm_fp8(
            a8, sa, b8, sb, gs, backend=backend, config=cfg,
            out_dtype=jnp.float32)
        planned = dispatch.grouped_gemm_fp8(
            a8, sa, b8, sb, gs, backend=backend, config=cfg,
            out_dtype=jnp.float32, plan=plan)
        np.testing.assert_array_equal(np.asarray(free), np.asarray(planned),
                                      err_msg=f"{backend} {sizes}")


@given(st.integers(1, 2048), st.integers(1, 32), st.integers(0, 10_000))
@settings(**SET)
def test_paper_group_generator_sums(m, g, seed):
    from benchmarks.common import generate_group_sizes
    sizes = generate_group_sizes(m, g, seed)
    assert sizes.sum() == m and (sizes >= 0).all() and len(sizes) == g


@given(st.integers(1, 64), st.sampled_from([128, 256, 384]))
@settings(**SET)
def test_quantization_roundtrip_bounded(m, k):
    """|dequant(quant(x)) - x| <= amax_tile / FP8_MAX  (one fp8 ulp-ish)."""
    x = jnp.asarray(np.random.default_rng(m * k).standard_normal((m, k)),
                    jnp.float32) * 3.0
    q, s = ref.quantize_tilewise_ref(x)
    back = ref.dequantize_tilewise_ref(q, s)
    tiles = np.asarray(x).reshape(m, k // 128, 128)
    amax = np.abs(tiles).max(-1, keepdims=True)
    # e4m3 has a 3-bit mantissa: worst-case rounding error of a value
    # scaled into [-448, 448] is half the ulp at 448, i.e. 16 -> amax/28
    bound = np.repeat(amax / 26.0 + 1e-6, 128, axis=-1)
    err = np.abs(np.asarray(back) - np.asarray(x)).reshape(tiles.shape)
    assert (err <= bound).all()


@given(st.lists(st.integers(0, 200), min_size=1, max_size=6))
@settings(**SET)
def test_padding_roundtrip_identity(sizes):
    """unpad(pad(x)) == x for any ragged group structure."""
    m = sum(sizes)
    if m == 0:
        return
    k = 64
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    sa = jnp.asarray(rng.standard_normal((m, 4)).astype(np.float32))
    gs = jnp.asarray(sizes, jnp.int32)
    a_p, s_p, psz, row_map = pb.pad_groups(a, sa, gs)
    np.testing.assert_array_equal(np.asarray(pb.unpad_groups(a_p, row_map)),
                                  np.asarray(a))
    np.testing.assert_array_equal(np.asarray(pb.unpad_groups(s_p, row_map)),
                                  np.asarray(sa))
    # padded group sizes are block-aligned and >= originals
    psz = np.asarray(psz)
    assert (psz % 128 == 0).all() and (psz >= np.asarray(sizes)).all()


@given(st.integers(2, 6), st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_cross_entropy_uniform_logits(b, s):
    from repro.models.layers import cross_entropy
    v = 17
    logits = jnp.zeros((b, s, v))
    labels = jnp.zeros((b, s), jnp.int32)
    loss = cross_entropy(logits, labels)
    np.testing.assert_allclose(float(loss), np.log(v), rtol=1e-5)
    # ignored labels contribute nothing
    labels2 = jnp.full((b, s), -1, jnp.int32)
    assert float(cross_entropy(logits, labels2)) == 0.0
