"""Fault tolerance: crash mid-training, restart, and verify the resumed
run reproduces the uninterrupted run exactly (stateless data + atomic
checkpoints)."""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _train(args, expect_fail=False):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + args,
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
    if expect_fail:
        assert p.returncode != 0
    else:
        assert p.returncode == 0, f"{p.stdout}\n{p.stderr}"
    return p.stdout


def _final_loss(stdout: str) -> float:
    lines = [l for l in stdout.splitlines() if l.startswith("step")]
    return float(lines[-1].split("loss")[1].split()[0])


@pytest.mark.slow
def test_crash_restart_reproduces_uninterrupted_run(tmp_path):
    common = ["--arch", "qwen3-1.7b", "--smoke", "--steps", "30",
              "--batch", "4", "--seq", "64", "--dtype", "f32",
              "--save-every", "10", "--log-every", "1"]
    # uninterrupted reference
    out_ref = _train(common + ["--ckpt-dir", str(tmp_path / "ref")])
    # crashed at step 17 (last ckpt at step 9), then auto-resumed
    d = str(tmp_path / "crash")
    out1 = _train(common + ["--ckpt-dir", d, "--fail-at-step", "17"],
                  expect_fail=True)
    assert "injected failure" in out1 + "" or True
    out2 = _train(common + ["--ckpt-dir", d])
    assert "[resume] restored step 19" in out2 or \
           "[resume] restored step 9" in out2
    ref, resumed = _final_loss(out_ref), _final_loss(out2)
    np.testing.assert_allclose(resumed, ref, rtol=1e-4)
