"""End-to-end behaviour tests: training reduces loss; the serving engine
generates coherently after prefill; fp8 and bf16 paths train comparably."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model_zoo import make_model, synthetic_batch
from repro.optim import adamw
from repro.serve.engine import Engine
from repro.train.trainer import make_train_step


def _train(cfg, steps=25, lr=1e-3, batch=4, seq=64, seed=0):
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    opt_cfg = adamw.OptConfig(lr=lr, total_steps=steps, warmup_steps=3,
                              use_master=False)
    opt = adamw.init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(model.loss, opt_cfg),
                   donate_argnums=(0, 1))
    data = SyntheticLM(DataConfig(seed=seed, batch_size=batch, seq_len=seq),
                       cfg)
    losses = []
    for s in range(steps):
        params, opt, m = step(params, opt, data.batch_at(s))
        losses.append(float(m["loss"]))
    return params, losses, model


def test_training_reduces_loss_dense():
    cfg = dataclasses.replace(smoke_config("qwen3-1.7b"),
                              dtype=jnp.float32)
    _, losses, _ = _train(cfg)
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]


def test_training_reduces_loss_moe():
    cfg = dataclasses.replace(smoke_config("deepseek-moe-16b"),
                              dtype=jnp.float32)
    _, losses, _ = _train(cfg)
    assert losses[-1] < losses[0] * 0.9


def test_fp8_training_tracks_bf16():
    """The paper's fp8 grouped-GEMM path must train: loss decreases and
    stays within a reasonable band of the bf16 run."""
    base = dataclasses.replace(smoke_config("deepseek-moe-16b"),
                               dtype=jnp.float32)
    fp8 = dataclasses.replace(base, precision="fp8",
                              gemm_backend="xla_exact")
    _, l_bf16, _ = _train(base, steps=20)
    _, l_fp8, _ = _train(fp8, steps=20)
    assert l_fp8[-1] < l_fp8[0] * 0.95
    assert abs(l_fp8[-1] - l_bf16[-1]) < 0.5 * abs(l_bf16[0])


def test_generation_after_training():
    cfg = dataclasses.replace(smoke_config("qwen3-1.7b"),
                              dtype=jnp.float32)
    params, _, model = _train(cfg, steps=10)
    engine = Engine(model, params, max_new_tokens=8)
    batch = synthetic_batch(jax.random.PRNGKey(3), cfg, 32, 2)
    res = engine.generate(batch)
    toks = np.asarray(res.tokens)
    assert toks.shape == (2, 8)
    assert (toks >= 0).all() and (toks < cfg.vocab_size).all()


def test_recurrent_decode_long_state_consistency():
    """ssm/hybrid archs: decoding N tokens one-by-one equals teacher-forced
    forward over the same tokens (state correctness over time)."""
    cfg = dataclasses.replace(smoke_config("recurrentgemma-2b"),
                              dtype=jnp.float32)
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = synthetic_batch(jax.random.PRNGKey(1), cfg, 48, 2)

    logits_full, _ = jax.jit(model.prefill)(params, batch)

    b16 = {k: (v[:, :16] if v.ndim == 2 else v) for k, v in batch.items()}
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, cache_capacity=48))(
        params, b16)
    logits = None
    step = jax.jit(model.decode_step)
    for t in range(16, 48):
        logits, cache = step(params, batch["tokens"][:, t:t + 1], cache)
    np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                               np.asarray(logits_full[:, -1], np.float32),
                               rtol=0.1, atol=0.1)
