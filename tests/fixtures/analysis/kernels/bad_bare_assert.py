"""Known-bad fixture for REPRO-A02: a bare assert in a kernel file (the
``kernels`` directory component makes the linter treat it as one).

Never imported — the AST linter parses it in tests/test_analysis.py.
"""


def kernel_entry(x):
    # WRONG: stripped under python -O; must raise ValueError instead
    assert x.shape[-1] % 128 == 0
    return x
