"""Known-bad fixture for REPRO-A01: bypasses the dispatch registry by
calling a kernel-internal Pallas entry point directly.

Never imported — the AST linter parses it in tests/test_analysis.py.
"""
from repro.kernels.grouped_gemm_kernel import gmm_pallas


def forward(lhs, rhs, plan):
    # WRONG: skips resolve()'s availability / fallback / tile policy
    return gmm_pallas(lhs, rhs, plan.group_sizes)
