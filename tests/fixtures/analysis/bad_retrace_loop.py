"""Known-bad retrace fixture: a shape-varying loop that recompiles.

The jitted step is called over a GROWING batch — every call changes the
abstract shape, so every call is a jit cache miss.  The declared
expectation (one trace) is exactly what REPRO-T01 must flag.
"""
NAME = "fixture.shape_varying_loop"

EXPECTED_TRACES = {"step": 1}


def run():
    import jax
    import jax.numpy as jnp

    def step(x):
        return jnp.sum(x * 2.0)

    fn = jax.jit(step)
    for rows in (8, 16, 24):        # three shapes -> three traces
        fn(jnp.ones((rows, 128), jnp.float32))
