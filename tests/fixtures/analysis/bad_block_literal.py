"""Known-bad fixture for REPRO-A03: hardcodes a (misaligned) tile shape
outside kernels/.

Never imported — the AST linter parses it in tests/test_analysis.py.
"""
from repro.kernels.plan import KernelConfig


def make_config():
    # WRONG: tile geometry belongs to the plan.py pool; 96 % 128 != 0
    return KernelConfig(block_n=96)
