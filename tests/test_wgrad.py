"""Ragged-contraction (wgrad) grouped GEMM subsystem: the Pallas kernel
vs the xla_exact oracle over ragged shapes (empty groups, sum < M), the
wgrad dispatch family's resolution/fallback semantics, plan reuse across
forward + dgrad + wgrad, and the wgrad-orientation autotuner."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.grouped_gemm import grouped_linear
from repro.kernels import dispatch
from repro.kernels import plan as plan_mod
from repro.kernels.plan import KernelConfig, make_tile_plan
from repro.kernels.wgrad_kernel import gmm_pallas_wgrad


def _inputs(sizes, m_buf, k, n, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m_buf, k)), jnp.bfloat16)
    dy = jnp.asarray(rng.standard_normal((m_buf, n)), jnp.bfloat16)
    return x, dy, jnp.asarray(sizes, jnp.int32)


# (sizes, m_buf, K, N): ragged, empty groups, sum < M (capacity tails),
# sub-block groups, exact multiples
CASES = [
    ([128, 128], 256, 128, 128),
    ([100, 0, 37, 163], 300, 256, 256),
    ([60, 30], 256, 128, 128),              # sum=90 << m_buf
    ([1, 1, 1, 1], 64, 128, 256),
    ([0, 0, 512], 512, 128, 384),
    ([5, 250, 3, 127, 129], 600, 384, 128),
    ([0, 0, 0], 128, 128, 128),             # every group empty
]


@pytest.mark.parametrize("sizes,m_buf,k,n", CASES)
def test_wgrad_kernel_matches_exact_oracle(sizes, m_buf, k, n):
    x, dy, gs = _inputs(sizes, m_buf, k, n, seed=sum(sizes) + m_buf)
    got = gmm_pallas_wgrad(x, dy, gs, interpret=True)
    want = dispatch.wgrad_xla_exact(x, dy, gs, num_groups=len(sizes))
    assert got.shape == (len(sizes), k, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("sizes,m_buf,k,n", CASES[:3])
def test_wgrad_xla_ragged_matches_exact_oracle(sizes, m_buf, k, n):
    if not dispatch.wgrad_availability("xla_ragged")[0]:
        pytest.skip("no ragged wgrad in this jax")
    x, dy, gs = _inputs(sizes, m_buf, k, n, seed=1)
    got = dispatch.wgrad_xla_ragged(x, dy, gs, num_groups=len(sizes))
    want = dispatch.wgrad_xla_exact(x, dy, gs, num_groups=len(sizes))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_wgrad_empty_groups_exactly_zero():
    x, dy, gs = _inputs([100, 0, 37, 163], 300, 256, 128, seed=2)
    dw = gmm_pallas_wgrad(x, dy, gs, interpret=True)
    assert float(jnp.abs(dw[1]).max()) == 0.0
    assert float(jnp.abs(dw[0]).max()) > 0.0


def test_wgrad_tail_rows_excluded_even_when_nan():
    """Rows beyond sum(group_sizes) must not leak into the contraction —
    even when they hold NaN (the pre-fix forward left exactly that in dx
    tails, and capacity buffers carry arbitrary garbage)."""
    x, dy, gs = _inputs([60, 30], 256, 128, 128, seed=3)
    x_nan = x.at[90:].set(jnp.nan)
    dy_nan = dy.at[90:].set(jnp.nan)
    dw = gmm_pallas_wgrad(x_nan, dy_nan, gs, interpret=True)
    want = dispatch.wgrad_xla_exact(x[:90], dy[:90], gs, num_groups=2)
    assert bool(jnp.isfinite(dw).all())
    np.testing.assert_allclose(np.asarray(dw), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("block_m", [64, 128, 256])
@pytest.mark.parametrize("block_k,block_n", [(128, 128), (128, 256)])
def test_wgrad_block_shape_sweep(block_m, block_k, block_n):
    x, dy, gs = _inputs([97, 31, 0, 200], 384, 256, 256, seed=7)
    got = gmm_pallas_wgrad(x, dy, gs, block_m=block_m, block_k=block_k,
                           block_n=block_n, interpret=True)
    want = dispatch.wgrad_xla_exact(x, dy, gs, num_groups=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_wgrad_precomputed_plan_bitwise_equals_plan_free():
    sizes = [100, 0, 37, 163]
    x, dy, gs = _inputs(sizes, 300, 256, 128, seed=11)
    plan = make_tile_plan(gs, 300, block_m=128)
    free = gmm_pallas_wgrad(x, dy, gs, interpret=True)
    planned = gmm_pallas_wgrad(x, dy, gs, interpret=True, plan=plan)
    np.testing.assert_array_equal(np.asarray(free), np.asarray(planned))


def test_wgrad_plan_governs_block_m():
    """A plan's block_m wins over the kwarg: the schedule IS the tiling."""
    sizes = [100, 44]
    x, dy, gs = _inputs(sizes, 144, 128, 128, seed=13)
    plan64 = make_tile_plan(gs, 144, block_m=64)
    got = gmm_pallas_wgrad(x, dy, gs, block_m=128, interpret=True,
                           plan=plan64)
    want = dispatch.wgrad_xla_exact(x, dy, gs, num_groups=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_wgrad_plan_mismatch_rejected():
    x, dy, gs = _inputs([64, 64], 128, 128, 128)
    plan = make_tile_plan(gs, 256, block_m=128)        # wrong m
    with pytest.raises(ValueError, match="TilePlan built for"):
        gmm_pallas_wgrad(x, dy, gs, interpret=True, plan=plan)


def test_wgrad_m_zero_returns_zeros():
    x, dy, gs = _inputs([0], 0, 128, 128)
    dw = gmm_pallas_wgrad(x, dy, gs, interpret=True)
    assert dw.shape == (1, 128, 128)
    assert np.all(np.asarray(dw) == 0.0)


# ---------------------------------------------------------------------------
# Dispatch family
# ---------------------------------------------------------------------------

def test_wgrad_registry_names_and_matrix():
    names = dispatch.wgrad_backend_names()
    for expected in ("pallas", "pallas_interpret", "xla_ragged",
                     "xla_exact"):
        assert expected in names
    ok, _ = dispatch.wgrad_availability("pallas_interpret")
    assert ok
    ok, _ = dispatch.wgrad_availability("xla_exact")
    assert ok


def test_wgrad_dispatch_entry_routes_and_defaults_f32():
    x, dy, gs = _inputs([40, 24], 64, 128, 128, seed=17)
    dw = dispatch.grouped_gemm_wgrad(x, dy, gs,
                                     backend="pallas_interpret")
    assert dw.dtype == jnp.float32
    want = dispatch.wgrad_xla_exact(x, dy, gs, num_groups=2)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_wgrad_gemm_only_backend_falls_back_to_auto():
    """padded_baseline exists only in the gemm family — a training config
    pinning it must not strand the backward."""
    x, dy, gs = _inputs([40, 24], 64, 128, 128, seed=19)
    dw = dispatch.grouped_gemm_wgrad(x, dy, gs, backend="padded_baseline")
    assert dw.shape == (2, 128, 128)
    with pytest.raises(ValueError, match="unknown backend"):
        dispatch.grouped_gemm_wgrad(x, dy, gs, backend="no_such_backend")


def test_wgrad_incompatible_dims_fall_back_when_auto():
    """Auto-resolved plan backends with tile shapes that don't divide
    (K, N) fall back to a tile-free entry (the bf16 path calls in with
    arbitrary model dims); an explicit request raises."""
    x = jnp.ones((16, 100), jnp.bfloat16)
    dy = jnp.ones((16, 60), jnp.bfloat16)
    gs = jnp.asarray([10, 6], jnp.int32)
    dw = dispatch.grouped_gemm_wgrad(x, dy, gs)        # must not raise
    assert dw.shape == (2, 100, 60)
    with pytest.raises(ValueError, match="block_k"):
        dispatch.grouped_gemm_wgrad(x, dy, gs, backend="pallas_interpret")


def test_wgrad_explicit_unavailable_raises(monkeypatch):
    from repro import compat
    monkeypatch.setattr(compat, "has_tpu", lambda: False)
    x, dy, gs = _inputs([8], 8, 128, 128)
    with pytest.raises(dispatch.BackendUnavailableError):
        dispatch.grouped_gemm_wgrad(x, dy, gs, backend="pallas")


# ---------------------------------------------------------------------------
# _fp8_bwd through the registry: oracle-pinned over ragged shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sizes,m_buf", [([40, 0, 57], 97),
                                         ([60, 30], 256),
                                         ([0, 0, 64], 128)])
def test_fp8_bwd_wgrad_pinned_to_exact_oracle(sizes, m_buf):
    """The grouped_linear fp8 backward's dw, computed through the wgrad
    registry's kernel, must agree with the xla_exact oracle backend over
    ragged shapes including empty groups and sum(group_sizes) < M."""
    rng = np.random.default_rng(sum(sizes))
    k = n = 128
    x = jnp.asarray(rng.standard_normal((m_buf, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((len(sizes), k, n)), jnp.float32)
    gs = jnp.asarray(sizes, jnp.int32)

    def gw(backend):
        def loss(w):
            y = grouped_linear(x, w, gs, precision="fp8", backend=backend)
            return jnp.sum(y.astype(jnp.float32) ** 2)
        return jax.grad(loss)(w)

    gw_pal = gw("pallas_interpret")
    gw_ora = gw("xla_exact")
    assert bool(jnp.isfinite(gw_pal).all())
    np.testing.assert_allclose(np.asarray(gw_pal), np.asarray(gw_ora),
                               rtol=5e-2, atol=5e-1)


def test_one_tile_plan_serves_forward_dgrad_and_wgrad(monkeypatch):
    """Build-count pin: one grouped_linear fp8 forward+backward on a plan
    backend builds group metadata EXACTLY once — the single TilePlan is
    consumed by the forward GEMM, the dgrad, and the wgrad kernel."""
    rng = np.random.default_rng(23)
    x = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 128, 128)), jnp.float32)
    gs = jnp.asarray([60, 0, 30], jnp.int32)

    calls = []
    inner = plan_mod.make_group_metadata

    def counting(*a, **kw):
        calls.append(a)
        return inner(*a, **kw)

    monkeypatch.setattr(plan_mod, "make_group_metadata", counting)

    def loss(x, w):
        y = grouped_linear(x, w, gs, precision="fp8",
                           backend="pallas_interpret")
        return jnp.sum(y.astype(jnp.float32) ** 2)

    jax.grad(loss, argnums=(0, 1))(x, w)
    assert len(calls) == 1, \
        f"expected one metadata build for fwd+dgrad+wgrad, saw {len(calls)}"


# ---------------------------------------------------------------------------
# Autotuner: wgrad orientation
# ---------------------------------------------------------------------------

def test_autotune_wgrad_caches_under_distinct_key(tmp_path):
    cache = str(tmp_path / "c.json")
    cfg_g = plan_mod.autotune(256, 128, 128, 4, backend="pallas_interpret",
                              cache_path=cache, measure=False)
    cfg_w = plan_mod.autotune(256, 128, 128, 4, backend="pallas_interpret",
                              cache_path=cache, measure=False, op="wgrad")
    entries = plan_mod.load_cache(cache)
    assert len(entries) == 2
    key_w = plan_mod.cache_key(plan_mod._device_kind(), "pallas_interpret",
                               256, 128, 128, 4, op="wgrad")
    assert key_w in entries and entries[key_w]["op"] == "wgrad"
    # and the wgrad entry reloads identically
    plan_mod.clear_cache_memo()
    again = plan_mod.autotune(256, 128, 128, 4, backend="pallas_interpret",
                              cache_path=cache, measure=False, op="wgrad")
    assert again == cfg_w


def test_autotune_wgrad_measures_the_wgrad_dispatch(tmp_path, monkeypatch):
    cache = str(tmp_path / "c.json")
    seen_ops = []
    real = plan_mod._measure_candidate

    def spying(*a, **kw):
        seen_ops.append(kw.get("op", "gemm"))
        return real(*a, iters=1, warmup=0,
                    **{k: v for k, v in kw.items()
                       if k not in ("iters", "warmup")})

    monkeypatch.setattr(plan_mod, "_measure_candidate", spying)
    plan_mod.autotune(256, 128, 128, 4, backend="pallas_interpret",
                      cache_path=cache, max_candidates=1, op="wgrad")
    assert seen_ops and all(op == "wgrad" for op in seen_ops)


def test_autotune_rejects_unknown_op():
    with pytest.raises(ValueError, match="unknown autotune op"):
        plan_mod.autotune(256, 128, 128, 4, op="dgrad")


def test_wgrad_pool_skips_transposability_requirement():
    """wgrad never transposes its output: for (K=128, N=256) the bn=256
    entries are wgrad-legal even though the fwd/dgrad pool rejects them."""
    fwd = plan_mod.candidate_pool(128, 256)
    assert all(c.block_n == 128 for c in fwd)
    wg = plan_mod.candidate_pool(128, 256, require_transposable=False)
    assert any(c.block_n == 256 for c in wg)
