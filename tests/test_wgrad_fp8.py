"""The all-fp8 training step (arXiv 2505.20524): fp8-operand wgrad kernel
vs its dequantize-first oracles over ragged shapes, the precision-aware
wgrad registry (``*_fp8`` twins), quantize-once plumbing (ONE
``quantize_tilewise`` of a shared activation buffer serves the MoE gate+up
forward AND the backward wgrad via the VJP residual), and the
``wgrad_fp8`` autotune family."""
import dataclasses
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import moe as moe_mod
from repro.core import quantization as qz
from repro.core.grouped_gemm import dense_linear_fp8, grouped_linear
from repro.kernels import dispatch, ref
from repro.kernels import plan as plan_mod
from repro.kernels.plan import KernelConfig, make_tile_plan
from repro.kernels.wgrad_kernel import gmm_pallas_wgrad_fp8


def _quantized_inputs(sizes, m_buf, k, n, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m_buf, k)), jnp.float32)
    dy = jnp.asarray(rng.standard_normal((m_buf, n)), jnp.float32)
    x8, sx = ref.quantize_tilewise_ref(x)
    d8, sd = ref.quantize_tilewise_ref(dy)
    return x, dy, x8, sx, d8, sd, jnp.asarray(sizes, jnp.int32)


# ragged, empty groups, sum < M (capacity tails), sub-block groups
CASES = [
    ([128, 128], 256, 128, 128),
    ([100, 0, 37, 163], 300, 256, 256),
    ([60, 30], 256, 128, 128),              # sum=90 << m_buf
    ([1, 1, 1, 1], 64, 128, 256),
    ([0, 0, 512], 512, 128, 384),
    ([5, 250, 3, 127, 129], 600, 384, 128),
    ([0, 0, 0], 128, 128, 128),             # every group empty
]


# ---------------------------------------------------------------------------
# Kernel vs oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sizes,m_buf,k,n", CASES)
def test_fp8_wgrad_kernel_matches_fp8_exact_oracle(sizes, m_buf, k, n):
    """Per-visit dequantization == up-front f32 dequantization, to f32
    rounding: the kernel's masked scale-multiply prologue must reproduce
    the dequantize-then-contract oracle on every ragged shape."""
    _, _, x8, sx, d8, sd, gs = _quantized_inputs(
        sizes, m_buf, k, n, seed=sum(sizes) + m_buf)
    got = gmm_pallas_wgrad_fp8(x8, sx, d8, sd, gs, interpret=True)
    want = dispatch.wgrad_fp8_xla_exact(x8, sx, d8, sd, gs,
                                        num_groups=len(sizes))
    assert got.shape == (len(sizes), k, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("sizes,m_buf,k,n", CASES[:4])
def test_fp8_wgrad_within_quantization_tolerance_of_bf16(sizes, m_buf, k, n):
    """fp8-operand wgrad == bf16-operand wgrad up to fp8 quantization
    noise (the claim of arXiv 2505.20524 this PR imports): relative
    deviation bounded well below what a broken mask/scale would produce."""
    x, dy, x8, sx, d8, sd, gs = _quantized_inputs(sizes, m_buf, k, n,
                                                  seed=3)
    got = gmm_pallas_wgrad_fp8(x8, sx, d8, sd, gs, interpret=True)
    want = dispatch.wgrad_xla_exact(x, dy, gs, num_groups=len(sizes))
    scale = max(float(jnp.abs(want).max()), 1e-6)
    rel = float(jnp.abs(got - want).max()) / scale
    assert rel < 0.08, f"fp8 wgrad deviates {rel:.4f} from bf16/f32 wgrad"


def test_fp8_wgrad_empty_groups_and_tail_garbage():
    """Empty groups come back exactly zero, and garbage (NaN) scales in
    the capacity tail beyond sum(group_sizes) never reach the
    accumulation — the masked prologue zeroes BEFORE the rescale."""
    _, _, x8, sx, d8, sd, gs = _quantized_inputs([60, 0, 30], 256, 128,
                                                 128, seed=5)
    sx = sx.at[90:].set(jnp.nan)
    sd = sd.at[90:].set(jnp.nan)
    dw = gmm_pallas_wgrad_fp8(x8, sx, d8, sd, gs, interpret=True)
    assert bool(jnp.isfinite(dw).all())
    assert float(jnp.abs(dw[1]).max()) == 0.0
    want = dispatch.wgrad_fp8_xla_exact(x8[:90], sx[:90], d8[:90], sd[:90],
                                        gs, num_groups=3)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_fp8_wgrad_precomputed_plan_bitwise_and_scale_shape_checks():
    _, _, x8, sx, d8, sd, gs = _quantized_inputs([100, 0, 37, 163], 300,
                                                 256, 128, seed=7)
    plan = make_tile_plan(gs, 300, block_m=128)
    free = gmm_pallas_wgrad_fp8(x8, sx, d8, sd, gs, interpret=True)
    planned = gmm_pallas_wgrad_fp8(x8, sx, d8, sd, gs, interpret=True,
                                   plan=plan)
    np.testing.assert_array_equal(np.asarray(free), np.asarray(planned))
    with pytest.raises(ValueError, match="s_x must be"):
        gmm_pallas_wgrad_fp8(x8, sx[:, :1], d8, sd, gs, interpret=True)
    with pytest.raises(ValueError, match="s_dy must be"):
        gmm_pallas_wgrad_fp8(x8, sx, d8, sd[:100], gs, interpret=True)


def test_fp8_wgrad_xla_ragged_matches_exact():
    if not dispatch.wgrad_availability("xla_ragged_fp8")[0]:
        pytest.skip("no ragged wgrad in this jax")
    _, _, x8, sx, d8, sd, gs = _quantized_inputs([100, 0, 37, 163], 300,
                                                 256, 256, seed=11)
    got = dispatch.wgrad_fp8_xla_ragged(x8, sx, d8, sd, gs, num_groups=4)
    want = dispatch.wgrad_fp8_xla_exact(x8, sx, d8, sd, gs, num_groups=4)
    # the ragged entry dequantizes to bf16 (portable path); its operand
    # rounding dominates the deviation from the f32-dequant oracle
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=3e-1)


# ---------------------------------------------------------------------------
# Registry: the precision dimension
# ---------------------------------------------------------------------------

def test_wgrad_registry_has_fp8_twins():
    names = dispatch.wgrad_backend_names()
    for expected in ("pallas_fp8", "pallas_interpret_fp8",
                     "xla_ragged_fp8", "xla_exact_fp8"):
        assert expected in names
    ok, _ = dispatch.wgrad_availability("pallas_interpret_fp8")
    assert ok


def test_resolve_wgrad_backend_precision_twins():
    assert dispatch.resolve_wgrad_backend(
        "pallas_interpret", precision="fp8") == "pallas_interpret_fp8"
    # already-suffixed names normalize to the precision actually requested
    assert dispatch.resolve_wgrad_backend(
        "pallas_interpret_fp8", precision="fp8") == "pallas_interpret_fp8"
    assert dispatch.resolve_wgrad_backend(
        "pallas_interpret_fp8", precision="bf16") == "pallas_interpret"
    assert dispatch.resolve_wgrad_backend(
        "xla", precision="fp8") == "xla_ragged_fp8"
    with pytest.raises(ValueError, match="precision"):
        dispatch.resolve_wgrad_backend("pallas", precision="int4")


def test_fp8_wgrad_dispatch_routes_and_defaults_f32():
    _, _, x8, sx, d8, sd, gs = _quantized_inputs([40, 24], 64, 128, 128,
                                                 seed=13)
    dw = dispatch.grouped_gemm_wgrad_fp8(x8, sx, d8, sd, gs,
                                         backend="pallas_interpret")
    assert dw.dtype == jnp.float32
    want = dispatch.wgrad_fp8_xla_exact(x8, sx, d8, sd, gs, num_groups=2)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_fp8_wgrad_gemm_only_backend_falls_back_to_auto():
    _, _, x8, sx, d8, sd, gs = _quantized_inputs([40, 24], 64, 128, 128,
                                                 seed=17)
    dw = dispatch.grouped_gemm_wgrad_fp8(x8, sx, d8, sd, gs,
                                         backend="padded_baseline")
    assert dw.shape == (2, 128, 128)
    with pytest.raises(ValueError, match="unknown backend"):
        dispatch.grouped_gemm_wgrad_fp8(x8, sx, d8, sd, gs,
                                        backend="no_such_backend")


def test_fp8_wgrad_explicit_unavailable_raises(monkeypatch):
    from repro import compat
    monkeypatch.setattr(compat, "has_tpu", lambda: False)
    _, _, x8, sx, d8, sd, gs = _quantized_inputs([8], 8, 128, 128)
    with pytest.raises(dispatch.BackendUnavailableError):
        dispatch.grouped_gemm_wgrad_fp8(x8, sx, d8, sd, gs,
                                        backend="pallas")


def test_fp8_wgrad_incompatible_tiles_fall_back_when_auto():
    """Auto-resolved plan backends whose tile shapes don't divide (K, N)
    fall back to a tile-free fp8 entry; an explicit request raises."""
    _, _, x8, sx, d8, sd, gs = _quantized_inputs([40, 24], 64, 128, 128,
                                                 seed=19)
    cfg = KernelConfig(block_n=256)                 # N=128 not divisible
    dw = dispatch.grouped_gemm_wgrad_fp8(x8, sx, d8, sd, gs, config=cfg)
    assert dw.shape == (2, 128, 128)
    with pytest.raises(ValueError, match="block_n"):
        dispatch.grouped_gemm_wgrad_fp8(
            x8, sx, d8, sd, gs,
            config=cfg.with_(backend="pallas_interpret"))


def test_kernel_config_wgrad_precision_field():
    assert KernelConfig().wgrad_precision == "bf16"
    cfg = KernelConfig(wgrad_precision="fp8")
    assert KernelConfig.from_dict(cfg.to_dict()) == cfg
    # legacy cache entries without the key default to bf16
    d = cfg.to_dict()
    del d["wgrad_precision"]
    assert KernelConfig.from_dict(d).wgrad_precision == "bf16"
    with pytest.raises(ValueError, match="wgrad_precision"):
        KernelConfig(wgrad_precision="int8")


# ---------------------------------------------------------------------------
# grouped_linear: wgrad_precision + quantize-once through the VJP
# ---------------------------------------------------------------------------

def _grad_setup(sizes=(60, 0, 30), m_buf=256, k=128, n=128, seed=29):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m_buf, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((len(sizes), k, n)), jnp.float32)
    return x, w, jnp.asarray(sizes, jnp.int32)


@pytest.mark.parametrize("sizes,m_buf", [([40, 0, 57], 97),
                                         ([60, 30], 256),
                                         ([0, 0, 64], 128)])
def test_grouped_linear_fp8_wgrad_matches_bf16_wgrad(sizes, m_buf):
    """jax.grad through grouped_linear with wgrad_precision='fp8' vs the
    default bf16 wgrad over ragged/empty/tail shapes: identical dx
    (the dgrad path is untouched) and dw within fp8 tolerance."""
    x, w, gs = _grad_setup(sizes, m_buf, seed=sum(sizes))

    def grads(**kw):
        def loss(x, w):
            y = grouped_linear(x, w, gs, precision="fp8",
                               backend="pallas_interpret", **kw)
            return jnp.sum(y.astype(jnp.float32) ** 2)
        return jax.grad(loss, argnums=(0, 1))(x, w)

    gx_bf, gw_bf = grads()
    gx_f8, gw_f8 = grads(wgrad_precision="fp8")
    np.testing.assert_array_equal(np.asarray(gx_bf), np.asarray(gx_f8))
    assert bool(jnp.isfinite(gw_f8).all())
    scale = max(float(jnp.abs(gw_bf).max()), 1e-6)
    rel = float(jnp.abs(gw_f8 - gw_bf).max()) / scale
    assert rel < 0.1, f"fp8 wgrad deviates {rel:.4f}"
    total = sum(sizes)
    assert np.all(np.asarray(gx_f8[total:]) == 0.0)   # tail dx stays zero


def test_grouped_linear_fp8_wgrad_matches_xla_exact_backend():
    x, w, gs = _grad_setup()

    def gw(backend):
        def loss(w):
            y = grouped_linear(x, w, gs, precision="fp8", backend=backend,
                               wgrad_precision="fp8")
            return jnp.sum(y.astype(jnp.float32) ** 2)
        return jax.grad(loss)(w)

    gw_pal = gw("pallas_interpret")
    gw_ora = gw("xla_exact")
    assert float(jnp.abs(gw_pal[1]).max()) == 0.0     # empty group
    np.testing.assert_allclose(np.asarray(gw_pal), np.asarray(gw_ora),
                               rtol=5e-2, atol=5e-1)


def test_fp8_bwd_reuses_forward_quantization(monkeypatch):
    """Quantize-once, VJP leg: with wgrad_precision='fp8' one
    forward+backward performs exactly TWO tilewise quantizations — x once
    (forward; the residual serves the wgrad) and dy once (shared by the
    dgrad and the wgrad's dy side).  Re-quantizing x in the backward
    would make it three."""
    x, w, gs = _grad_setup()
    calls = []
    real = qz.quantize_tilewise
    monkeypatch.setattr(qz, "quantize_tilewise",
                        lambda a, **kw: calls.append(a.shape) or
                        real(a, **kw))

    def loss(x, w):
        y = grouped_linear(x, w, gs, precision="fp8",
                           backend="pallas_interpret",
                           wgrad_precision="fp8")
        return jnp.sum(y.astype(jnp.float32) ** 2)

    jax.grad(loss, argnums=(0, 1))(x, w)
    assert len(calls) == 2, f"expected x-once + dy-once, saw {calls}"


def test_quantized_activation_shared_across_calls(monkeypatch):
    """Quantize-once, layer leg: one QuantizedActivation serves several
    grouped_linear calls bitwise-identically, and gradients still flow."""
    # n != k so the census can tell x-quantizations from dy-quantizations
    x, w, gs = _grad_setup(n=256)
    qa = qz.quantize_activation(x, backend="pallas_interpret")
    y_qa = grouped_linear(x, w, gs, precision="fp8",
                          backend="pallas_interpret", quantized=qa)
    y_plain = grouped_linear(x, w, gs, precision="fp8",
                             backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(y_qa), np.asarray(y_plain))

    calls = []
    real = qz.quantize_tilewise
    monkeypatch.setattr(qz, "quantize_tilewise",
                        lambda a, **kw: calls.append(a.shape) or
                        real(a, **kw))

    def loss(x, w):
        qa = qz.quantize_activation(x, backend="pallas_interpret")
        y1 = grouped_linear(x, w, gs, precision="fp8",
                            backend="pallas_interpret", quantized=qa,
                            wgrad_precision="fp8")
        y2 = grouped_linear(x, w, gs, precision="fp8",
                            backend="pallas_interpret", quantized=qa,
                            wgrad_precision="fp8")
        return jnp.sum((y1 + y2).astype(jnp.float32) ** 2)

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert bool(jnp.isfinite(gx).all()) and bool(jnp.isfinite(gw).all())
    assert float(jnp.linalg.norm(gx)) > 0 and float(jnp.linalg.norm(gw)) > 0
    # x quantized ONCE for both calls; each backward quantizes its dy
    x_like = [s for s in calls if s == x.shape]
    assert len(x_like) == 1, f"shared buffer quantized {len(x_like)}x"
    assert len(calls) == 3, f"expected 1 shared + 2 dy quants, saw {calls}"


def test_one_plan_serves_forward_dgrad_and_fp8_wgrad(monkeypatch):
    """Build-count pin, fp8-wgrad edition: fwd+bwd still builds group
    metadata exactly once — the fp8 wgrad consumes the SAME TilePlan."""
    x, w, gs = _grad_setup()
    calls = []
    inner = plan_mod.make_group_metadata
    monkeypatch.setattr(plan_mod, "make_group_metadata",
                        lambda *a, **kw: calls.append(a) or inner(*a, **kw))

    def loss(x, w):
        y = grouped_linear(x, w, gs, precision="fp8",
                           backend="pallas_interpret",
                           wgrad_precision="fp8")
        return jnp.sum(y.astype(jnp.float32) ** 2)

    jax.grad(loss, argnums=(0, 1))(x, w)
    assert len(calls) == 1, f"expected one metadata build, saw {len(calls)}"


def test_bf16_path_warns_on_fp8_only_kwargs():
    x, w, gs = _grad_setup(sizes=(16, 16), m_buf=32)
    qa = qz.quantize_activation(x)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        grouped_linear(x, w, gs, precision="bf16", quantized=qa)
    assert any("ignores quantized" in str(c.message) for c in caught)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        grouped_linear(x, w, gs, precision="bf16", wgrad_precision="fp8")
    assert any("wgrad_precision" in str(c.message) for c in caught)
    # the config-carried field must not be dropped silently either (the
    # route MoEConfig.kernel_config advertises)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        grouped_linear(x, w, gs, precision="bf16",
                       config=KernelConfig(wgrad_precision="fp8"))
    assert any("wgrad_precision" in str(c.message) for c in caught)


def test_dense_linear_fp8_forwards_out_dtype():
    """REGRESSION: dense_linear_fp8 accepted no out_dtype and the G=1
    wrapper could not pin one — it must forward like grouped_linear."""
    rng = np.random.default_rng(31)
    x = jnp.asarray(rng.standard_normal((32, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    y = dense_linear_fp8(x, w, backend="pallas_interpret",
                         out_dtype=jnp.bfloat16)
    assert y.dtype == jnp.bfloat16
    # config-pinned out_dtype applies too
    cfg = KernelConfig(backend="pallas_interpret", out_dtype=jnp.float32)
    assert dense_linear_fp8(x, w, config=cfg).dtype == jnp.float32
    # and the explicit kwarg wins over the pin
    assert dense_linear_fp8(x, w, config=cfg,
                            out_dtype=jnp.bfloat16).dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# MoE layer: the acceptance count (3 -> 1 quantizations of the shared xs)
# ---------------------------------------------------------------------------

def _moe_cfg(**kw):
    base = dict(num_experts=4, top_k=2, d_model=128, d_ff_expert=256,
                num_shared_experts=0, precision="fp8",
                backend="pallas_interpret",
                kernel_config=KernelConfig(wgrad_precision="fp8"))
    base.update(kw)
    return moe_mod.MoEConfig(**base)


def test_moe_fp8_quantizes_shared_activation_exactly_once(monkeypatch):
    """ACCEPTANCE: one fp8 MoE layer forward+backward performs exactly ONE
    quantize_tilewise of the shared activation buffer (down from three —
    gate fwd + up fwd + backward requant) and ZERO standalone quantizes of
    the down-projection's input h — the fused (act_quant, fp8) epilogue
    produces the down GEMM's QuantizedActivation without a separate
    quantize_tilewise pass.  Total call census: xs once forward, plus one
    dy per GEMM's backward."""
    cfg = _moe_cfg()
    params = moe_mod.init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
    cap = moe_mod._capacity(32 * cfg.top_k, 1, cfg.capacity_factor)

    calls = []
    real = qz.quantize_tilewise
    monkeypatch.setattr(qz, "quantize_tilewise",
                        lambda a, **kw: calls.append(a.shape) or
                        real(a, **kw))

    # forward only: xs once (shared by gate+up); h is fused away entirely
    moe_mod.moe_apply(params, x, cfg)
    assert calls == [(cap, cfg.d_model)], calls

    # forward+backward: + one dy per GEMM backward (down/gate/up); the
    # wgrads reuse the forward residuals — NO extra xs/h quantization
    calls.clear()

    def loss(p, x):
        y, _ = moe_mod.moe_apply(p, x, cfg)
        return jnp.mean(y.astype(jnp.float32) ** 2)

    g = jax.grad(loss, argnums=(0, 1))(params, x)
    xs_like = [s for s in calls if s == (cap, cfg.d_model)]
    # (cap, d_model) twice: the shared xs + the down GEMM's dy (same shape)
    assert len(xs_like) == 2, f"shared-buffer quantizations: {calls}"
    assert len(calls) == 4, f"expected 1 fwd + 3 dy quants, saw {calls}"
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.isfinite(leaf).all())


def test_moe_fp8_wgrad_precision_matches_bf16_layer():
    """The all-fp8 layer's gradients stay within fp8 tolerance of the
    default (bf16-wgrad) layer's."""
    cfg8 = _moe_cfg()
    cfg16 = dataclasses.replace(cfg8, kernel_config=KernelConfig())
    params = moe_mod.init_moe_params(jax.random.PRNGKey(0), cfg8)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg8.d_model))

    def grads(cfg):
        def loss(p):
            y, _ = moe_mod.moe_apply(p, x, cfg)
            return jnp.mean(y.astype(jnp.float32) ** 2)
        return jax.grad(loss)(params)

    g8, g16 = grads(cfg8), grads(cfg16)
    for name in g16:
        a, b = np.asarray(g8[name], np.float32), np.asarray(g16[name],
                                                            np.float32)
        scale = max(np.abs(b).max(), 1e-6)
        assert np.abs(a - b).max() / scale < 0.12, name


# ---------------------------------------------------------------------------
# Autotuner: the wgrad_fp8 family
# ---------------------------------------------------------------------------

def test_autotune_wgrad_fp8_caches_under_distinct_key(tmp_path):
    cache = str(tmp_path / "c.json")
    cfg_w = plan_mod.autotune(256, 128, 128, 4, backend="pallas_interpret",
                              cache_path=cache, measure=False, op="wgrad")
    cfg_f = plan_mod.autotune(256, 128, 128, 4, backend="pallas_interpret",
                              cache_path=cache, measure=False,
                              op="wgrad_fp8")
    assert cfg_f.wgrad_precision == "fp8"
    assert cfg_f.backend == "pallas_interpret"      # family-neutral name
    assert cfg_w.wgrad_precision == "bf16"
    entries = plan_mod.load_cache(cache)
    key_f = plan_mod.cache_key(plan_mod._device_kind(),
                               "pallas_interpret_fp8", 256, 128, 128, 4,
                               op="wgrad_fp8")
    assert key_f in entries and entries[key_f]["op"] == "wgrad_fp8"
    plan_mod.clear_cache_memo()
    again = plan_mod.autotune(256, 128, 128, 4, backend="pallas_interpret",
                              cache_path=cache, measure=False,
                              op="wgrad_fp8")
    assert again == cfg_f


def test_autotune_wgrad_fp8_measures_the_fp8_dispatch(tmp_path, monkeypatch):
    cache = str(tmp_path / "c.json")
    seen = []
    real = plan_mod._measure_candidate

    def spying(*a, **kw):
        seen.append(kw.get("op", "gemm"))
        return real(*a, iters=1, warmup=0,
                    **{k: v for k, v in kw.items()
                       if k not in ("iters", "warmup")})

    monkeypatch.setattr(plan_mod, "_measure_candidate", spying)
    cfg = plan_mod.autotune(256, 128, 128, 4, backend="pallas_interpret",
                            cache_path=cache, max_candidates=1,
                            op="wgrad_fp8")
    assert seen and all(op == "wgrad_fp8" for op in seen)
    assert cfg.wgrad_precision == "fp8"
