"""Pallas grouped-GEMM kernel vs pure-jnp oracle: shape sweeps, ragged
edge cases, and the paper's bitwise-equivalence claim vs the padded
baseline."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref, ops
from repro.kernels.grouped_gemm_kernel import (gmm_pallas,
                                               make_group_metadata,
                                               validate_kernel_config)
from repro.core import padding_baseline as pb


def _quantize_inputs(rng, sizes, k, n):
    g = len(sizes)
    m = int(np.sum(sizes))
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((g, k, n)), jnp.float32)
    a8, sa = ref.quantize_tilewise_ref(a)
    b8, sb = jax.vmap(ref.quantize_blockwise_ref)(b)
    return a8, sa, b8, sb, jnp.asarray(sizes, jnp.int32)


CASES = [
    # (sizes, K, N) — ragged sizes incl. zero groups, single-row groups,
    # exact multiples of block_m, sub-block groups
    ([128, 128], 128, 128),
    ([100, 0, 37, 163], 256, 256),
    ([1, 1, 1, 1], 128, 256),
    ([5, 250, 3, 127, 129], 384, 128),
    ([0, 0, 512], 128, 384),
    ([255], 512, 128),
    ([64] * 8, 256, 128),
]


@pytest.mark.parametrize("sizes,k,n", CASES)
def test_kernel_matches_oracle(sizes, k, n):
    rng = np.random.default_rng(hash((tuple(sizes), k, n)) % 2**32)
    a8, sa, b8, sb, gs = _quantize_inputs(rng, sizes, k, n)
    oracle = ref.grouped_gemm_blockscaled_ref(a8, sa, b8, sb, sizes,
                                              out_dtype=jnp.float32)
    out = gmm_pallas(a8, sa, b8, sb, gs, out_dtype=jnp.float32,
                     interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("block_m", [64, 128, 256])
@pytest.mark.parametrize("block_n", [128, 256])
def test_kernel_block_shape_sweep(block_m, block_n):
    sizes = [97, 31, 0, 200]
    rng = np.random.default_rng(7)
    a8, sa, b8, sb, gs = _quantize_inputs(rng, sizes, 256, 256)
    oracle = ref.grouped_gemm_blockscaled_ref(a8, sa, b8, sb, sizes,
                                              out_dtype=jnp.float32)
    out = gmm_pallas(a8, sa, b8, sb, gs, block_m=block_m, block_n=block_n,
                     out_dtype=jnp.float32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("block_k", [128, 256])
def test_kernel_block_k_sweep(block_k):
    sizes = [130, 126]
    rng = np.random.default_rng(9)
    a8, sa, b8, sb, gs = _quantize_inputs(rng, sizes, 512, 128)
    oracle = ref.grouped_gemm_blockscaled_ref(a8, sa, b8, sb, sizes,
                                              out_dtype=jnp.float32)
    out = gmm_pallas(a8, sa, b8, sb, gs, block_k=block_k,
                     out_dtype=jnp.float32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("out_dtype", [jnp.bfloat16, jnp.float32])
def test_kernel_out_dtypes(out_dtype):
    sizes = [77, 51]
    rng = np.random.default_rng(11)
    a8, sa, b8, sb, gs = _quantize_inputs(rng, sizes, 128, 128)
    oracle = ref.grouped_gemm_blockscaled_ref(a8, sa, b8, sb, sizes,
                                              out_dtype=out_dtype)
    out = gmm_pallas(a8, sa, b8, sb, gs, out_dtype=out_dtype,
                     interpret=True)
    assert out.dtype == oracle.dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(oracle, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_bitwise_equivalence_vs_padded_baseline():
    """Paper §3.2: output of the padding-free kernel is BITWISE identical
    to (pad -> aligned grouped GEMM -> unpad) on the valid rows — the
    central numerical claim."""
    sizes = [100, 0, 37, 163, 129]
    rng = np.random.default_rng(3)
    a8, sa, b8, sb, gs = _quantize_inputs(rng, sizes, 256, 128)

    ours = gmm_pallas(a8, sa, b8, sb, gs, out_dtype=jnp.bfloat16,
                      interpret=True)
    base = pb.grouped_gemm_fp8_padded(a8, sa, b8, sb, gs,
                                      backend="pallas_interpret",
                                      out_dtype=jnp.bfloat16)
    assert np.array_equal(np.asarray(ours, np.float32),
                          np.asarray(base, np.float32)), \
        "padding-free kernel must be bitwise-identical to padded baseline"


@pytest.mark.parametrize("sizes,m_buf", [
    ([60, 30], 256),        # tail spans a partially-owned tile + 1 full tile
    ([100, 0, 37], 512),    # empty group; tail spans several whole tiles
    ([128], 384),           # tail starts exactly on a tile boundary
    ([5], 128),             # single sub-block group
])
def test_unowned_rows_are_exactly_zero(sizes, m_buf):
    """Rows beyond sum(group_sizes) are DEFINED zeros (the schedule's
    padding visits sweep the tail tiles and the masked store zero-fills
    every row no group owns); valid rows stay exactly right.  Pre-fix,
    those rows were uninitialized memory (NaN in interpret mode) and the
    fp8 backward scatter-added them into real token gradients."""
    rng = np.random.default_rng(5)
    g = len(sizes)
    total = int(np.sum(sizes))
    a = jnp.asarray(rng.standard_normal((m_buf, 128)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((g, 128, 128)), jnp.float32)
    a8, sa = ref.quantize_tilewise_ref(a)
    b8, sb = jax.vmap(ref.quantize_blockwise_ref)(b)
    gs = jnp.asarray(sizes, jnp.int32)
    out = gmm_pallas(a8, sa, b8, sb, gs, out_dtype=jnp.float32,
                     interpret=True)
    oracle = ref.grouped_gemm_blockscaled_ref(
        a8[:total], sa[:total], b8, sb, sizes, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out[:total]), np.asarray(oracle),
                               rtol=1e-5, atol=1e-4)
    tail = np.asarray(out[total:])
    assert np.all(tail == 0.0), \
        f"unowned rows must be zero, got {tail[np.nonzero(tail)][:4]}"


def test_group_metadata():
    gs = jnp.array([100, 0, 37, 163], jnp.int32)
    offs, gids, tids = make_group_metadata(gs, 300, 128, 4)
    assert offs.tolist() == [0, 100, 100, 137, 300]
    # group 0 covers tiles 0 (0..127); group 2 covers tile 0? no: rows
    # 100..136 -> tiles 0,1; group 3 rows 137..299 -> tiles 1,2
    real = [(int(g), int(t)) for g, t in zip(gids, tids)]
    # visits: g0:t0 ; g2:t0,t1(row 100-136 spans tile0 only? 100//128=0,
    # ceil(137/128)=2 -> tiles 0,1) ; g3: 137//128=1..ceil(300/128)=3 ->
    # tiles 1,2
    expected_prefix = [(0, 0), (2, 0), (2, 1), (3, 1), (3, 2)]
    assert real[:5] == expected_prefix
    # padding visits replicate the last real visit (idempotent)
    assert all(v == (3, 2) for v in real[5:])


def test_validate_config_rejects_bad_blocks():
    with pytest.raises(ValueError):
        validate_kernel_config(100, 128, 128, 128, 64, 128)   # block_n % 128
    with pytest.raises(ValueError):
        validate_kernel_config(100, 100, 128, 128, 128, 128)  # K % block_k
    with pytest.raises(ValueError):
        validate_kernel_config(100, 128, 100, 128, 128, 128)  # N % block_n


def test_operand_shape_mismatches_raise_value_error():
    """Shape guards survive ``python -O`` (ValueError, not assert)."""
    rng = np.random.default_rng(21)
    a8, sa, b8, sb, gs = _quantize_inputs(rng, [64], 128, 128)
    b8_bad = jnp.zeros((1, 256, 128), b8.dtype)        # K mismatch
    sb_bad = jnp.zeros((1, 2, 1), sb.dtype)
    with pytest.raises(ValueError, match="disagree on K"):
        gmm_pallas(a8, sa, b8_bad, sb_bad, gs, interpret=True)
    sa_bad = jnp.zeros((64, 3), sa.dtype)              # wrong scale cols
    with pytest.raises(ValueError, match="scale columns"):
        gmm_pallas(a8, sa_bad, b8, sb, gs, interpret=True)


def test_xla_backends_match_oracle():
    sizes = [40, 88]
    rng = np.random.default_rng(13)
    a8, sa, b8, sb, gs = _quantize_inputs(rng, sizes, 256, 128)
    oracle = ref.grouped_gemm_blockscaled_ref(a8, sa, b8, sb, sizes,
                                              out_dtype=jnp.float32)
    exact = ops.grouped_gemm_fp8(a8, sa, b8, sb, gs, backend="xla_exact",
                                 out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(exact), np.asarray(oracle))
    # "xla" dequantizes to bf16 before the dot: per-element ~0.4% input
    # rounding accumulates over K=256 -> tolerance scales with sqrt(K)
    fast = ops.grouped_gemm_fp8(a8, sa, b8, sb, gs, backend="xla",
                                out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(oracle),
                               rtol=5e-2, atol=0.35)


def test_quant_kernel_matches_ref():
    rng = np.random.default_rng(17)
    for m, k in [(8, 128), (100, 256), (256, 512), (1, 128)]:
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        q_ref, s_ref = ref.quantize_tilewise_ref(x)
        q_k, s_k = ops.quantize_tilewise(x, backend="pallas_interpret")
        np.testing.assert_array_equal(np.asarray(q_k, np.float32),
                                      np.asarray(q_ref, np.float32))
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                                   rtol=1e-6)
