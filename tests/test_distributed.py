"""Distribution tests on 8 simulated devices (subprocess so the main test
process keeps its single-device jax).

Covers: EP-sharded MoE == single-device reference; sharded train step runs
and matches unsharded loss; dryrun lower/compile on a small mesh.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=900)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def test_moe_ep_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.moe import (MoEConfig, init_moe_params, moe_apply,
                                    shard_moe_params)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = MoEConfig(num_experts=8, top_k=2, d_model=128, d_ff_expert=64,
                        num_shared_experts=1, capacity_factor=8.0)
        params = init_moe_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 32, 128))
        y_ref, _ = moe_apply(params, x.reshape(-1, 128), cfg)
        y_ref = y_ref.reshape(x.shape)
        ep = 4
        pspecs = shard_moe_params(params, cfg, ep)
        xspec = P("data", None, None)
        def local_fn(p, xl):
            rank = jax.lax.axis_index("model")
            b, s, d = xl.shape
            y, _ = moe_apply(p, xl.reshape(b*s, d), cfg, ep_rank=rank,
                             ep_size=ep, axis_name="model")
            return y.reshape(b, s, d)
        from repro.compat import shard_map
        fn = jax.jit(shard_map(local_fn, mesh=mesh,
                               in_specs=(pspecs, xspec),
                               out_specs=xspec, check_vma=False))
        y = fn(params, x)
        err = float(jnp.max(jnp.abs(y - y_ref)))
        assert err < 1e-3, err
        print("EP_OK", err)
    """)
    assert "EP_OK" in out


def test_moe_tp_fallback_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core.moe import (MoEConfig, init_moe_params, moe_apply,
                                    shard_moe_params)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        # 6 experts % 4 != 0 -> TP-on-d_ff fallback (qwen2-moe regime)
        cfg = MoEConfig(num_experts=6, top_k=2, d_model=128, d_ff_expert=64,
                        num_shared_experts=1)
        params = init_moe_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 128))
        y_ref, _ = moe_apply(params, x.reshape(-1, 128), cfg)
        pspecs = shard_moe_params(params, cfg, 1)
        xspec = P("data", None, None)
        def local_fn(p, xl):
            b, s, d = xl.shape
            y, _ = moe_apply(p, xl.reshape(b*s, d), cfg, ep_rank=0,
                             ep_size=1, axis_name="model")
            return y.reshape(b, s, d)
        from repro.compat import shard_map
        fn = jax.jit(shard_map(local_fn, mesh=mesh,
                               in_specs=(pspecs, xspec),
                               out_specs=xspec, check_vma=False))
        y = fn(params, x)
        err = float(jnp.max(jnp.abs(y.reshape(-1, 128) - y_ref)))
        assert err < 1e-3, err
        print("TP_OK", err)
    """)
    assert "TP_OK" in out


def test_sharded_train_step_matches_unsharded():
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import smoke_config
        from repro.distributed import context as dctx
        from repro.distributed.sharding import named_shardings
        from repro.models.model_zoo import make_model, synthetic_batch
        from repro.optim import adamw
        from repro.train.trainer import make_train_step

        cfg = dataclasses.replace(smoke_config("deepseek-moe-16b"),
                                  dtype=jnp.float32)
        model = make_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        batch = synthetic_batch(jax.random.PRNGKey(1), cfg, 64, 8)
        opt_cfg = adamw.OptConfig(use_master=False)
        opt = adamw.init_opt_state(params, opt_cfg)
        step = make_train_step(model.loss, opt_cfg, grad_accum=2)

        # unsharded reference
        _, _, m_ref = jax.jit(step)(params, opt, batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        dctx.set_mesh(mesh)
        pshard = named_shardings(params, mesh, moe_mode="ep")
        params_s = jax.device_put(params, pshard)
        opt_s = adamw.init_opt_state(params_s, opt_cfg)
        _, _, m = jax.jit(step)(params_s, opt_s, batch)
        a, b = float(m_ref["loss"]), float(m["loss"])
        assert abs(a - b) / abs(a) < 2e-2, (a, b)
        print("TRAIN_OK", a, b)
    """)
    assert "TRAIN_OK" in out


def test_dryrun_lowers_on_small_mesh():
    """The dryrun machinery itself (specs, shardings, analyzer) on an
    8-device mesh with a reduced arch — fast end-to-end coverage."""
    out = _run("""
        import jax
        import repro.launch.dryrun as d
        from repro.configs import smoke_config
        import repro.launch.mesh as mesh_mod
        # shrink the production mesh for the test
        mesh_mod.make_production_mesh = \\
            lambda multi_pod=False: jax.make_mesh((2, 2, 2) if multi_pod
                                                  else (4, 2),
                                                  ("pod", "data", "model")
                                                  if multi_pod else
                                                  ("data", "model"))
        d.make_production_mesh = mesh_mod.make_production_mesh
        import repro.configs as C
        real_get = C.get_config
        import repro.launch.dryrun as dd
        dd.get_config = lambda a: smoke_config(a)
        dd.SHAPES = {k: v for k, v in d.SHAPES.items()}
        import dataclasses
        dd.SHAPES["train_4k"] = dataclasses.replace(
            d.SHAPES["train_4k"], seq_len=128, global_batch=8)
        dd.SHAPES["decode_32k"] = dataclasses.replace(
            d.SHAPES["decode_32k"], seq_len=256, global_batch=8)
        for arch in ("deepseek-moe-16b", "recurrentgemma-2b"):
            for shape in ("train_4k", "decode_32k"):
                rec = dd.lower_cell(arch, shape, multi_pod=False)
                assert rec["ok"], rec
                assert rec["cost"]["flops_per_device"] > 0
        rec = dd.lower_cell("qwen3-1.7b", "train_4k", multi_pod=True)
        assert rec["ok"]
        print("DRYRUN_OK")
    """, devices=8)
    assert "DRYRUN_OK" in out
