"""``scripts/bench_diff.py``: regression gate over two BENCH snapshots.

Loaded via importlib (``scripts/`` is deliberately not a package — the
tool must stay a stdlib-only single file so the jax-free CI step can run
it)."""
import importlib.util
import io
import json
import pathlib


_SPEC = importlib.util.spec_from_file_location(
    "bench_diff",
    pathlib.Path(__file__).resolve().parents[1] / "scripts" / "bench_diff.py")
bench_diff = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_diff)


def _snap(tmp_path, name, rows, **meta):
    p = tmp_path / name
    p.write_text(json.dumps({"date": "2026-08-08", "device": "cpu",
                             "rows": rows, **meta}))
    return str(p)


def _row(name, us=None, backend=None, measured=None, **extra):
    row = {"name": name, **extra}
    if us is not None:
        row["us_per_call"] = us
    if backend is not None:
        row["backend"] = backend
    if measured is not None:
        row["measured"] = measured
    return row


def test_pass_within_threshold(tmp_path):
    old = _snap(tmp_path, "old.json",
                [_row("a", 100.0, measured=True),
                 _row("b", 50.0, measured=True)])
    new = _snap(tmp_path, "new.json",
                [_row("a", 105.0, measured=True),
                 _row("b", 40.0, measured=True)])  # improvement
    buf = io.StringIO()
    assert bench_diff.diff(old, new, 0.10, out=buf) == 0
    out = buf.getvalue()
    assert "improved b" in out and "REGRESSION" not in out


def test_regression_detected(tmp_path):
    old = _snap(tmp_path, "old.json", [_row("a", 100.0, measured=True)])
    new = _snap(tmp_path, "new.json", [_row("a", 150.0, measured=True)])
    buf = io.StringIO()
    assert bench_diff.diff(old, new, 0.10, out=buf) == 1
    assert "REGRESSION a" in buf.getvalue()


def test_unmeasured_rows_skipped(tmp_path):
    buf = io.StringIO()
    # derived-only rows (cost-model columns) never fail the diff
    old = _snap(tmp_path, "old.json",
                [_row("a", 100.0, measured=True),
                 _row("d", measured=False, operand_bytes=123)])
    new = _snap(tmp_path, "new.json",
                [_row("a", 101.0, measured=True),
                 _row("d", measured=False, operand_bytes=999)])
    assert bench_diff.diff(old, new, 0.10, out=buf) == 0


def test_backend_change_skipped(tmp_path):
    old = _snap(tmp_path, "old.json",
                [_row("a", 100.0, backend="xla_ragged", measured=True)])
    new = _snap(tmp_path, "new.json",
                [_row("a", 900.0, backend="pallas_interpret", measured=True)])
    buf = io.StringIO()
    assert bench_diff.diff(old, new, 0.10, out=buf) == 0
    assert "SKIP a: backend changed" in buf.getvalue()


def test_pre_protocol_rows_use_time_presence(tmp_path):
    # the 2026-08-08 seed snapshot has no `measured`/`backend` keys: any
    # row carrying us_per_call must still be compared
    old = _snap(tmp_path, "old.json", [_row("a", 100.0), _row("d")])
    new = _snap(tmp_path, "new.json",
                [_row("a", 150.0, measured=True), _row("d", measured=False)])
    assert bench_diff.diff(old, new, 0.10, out=io.StringIO()) == 1


def test_disjoint_names_pass(tmp_path):
    old = _snap(tmp_path, "old.json", [_row("gone", 10.0, measured=True)])
    new = _snap(tmp_path, "new.json", [_row("fresh", 10.0, measured=True)])
    assert bench_diff.diff(old, new, 0.10, out=io.StringIO()) == 0
