"""Unit tests for the loop-aware HLO analyzer that backs §Roofline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_flops_scaled_by_trip_count():
    n, trips = 256, 8

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=trips)
        return out

    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    w = jax.ShapeDtypeStruct((n, n), jnp.float32)
    res = analyze(_hlo(f, x, w))
    expected = trips * 2 * n ** 3
    assert abs(res["dot_flops"] - expected) / expected < 0.01, \
        (res["dot_flops"], expected)


def test_nested_scan_multiplies():
    n, t1, t2 = 128, 4, 6

    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=t2)
            return ci, None
        out, _ = jax.lax.scan(outer, x, None, length=t1)
        return out

    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    w = jax.ShapeDtypeStruct((n, n), jnp.float32)
    res = analyze(_hlo(f, x, w))
    expected = t1 * t2 * 2 * n ** 3
    assert abs(res["dot_flops"] - expected) / expected < 0.01


def test_dot_flops_batched_contraction():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)
    a = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    res = analyze(_hlo(f, a, b))
    expected = 2 * 4 * 64 * 16 * 32
    assert abs(res["dot_flops"] - expected) / expected < 0.01


def test_conditional_branches_expectation_weighted():
    n = 128

    def f(x, w):
        def body(c, i):
            c = jax.lax.cond(i % 2 == 0, lambda z: z @ w, lambda z: z, c)
            return c, None
        out, _ = jax.lax.scan(body, x, jnp.arange(8))
        return out

    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    w = jax.ShapeDtypeStruct((n, n), jnp.float32)
    res = analyze(_hlo(f, x, w))
    # 8 iterations x expected 0.5 branch weight = 4 matmuls expected
    expected = 4 * 2 * n ** 3
    assert abs(res["dot_flops"] - expected) / expected < 0.01


def test_hbm_fused_leq_unfused():
    def f(x):
        return jnp.tanh(x * 2.0 + 1.0) * x
    x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    res = analyze(_hlo(f, x))
    assert 0 < res["hbm_bytes_fused"] <= res["hbm_bytes"]
