"""Elastic scaling: a checkpoint saved under one mesh restores onto a
different (smaller) mesh — the restart path after losing nodes.

Checkpoints store full logical arrays; shardings are re-derived from the
logical partition rules for whatever mesh the surviving devices form
(launch/mesh.py:make_mesh_for), so resharding is free at restore time.
"""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=900)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def test_checkpoint_reshards_onto_smaller_mesh(tmp_path):
    out = _run(f"""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint import checkpointer as ckpt
        from repro.configs import smoke_config
        from repro.distributed import context as dctx
        from repro.distributed.sharding import named_shardings
        from repro.launch.mesh import make_mesh_for
        from repro.models.model_zoo import make_model, synthetic_batch

        cfg = dataclasses.replace(smoke_config("qwen3-1.7b"),
                                  dtype=jnp.float32)
        model = make_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))

        # "before failure": 8 devices, (4 data, 2 model)
        mesh8 = make_mesh_for(8, model_parallel=2)
        p8 = jax.device_put(params, named_shardings(params, mesh8))
        ckpt.save({str(tmp_path)!r}, 7, {{"params": p8}})

        # "after failure": 4 surviving devices, (2 data, 2 model)
        mesh4 = jax.make_mesh((2, 2), ("data", "model"),
                              devices=jax.devices()[:4])
        like = jax.tree.map(
            lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                              sharding=s),
            params, named_shardings(params, mesh4))
        restored, meta, step = ckpt.restore_latest(
            {str(tmp_path)!r}, {{"params": like}})
        assert step == 7
        # values identical, shardings re-derived for the new mesh
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        devs = {{d for leaf in jax.tree.leaves(restored["params"])
                for d in leaf.sharding.device_set}}
        assert len(devs) <= 4
        # and the restored params still run a forward pass on the new mesh
        dctx.set_mesh(mesh4)
        batch = synthetic_batch(jax.random.PRNGKey(1), cfg, 64, 4)
        loss, _ = jax.jit(model.loss)(restored["params"], batch)
        assert bool(jnp.isfinite(loss))
        print("ELASTIC_OK", float(loss))
    """)
    assert "ELASTIC_OK" in out
