"""Plan-aware serving decode path.

The Engine resolves a decode-specialized ``block_m<=16`` KernelConfig
exactly ONCE at construction (the decode pool, ``op="decode"``), pins
separate prefill/decode configs over one param tree, and a full generate
builds plan metadata exactly once per phase — the decode loop's traced
plan is replayed for every step.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.kernels import plan as plan_mod
from repro.kernels.plan import DECODE_POOL, KernelConfig
from repro.models import model_zoo
from repro.models.model_zoo import make_model, synthetic_batch
from repro.serve.engine import Engine


@pytest.fixture(scope="module")
def moe_model():
    cfg = dataclasses.replace(smoke_config("qwen2-moe-a2.7b"),
                              precision="fp8",
                              gemm_backend="pallas_interpret")
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params


def test_decode_config_resolved_once_per_engine(moe_model, monkeypatch,
                                                tmp_path):
    model, params = moe_model
    monkeypatch.setenv("REPRO_TILEPLAN_CACHE", str(tmp_path / "c.json"))
    selections = []
    real = plan_mod.decode_config
    monkeypatch.setattr(plan_mod, "decode_config",
                        lambda *a, **kw: selections.append(a) or
                        real(*a, **kw))
    engine = Engine(model, params, max_new_tokens=6, decode_batch_size=2)
    assert len(selections) == 1, "one decode selection per engine"
    assert engine.decode_config is not None
    assert engine.decode_config.block_m <= 16
    # prefill keeps its own (non-decode) geometry
    pf = engine.prefill_config
    assert pf is None or pf.block_m > 16
    # ...and a second generate-sized workload does not re-select
    batch = synthetic_batch(jax.random.PRNGKey(1), model.cfg, 16, 2)
    engine.generate(batch, key=jax.random.PRNGKey(2))
    assert len(selections) == 1


def test_generate_builds_one_plan_per_phase(moe_model, monkeypatch,
                                            tmp_path):
    """prefill + >=4 decode steps = exactly FOUR metadata builds: per
    phase trace, one for the routed experts and one for the shared-expert
    FFN's G=1 plan (the shared experts run fp8 since the precision
    bugfix, with their own plan-once group structure); the decode loop's
    scanned body replays its pair on every step without rebuilding."""
    model, params = moe_model
    monkeypatch.setenv("REPRO_TILEPLAN_CACHE", str(tmp_path / "c.json"))
    engine = Engine(model, params, max_new_tokens=6, decode_batch_size=2)
    builds = []
    inner = plan_mod.make_group_metadata
    monkeypatch.setattr(plan_mod, "make_group_metadata",
                        lambda *a, **kw: builds.append(a) or inner(*a, **kw))
    batch = synthetic_batch(jax.random.PRNGKey(1), model.cfg, 16, 2)
    res = engine.generate(batch, key=jax.random.PRNGKey(42))
    assert res.tokens.shape == (2, 6)            # 1 prefill + 5 decode
    assert len(builds) == 4, \
        f"two plan builds per phase (routed + shared), saw {len(builds)}"
    # per phase: one routed build (G=num_experts) + one shared G=1 build
    assert [b[3] for b in builds] == [model.cfg.moe.num_experts, 1,
                                      model.cfg.moe.num_experts, 1]
    # the decode phase's routed build runs under the decode-specialized
    # tiling
    assert int(builds[2][2]) == engine.decode_config.block_m


def test_explicit_decode_config_skips_selection(moe_model, monkeypatch):
    model, params = moe_model
    monkeypatch.setattr(plan_mod, "decode_config",
                        lambda *a, **kw: pytest.fail("selection ran"))
    pinned = KernelConfig(block_m=16, backend="pallas_interpret")
    engine = Engine(model, params, decode_kernel_config=pinned)
    assert engine.decode_config == pinned


def test_non_moe_model_has_no_decode_config(monkeypatch):
    monkeypatch.setattr(plan_mod, "decode_config",
                        lambda *a, **kw: pytest.fail("selection ran"))
    cfg = smoke_config("qwen3-1.7b")
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = Engine(model, params, max_new_tokens=2)
    assert engine.decode_config is None
    assert engine._decode_model is engine.model
    batch = synthetic_batch(jax.random.PRNGKey(1), cfg, 8, 1)
    assert engine.generate(batch).tokens.shape == (1, 2)


def test_decode_config_inherits_run_config_fields(moe_model, tmp_path,
                                                  monkeypatch):
    """The decode selection replaces tile geometry ONLY — backend,
    out_dtype, and wgrad_precision of a pinned run config survive."""
    model, params = moe_model
    monkeypatch.setenv("REPRO_TILEPLAN_CACHE", str(tmp_path / "c.json"))
    pinned = KernelConfig(block_m=256, backend="pallas_interpret",
                          out_dtype=jnp.float32)
    engine = Engine(model, params, kernel_config=pinned,
                    decode_batch_size=2)
    dc = engine.decode_config
    assert dc.block_m <= 16
    assert dc.backend == "pallas_interpret"
    assert dc.out_dtype == jnp.float32
    assert engine.prefill_config == pinned


def test_decode_autotune_uses_decode_pool_and_key(tmp_path):
    cache = str(tmp_path / "c.json")
    cfg = plan_mod.decode_config(16, 128, 128, 4,
                                 backend="pallas_interpret",
                                 cache_path=cache)
    assert cfg.block_m in {c.block_m for c in DECODE_POOL}
    entries = plan_mod.load_cache(cache)
    key = plan_mod.cache_key(plan_mod._device_kind(), "pallas_interpret",
                             16, 128, 128, 4, op="decode")
    assert key in entries and entries[key]["op"] == "decode"
    # distinct from a generic gemm tune of the same shape class
    plan_mod.autotune(16, 128, 128, 4, backend="pallas_interpret",
                      cache_path=cache, measure=False)
    assert len(plan_mod.load_cache(cache)) == 2


def test_decode_entries_never_rank_at_training_shapes():
    """The MXU-occupancy cost term confines block_m=8/16 to tiny M: at a
    training shape the ranked-first candidate keeps a full tile."""
    spec = plan_mod.device_spec("cpu")
    cands = plan_mod.candidate_pool(512, 512)
    best = min(cands, key=lambda c: plan_mod.estimate_cost_s(
        8192, 512, 512, 16, c, spec))
    assert best.block_m >= 64, best
    tiny = min(cands, key=lambda c: plan_mod.estimate_cost_s(
        8, 512, 512, 4, c, spec))
    assert tiny.block_m <= 16, tiny


def test_with_kernel_config_is_noop_on_match(moe_model):
    model, _ = moe_model
    assert model_zoo.with_kernel_config(model, model.cfg.kernel_config) \
        is model
    pinned = KernelConfig(block_m=16)
    rebuilt = model_zoo.with_kernel_config(model, pinned)
    assert rebuilt is not model
    assert rebuilt.cfg.kernel_config == pinned


def test_decode_output_matches_default_tiling(moe_model, tmp_path,
                                              monkeypatch):
    """Decode-specialized tiles are pure scheduling: greedy decode
    produces the same tokens as an engine pinned to the training
    geometry (same kernel arithmetic, different tile walk)."""
    model, params = moe_model
    monkeypatch.setenv("REPRO_TILEPLAN_CACHE", str(tmp_path / "c.json"))
    batch = synthetic_batch(jax.random.PRNGKey(1), model.cfg, 16, 2)
    fast = Engine(model, params, max_new_tokens=4, decode_batch_size=2)
    ref = Engine(model, params, max_new_tokens=4,
                 decode_kernel_config=KernelConfig(
                     backend="pallas_interpret"))
    t_fast = fast.generate(batch, key=jax.random.PRNGKey(7)).tokens
    t_ref = ref.generate(batch, key=jax.random.PRNGKey(7)).tokens
    np.testing.assert_array_equal(np.asarray(t_fast), np.asarray(t_ref))
