"""Fused activation->quantize epilogue: the ``(act_quant, fp8)`` family.

The tentpole seam of the fused-epilogue PR: ``silu(g)*u`` (or ``gelu(g)``)
and its 1x128 fp8 quantization run as ONE kernel pass, so the bf16 ``h``
intermediate never exists as a standalone array on the fp8 hot path.
Covers the kernel vs its oracles, the registry family's resolution
semantics, the :class:`QuantizedActivation` producer, the fused
grouped-linear custom VJP (value and grad parity vs the unfused pair in
both wgrad precisions), the whisper gelu variant, the shared-expert
precision bugfix, and the ``op="act_quant"`` autotune family.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import moe as moe_mod
from repro.core import quantization as qz
from repro.core.grouped_gemm import (dense_linear_fp8, dense_linear_fp8_fused,
                                     grouped_linear, grouped_linear_fused)
from repro.core.moe import MoEConfig, init_moe_params, moe_apply
from repro.kernels import dispatch, ref
from repro.kernels import plan as plan_mod
from repro.kernels.epilogue_kernel import (ACTIVATIONS, _act_f32,
                                           act_quantize_pallas)
from repro.kernels.plan import KernelConfig, make_tile_plan
from repro.kernels.quant_kernel import quantize_tilewise_pallas


def _operands(m, k, act, seed=0):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    u = (jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
         if act == "silu_mul" else None)
    return g, u


# ---------------------------------------------------------------------------
# Kernel vs oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("act", ACTIVATIONS)
@pytest.mark.parametrize("m,k", [(64, 128), (200, 256), (7, 128)])
def test_fused_kernel_bitwise_vs_jitted_composition(act, m, k):
    """The fused pass is bitwise identical (payload AND scales) to the
    jitted unfused composition: activation, then the existing tilewise
    quantize kernel.  Ragged/odd M exercises the tail program."""
    g, u = _operands(m, k, act, seed=m + k)
    q8, s = act_quantize_pallas(g, u, act=act, interpret=True)
    h = jax.jit(lambda *a: _act_f32(*a, act))(g, u)
    q8_c, s_c = quantize_tilewise_pallas(h, interpret=True)
    np.testing.assert_array_equal(np.asarray(q8, jnp.float32),
                                  np.asarray(q8_c, jnp.float32))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_c))
    assert q8.dtype == jnp.float8_e4m3fn and s.shape == (m, k // 128)


@pytest.mark.parametrize("act", ACTIVATIONS)
def test_fused_kernel_matches_ref(act):
    """vs the eager reference: payload bitwise, scales allclose (the
    jitted ``amax/448`` division can differ from eager by one f32 ulp —
    the same property the standalone quantize kernel has vs its ref)."""
    g, u = _operands(96, 256, act, seed=3)
    q8, s = act_quantize_pallas(g, u, act=act, interpret=True)
    qr, sr = ref.act_quantize_ref(g, u, act)
    np.testing.assert_array_equal(np.asarray(q8, jnp.float32),
                                  np.asarray(qr, jnp.float32))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


def test_fused_kernel_validates_operands():
    g, u = _operands(16, 128, "silu_mul")
    with pytest.raises(ValueError):
        act_quantize_pallas(g, None, act="silu_mul", interpret=True)
    with pytest.raises(ValueError):
        act_quantize_pallas(g, u, act="gelu", interpret=True)
    with pytest.raises(ValueError):
        act_quantize_pallas(g, u, act="tanh_mul", interpret=True)
    with pytest.raises(ValueError):
        act_quantize_pallas(g[:, :100], u[:, :100], interpret=True)


# ---------------------------------------------------------------------------
# Registry family
# ---------------------------------------------------------------------------

def test_act_quant_family_registered():
    key = dispatch.OpKey("act_quant", "fp8")
    assert key in dispatch._OPERATORS
    names = set(dispatch._OPERATORS[key])
    assert {"pallas", "pallas_interpret", "xla_ragged", "xla_exact",
            "padded_baseline", "ref"} <= names
    row = dispatch.backend_matrix(key)
    assert row, "backend_matrix must report the act_quant family"


def test_act_quantize_dispatch_and_fallback_semantics(monkeypatch):
    """Auto-resolution failure serves the unfused reference (a fused
    epilogue is an optimization, never a refusal); an explicitly
    requested unavailable backend still raises."""
    g, u = _operands(8, 128, "silu_mul")
    q8, s = dispatch.act_quantize(g, u, backend="pallas_interpret")
    qr, sr = ref.act_quantize_ref(g, u, "silu_mul")
    np.testing.assert_array_equal(np.asarray(q8, jnp.float32),
                                  np.asarray(qr, jnp.float32))
    from repro import compat
    monkeypatch.setattr(compat, "has_tpu", lambda: False)
    with pytest.raises(dispatch.BackendUnavailableError):
        dispatch.act_quantize(g, u, backend="pallas")
    dispatch.set_default_backend("pallas")      # unavailable here
    try:
        dispatch.act_quantize(g, u)             # must not raise
    finally:
        dispatch.set_default_backend(None)


def test_fused_act_quantize_is_a_quantized_activation():
    """core producer == quantize_activation of the materialized h (same
    jitted-composition contract the kernel is pinned to)."""
    g, u = _operands(64, 128, "silu_mul", seed=11)
    qa = qz.fused_act_quantize(g, u, backend="pallas_interpret")
    assert isinstance(qa, qz.QuantizedActivation)
    h = jax.jit(lambda a, b: _act_f32(a, b, "silu_mul"))(g, u)
    want = qz.quantize_activation(h, backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(qa.q, jnp.float32),
                                  np.asarray(want.q, jnp.float32))
    np.testing.assert_array_equal(np.asarray(qa.scale),
                                  np.asarray(want.scale))


# ---------------------------------------------------------------------------
# Fused grouped linear: value + grad parity, zero standalone h quantizes
# ---------------------------------------------------------------------------

def _fused_vs_unfused(wgrad_precision):
    sizes, m_buf, k, n = [60, 0, 130], 256, 128, 128
    rng = np.random.default_rng(17)
    g = jnp.asarray(rng.standard_normal((m_buf, k)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((m_buf, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((len(sizes), k, n)), jnp.float32)
    gs = jnp.asarray(sizes, jnp.int32)
    cfg = KernelConfig(backend="pallas_interpret",
                       wgrad_precision=wgrad_precision)

    def fused(g, u, w):
        y = grouped_linear_fused(g, u, w, gs, config=cfg)
        return jnp.sum(y.astype(jnp.float32) ** 2), y

    def unfused(g, u, w):
        h = _act_f32(g, u, "silu_mul")
        y = grouped_linear(h, w, gs, precision="fp8", config=cfg)
        return jnp.sum(y.astype(jnp.float32) ** 2), y

    (lf, yf), gradf = jax.value_and_grad(fused, (0, 1, 2),
                                         has_aux=True)(g, u, w)
    (lu, yu), gradu = jax.value_and_grad(unfused, (0, 1, 2),
                                         has_aux=True)(g, u, w)
    return (lf, yf, gradf), (lu, yu, gradu)


@pytest.mark.parametrize("wgrad_precision", ["bf16", "fp8"])
def test_grouped_linear_fused_matches_unfused(wgrad_precision):
    """Values AND jax.grad of the fused path match the unfused
    ``h = silu(g)*u; grouped_linear(h)`` pair in both wgrad modes."""
    (lf, yf, gradf), (lu, yu, gradu) = _fused_vs_unfused(wgrad_precision)
    np.testing.assert_array_equal(np.asarray(yf, jnp.float32),
                                  np.asarray(yu, jnp.float32))
    assert float(lf) == float(lu)
    for df, du_, name in zip(gradf, gradu, ("dg", "du", "dw")):
        np.testing.assert_array_equal(np.asarray(df, jnp.float32),
                                      np.asarray(du_, jnp.float32),
                                      err_msg=name)


def test_grouped_linear_fused_tail_rows_zero():
    sizes, m_buf = [40, 24], 128
    g, u = _operands(m_buf, 128, "silu_mul", seed=5)
    w = jnp.asarray(np.random.default_rng(6).standard_normal((2, 128, 128)),
                    jnp.float32)
    y = grouped_linear_fused(g, u, w, jnp.asarray(sizes, jnp.int32),
                             backend="pallas_interpret")
    assert not np.any(np.asarray(y[sum(sizes):], jnp.float32))
    assert np.any(np.asarray(y[:sum(sizes)], jnp.float32))


def test_grouped_linear_fused_never_quantizes_h_standalone(monkeypatch):
    """The whole point of the seam: forward+backward of the fused path
    performs ZERO standalone ``quantize_tilewise`` calls on h — the only
    tilewise quantize is the backward's dy (wgrad_precision='fp8' reuses
    the fused pass's q/scales as the wgrad residual)."""
    calls = []
    inner = qz.quantize_tilewise

    def counting(x, **kw):
        calls.append(x.shape)
        return inner(x, **kw)

    monkeypatch.setattr(qz, "quantize_tilewise", counting)
    g, u = _operands(64, 128, "silu_mul", seed=9)
    w = jnp.asarray(np.random.default_rng(9).standard_normal((2, 128, 256)),
                    jnp.float32)
    gs = jnp.asarray([30, 34], jnp.int32)
    cfg = KernelConfig(backend="pallas_interpret", wgrad_precision="fp8")

    def loss(g, u, w):
        y = grouped_linear_fused(g, u, w, gs, config=cfg)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    jax.grad(loss, (0, 1, 2))(g, u, w)
    assert calls == [(64, 256)], \
        f"expected exactly one quantize (dy), saw {calls}"


def test_grouped_linear_fused_validates_activation():
    g, u = _operands(16, 128, "silu_mul")
    w = jnp.zeros((1, 128, 128))
    gs = jnp.asarray([16], jnp.int32)
    with pytest.raises(ValueError):
        grouped_linear_fused(g, None, w, gs, backend="pallas_interpret")
    with pytest.raises(ValueError):
        grouped_linear_fused(g, u, w, gs, act="gelu",
                             backend="pallas_interpret")
    with pytest.raises(ValueError):
        grouped_linear_fused(g, u, w, gs, act="relu",
                             backend="pallas_interpret")


# ---------------------------------------------------------------------------
# Satellite: whisper gelu variant on whisper-tiny MLP dims
# ---------------------------------------------------------------------------

def test_gelu_epilogue_whisper_tiny_mlp_dims():
    """Unary gelu epilogue at whisper-tiny geometry (d_model=384,
    d_ff=1536): the fused down projection matches the unfused
    quantize-then-GEMM of the materialized gelu activation."""
    d_model, d_ff = 384, 1536
    rng = np.random.default_rng(23)
    up = jnp.asarray(rng.standard_normal((8, 10, d_ff)), jnp.float32)
    w_down = jnp.asarray(rng.standard_normal((d_ff, d_model)) * 0.02,
                         jnp.float32)
    y = dense_linear_fp8_fused(up, None, w_down, act="gelu",
                               backend="pallas_interpret")
    h = jax.jit(lambda a: _act_f32(a.reshape(-1, d_ff), None, "gelu"))(up)
    want = dense_linear_fp8(h, w_down, backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(y, jnp.float32),
                                  np.asarray(want, jnp.float32)
                                  .reshape(8, 10, d_model))


# ---------------------------------------------------------------------------
# Satellite/bugfix: shared-expert FFN honors cfg.precision
# ---------------------------------------------------------------------------

def _shared_cfg(**kw):
    base = dict(num_experts=4, top_k=2, d_model=128, d_ff_expert=128,
                num_shared_experts=1, precision="fp8",
                backend="pallas_interpret")
    base.update(kw)
    return MoEConfig(**base)


def test_shared_expert_ffn_runs_fp8(monkeypatch):
    """Regression for the precision bug: under precision='fp8' the
    shared-expert FFN must route through the fp8 dense path (gate/up via
    dense_linear_fp8 + fused silu·mul down projection), not silently
    stay a bf16 einsum."""
    cfg = _shared_cfg()
    params = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
    dense_calls, fused_calls = [], []
    real_d, real_f = moe_mod.dense_linear_fp8, moe_mod.dense_linear_fp8_fused
    monkeypatch.setattr(moe_mod, "dense_linear_fp8",
                        lambda *a, **kw: dense_calls.append(a[1].shape)
                        or real_d(*a, **kw))
    monkeypatch.setattr(moe_mod, "dense_linear_fp8_fused",
                        lambda *a, **kw: fused_calls.append(a[2].shape)
                        or real_f(*a, **kw))
    y, _ = moe_apply(params, x, cfg)
    assert len(dense_calls) == 2, "shared gate+up through the fp8 path"
    assert len(fused_calls) == 1, "shared down through the fused epilogue"
    assert np.all(np.isfinite(np.asarray(y, jnp.float32)))


def test_shared_expert_ffn_stays_bf16_without_fp8(monkeypatch):
    cfg = _shared_cfg(precision="bf16", backend=None)
    params = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
    monkeypatch.setattr(moe_mod, "dense_linear_fp8",
                        lambda *a, **kw: pytest.fail("fp8 path ran"))
    monkeypatch.setattr(moe_mod, "dense_linear_fp8_fused",
                        lambda *a, **kw: pytest.fail("fused path ran"))
    y, _ = moe_apply(params, x, cfg)
    assert np.all(np.isfinite(np.asarray(y, jnp.float32)))


def test_shared_expert_fp8_changes_numerics_vs_bf16():
    """The bugfix is observable: shared-expert outputs now carry fp8
    quantization noise relative to the bf16 shared path (previously
    identical because precision was ignored)."""
    cfg8 = _shared_cfg()
    cfg16 = _shared_cfg(precision="bf16", backend=None)
    params = init_moe_params(jax.random.PRNGKey(0), cfg8)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg8.d_model))
    y8, _ = moe_apply(params, x, cfg8)
    y16, _ = moe_apply(params, x, cfg16)
    diff = np.abs(np.asarray(y8, np.float32) - np.asarray(y16, np.float32))
    scale = np.abs(np.asarray(y16, np.float32)).max()
    assert 0 < diff.max() < 0.2 * max(scale, 1.0), \
        "fp8 shared path: nonzero but bounded quantization noise"


# ---------------------------------------------------------------------------
# Satellite: op="act_quant" autotune family
# ---------------------------------------------------------------------------

def test_autotune_act_quant_caches_under_distinct_key(tmp_path):
    cache = str(tmp_path / "c.json")
    cfg = plan_mod.autotune(512, 256, 0, 0, backend="pallas_interpret",
                            cache_path=cache, measure=False,
                            op="act_quant")
    assert cfg.backend == "pallas_interpret"
    key = plan_mod.cache_key(plan_mod._device_kind(), "pallas_interpret",
                             512, 256, 0, 0, op="act_quant")
    entries = plan_mod.load_cache(cache)
    assert key in entries and entries[key]["op"] == "act_quant"
    # distinct from the standalone quantizer's family at the same shape
    plan_mod.autotune(512, 256, 0, 0, backend="pallas_interpret",
                      cache_path=cache, measure=False, op="quantize")
    assert len(plan_mod.load_cache(cache)) == 2
    plan_mod.clear_cache_memo()
    again = plan_mod.autotune(512, 256, 0, 0, backend="pallas_interpret",
                              cache_path=cache, measure=False,
                              op="act_quant")
    assert again == cfg


def test_autotune_act_quant_dedupes_tile_heights(tmp_path):
    """Like the quantizer, the epilogue only varies in tile height —
    pool entries differing in (block_n, block_k) are one candidate."""
    cache = str(tmp_path / "c.json")
    plan_mod.autotune(256, 128, 0, 0, backend="pallas_interpret",
                      cache_path=cache, measure=False, op="act_quant")
    (entry,) = plan_mod.load_cache(cache).values()
    pool_heights = {c.block_m for c in plan_mod.CONFIG_POOL}
    assert entry["pool_size"] == len(pool_heights)


def test_autotune_act_quant_measures_the_fused_dispatch(tmp_path,
                                                       monkeypatch):
    cache = str(tmp_path / "c.json")
    seen = []
    real = plan_mod._measure_candidate

    def spying(*a, **kw):
        seen.append(kw.get("op", "gemm"))
        return real(*a, iters=1, warmup=0,
                    **{k: v for k, v in kw.items()
                       if k not in ("iters", "warmup")})

    monkeypatch.setattr(plan_mod, "_measure_candidate", spying)
    plan_mod.autotune(256, 128, 0, 0, backend="pallas_interpret",
                      cache_path=cache, max_candidates=2, op="act_quant")
    assert seen and all(op == "act_quant" for op in seen)
