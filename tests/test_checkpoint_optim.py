"""Checkpointing (atomicity, resume, GC) and optimizer behaviour."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ckpt
from repro.optim import adamw


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.float32)}}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    ckpt.save(d, 5, tree)
    assert ckpt.latest_step(d) == 5
    restored, meta = ckpt.restore(d, 5, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gc_keeps_last(tmp_path):
    d = str(tmp_path)
    for s in range(6):
        ckpt.save(d, s, _tree(s), keep_last=2)
    steps = sorted(ckpt.all_steps(d))
    assert steps == [4, 5]
    assert ckpt.latest_step(d) == 5


def test_torn_latest_falls_back_to_scan(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 3, _tree())
    with open(os.path.join(d, "latest"), "w") as f:
        f.write("99")               # pointer to a nonexistent step
    assert ckpt.latest_step(d) == 3


def test_orphan_tmp_dir_ignored(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    os.makedirs(os.path.join(d, ".tmp_step_2"))   # simulated crash
    assert ckpt.latest_step(d) == 1
    restored, _, s = ckpt.restore_latest(d, _tree())
    assert s == 1


def test_adamw_converges_quadratic():
    cfg = adamw.OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=200, use_master=False, clip_norm=10.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init_opt_state(params, cfg)
    target = jnp.array([1.0, 2.0])
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw.apply_updates(params, g, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_adamw_master_weights_bf16():
    """bf16 params + f32 master: tiny updates must not be lost to bf16
    rounding (the master accumulates them)."""
    cfg = adamw.OptConfig(lr=1e-4, weight_decay=0.0, warmup_steps=0,
                          total_steps=1000, use_master=True, clip_norm=1e9)
    params = {"w": jnp.ones((4,), jnp.bfloat16) * 100.0}
    state = adamw.init_opt_state(params, cfg)
    for _ in range(50):
        g = {"w": jnp.ones((4,), jnp.float32)}
        params, state, _ = adamw.apply_updates(params, g, state, cfg)
    # master moved by ~50 * 1e-4 * 1 = 5e-3 even though each step is
    # below bf16 resolution at magnitude 100
    assert float(state["master"]["w"][0]) < 100.0 - 2e-3


def test_gradient_compression_error_feedback():
    """int8 + error feedback must track the uncompressed trajectory."""
    base = adamw.OptConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                           total_steps=300, use_master=False,
                           clip_norm=1e9)
    comp = adamw.OptConfig(**{**base.__dict__, "compress_grads": True})
    p1 = {"w": jnp.array([5.0, -3.0, 2.0])}
    p2 = {"w": jnp.array([5.0, -3.0, 2.0])}
    s1 = adamw.init_opt_state(p1, base)
    s2 = adamw.init_opt_state(p2, comp)
    target = jnp.array([1.0, 2.0, -1.0])
    for _ in range(300):
        g1 = {"w": 2 * (p1["w"] - target)}
        g2 = {"w": 2 * (p2["w"] - target)}
        p1, s1, _ = adamw.apply_updates(p1, g1, s1, base)
        p2, s2, _ = adamw.apply_updates(p2, g2, s2, comp)
    np.testing.assert_allclose(np.asarray(p2["w"]), np.asarray(target),
                               atol=0.1)


def test_grad_clipping():
    cfg = adamw.OptConfig(clip_norm=1.0, use_master=False)
    params = {"w": jnp.zeros((3,))}
    state = adamw.init_opt_state(params, cfg)
    g = {"w": jnp.array([100.0, 0.0, 0.0])}
    _, _, metrics = adamw.apply_updates(params, g, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0)
