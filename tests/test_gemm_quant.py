"""Producer-side quantizing epilogue: ``gmm_pallas_quant`` / the
``(gemm_quant, fp8)`` registry family / the FFN-level fused VJP.

The load-bearing claims pinned here:

  * the fused kernel is BITWISE identical to the jitted unfused
    composition (same-backend GEMM -> quantize_tilewise) on aligned
    shapes — payload and scales both;
  * ragged shapes stay allclose vs the pure-jnp oracle;
  * tail rows beyond ``sum(group_sizes)`` come back as payload 0 /
    scale 1 (the PR 3 defined-zeros contract, extended to dual outputs);
  * the producer-fused FFN's gradients track the unfused recipe in both
    ``wgrad_precision`` modes (tolerance, not equality: the fused FFN
    applies one extra e4m3 quantization to g/u);
  * registry semantics: every backend of the family runs, explicit
    unavailable raises, incompatible explicit tiles raise, auto falls
    back tile-free.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.grouped_gemm import (dense_ffn_fp8, grouped_linear,
                                     grouped_linear_ffn,
                                     grouped_linear_fused)
from repro.kernels import dispatch, ref
from repro.kernels.grouped_gemm_kernel import gmm_pallas, gmm_pallas_quant
from repro.kernels.plan import KernelConfig
from repro.kernels.quant_kernel import quantize_tilewise_pallas


def _inputs(rng, sizes, k, n, m=None):
    g = len(sizes)
    m = int(np.sum(sizes)) if m is None else m
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((g, k, n)), jnp.float32)
    a8, sa = ref.quantize_tilewise_ref(a)
    b8, sb = jax.vmap(ref.quantize_blockwise_ref)(b)
    return a8, sa, b8, sb, jnp.asarray(sizes, jnp.int32)


# ---------------------------------------------------------------------------
# kernel-level parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sizes,k,n", [
    ([128, 128], 128, 128),
    ([256, 128, 128], 256, 256),
    ([384], 128, 384),
])
def test_fused_bitwise_vs_composition_aligned(sizes, k, n):
    """Aligned shapes: the fused store-phase quantization must round
    through the intermediate dtype exactly like the unfused pipeline, so
    fused == (GEMM -> same-backend quantize) bit for bit — jitted."""
    rng = np.random.default_rng(hash((tuple(sizes), k, n)) % 2**32)
    a8, sa, b8, sb, gs = _inputs(rng, sizes, k, n)
    q, s = jax.jit(lambda *xs: gmm_pallas_quant(*xs, interpret=True))(
        a8, sa, b8, sb, gs)

    def composition(a8, sa, b8, sb, gs):
        y = gmm_pallas(a8, sa, b8, sb, gs, out_dtype=jnp.bfloat16,
                       interpret=True)
        return quantize_tilewise_pallas(y.astype(jnp.float32),
                                        interpret=True)

    q2, s2 = jax.jit(composition)(a8, sa, b8, sb, gs)
    np.testing.assert_array_equal(np.asarray(q).view(np.uint8),
                                  np.asarray(q2).view(np.uint8))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s2))


@pytest.mark.parametrize("sizes,k,n", [
    ([100, 0, 37, 119], 256, 256),
    ([1, 1, 1, 1], 128, 128),
    ([5, 250, 3, 127, 127], 384, 128),
    ([0, 0, 512], 128, 384),
])
def test_fused_allclose_vs_ref_ragged(sizes, k, n):
    """Ragged shapes vs the pure-jnp oracle (allclose: XLA may rewrite
    the divide-by-FP8_MAX differently across compilation contexts, so
    scales can differ from the *ref* by 1 ulp — the bitwise claim is
    vs the same-backend composition above)."""
    rng = np.random.default_rng(hash((tuple(sizes), k, n)) % 2**32)
    a8, sa, b8, sb, gs = _inputs(rng, sizes, k, n)
    q, s = gmm_pallas_quant(a8, sa, b8, sb, gs, interpret=True)
    y = ref.grouped_gemm_blockscaled_ref(a8, sa, b8, sb, sizes,
                                         out_dtype=jnp.bfloat16)
    qr, sr = ref.quantize_tilewise_ref(y.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=1e-6, atol=0)
    # a 1-ulp scale difference can flip a payload value sitting exactly on
    # an e4m3 rounding boundary by one step (relative spacing 2^-3), so
    # the dequantized comparison allows one quantization step; exact
    # payload equality is pinned vs the same-backend composition instead
    deq = ref.dequantize_tilewise_ref(q, s)
    deq_r = ref.dequantize_tilewise_ref(qr, sr)
    np.testing.assert_allclose(np.asarray(deq), np.asarray(deq_r),
                               rtol=0.13, atol=1e-4)


def test_tail_rows_zero_payload_unit_scale():
    """Capacity-buffer tail (rows >= sum(group_sizes)) must come back as
    DEFINED payload zeros with scale 1 — the combine relies on it."""
    rng = np.random.default_rng(3)
    sizes = [100, 30, 20]                        # sum 150, buffer 256
    a8, sa, b8, sb, gs = _inputs(rng, sizes, 128, 256, m=256)
    q, s = gmm_pallas_quant(a8, sa, b8, sb, gs, interpret=True)
    assert q.shape == (256, 256) and s.shape == (256, 2)
    np.testing.assert_array_equal(np.asarray(q[150:]).astype(np.float32), 0)
    np.testing.assert_array_equal(np.asarray(s[150:]), 1.0)
    # owned rows are NOT all zero (the mask didn't over-reach)
    assert np.abs(np.asarray(q[:150]).astype(np.float32)).sum() > 0


def test_empty_and_all_empty_groups():
    rng = np.random.default_rng(4)
    a8, sa, b8, sb, _ = _inputs(rng, [128, 128], 128, 128)
    gs0 = jnp.zeros(2, jnp.int32)
    q, s = gmm_pallas_quant(a8, sa, b8, sb, gs0, interpret=True)
    np.testing.assert_array_equal(np.asarray(q).astype(np.float32), 0)
    np.testing.assert_array_equal(np.asarray(s), 1.0)
    qm, sm = gmm_pallas_quant(a8[:0], sa[:0], b8, sb, gs0, interpret=True)
    assert qm.shape == (0, 128) and sm.shape == (0, 1)


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_registry_all_backends_agree():
    rng = np.random.default_rng(5)
    sizes = [100, 30, 126]
    a8, sa, b8, sb, gs = _inputs(rng, sizes, 128, 256)
    ref_q, ref_s = dispatch.grouped_gemm_quant(a8, sa, b8, sb, gs,
                                               backend="ref")
    want = ref.dequantize_tilewise_ref(ref_q, ref_s)
    key = ("gemm_quant", "fp8")
    for name in dispatch.op_backend_names(key):
        ok, _ = dispatch.op_availability(key, name)
        if not ok:
            continue
        q, s = dispatch.grouped_gemm_quant(a8, sa, b8, sb, gs, backend=name)
        assert q.dtype == jnp.float8_e4m3fn and s.dtype == jnp.float32
        got = np.asarray(ref.dequantize_tilewise_ref(q, s))
        # backends accumulate in different orders (blockscaled kernel vs
        # one dequantized matmul), so bf16 intermediate rounding can flip
        # e4m3 boundary values: allow one quant step relative to the
        # element (2^-3) plus one step relative to the tile amax (the
        # step size small elements actually quantize with)
        w = np.asarray(want)
        bound = 0.13 * np.abs(w) + 0.01 * np.abs(w).max(axis=1,
                                                        keepdims=True)
        assert (np.abs(got - w) <= bound).all(), name


def test_registry_explicit_unavailable_raises():
    from repro import compat
    if compat.has_tpu():
        pytest.skip("pallas is available on TPU hosts")
    rng = np.random.default_rng(6)
    a8, sa, b8, sb, gs = _inputs(rng, [128], 128, 128)
    with pytest.raises(dispatch.BackendUnavailableError):
        dispatch.grouped_gemm_quant(a8, sa, b8, sb, gs, backend="pallas")


def test_registry_tile_fallback_semantics():
    rng = np.random.default_rng(7)
    a8, sa, b8, sb, gs = _inputs(rng, [128, 128], 128, 128)
    bad = KernelConfig(block_k=256)              # K=128 not divisible
    # auto: silently falls back to a tile-free backend
    q, s = dispatch.grouped_gemm_quant(a8, sa, b8, sb, gs, config=bad)
    assert q.shape == (256, 128)
    # explicit plan-backend + incompatible tiles: loud failure
    with pytest.raises(ValueError, match="block_k"):
        dispatch.grouped_gemm_quant(
            a8, sa, b8, sb, gs,
            config=bad.with_(backend="pallas_interpret"))


def test_dispatch_bitwise_vs_same_backend_composition():
    rng = np.random.default_rng(8)
    a8, sa, b8, sb, gs = _inputs(rng, [100, 156], 128, 256)
    q, s = dispatch.grouped_gemm_quant(a8, sa, b8, sb, gs,
                                       backend="pallas_interpret")
    y = dispatch.grouped_gemm_fp8(a8, sa, b8, sb, gs,
                                  backend="pallas_interpret",
                                  out_dtype=jnp.bfloat16)
    q2, s2 = dispatch.quantize_tilewise(y.astype(jnp.float32),
                                        backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(q).view(np.uint8),
                                  np.asarray(q2).view(np.uint8))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s2))


# ---------------------------------------------------------------------------
# FFN-level fused VJP
# ---------------------------------------------------------------------------

CFG = KernelConfig(backend="pallas_interpret")


def _ffn_weights(rng, g, k, f, n):
    wg = jnp.asarray(rng.standard_normal((g, k, f)), jnp.float32) * 0.05
    wu = jnp.asarray(rng.standard_normal((g, k, f)), jnp.float32) * 0.05
    wd = jnp.asarray(rng.standard_normal((g, f, n)), jnp.float32) * 0.05
    return wg, wu, wd


@pytest.mark.parametrize("wgrad_precision", ["bf16", "fp8"])
def test_ffn_grad_parity_vs_unfused(wgrad_precision):
    """Fused-producer FFN gradients vs the unfused recipe, both residual
    modes.  Tolerance, not equality: the fused path applies one extra
    e4m3 quantization to g/u before the activation."""
    rng = np.random.default_rng(9)
    sizes = [100, 30, 70, 56]
    m, k, f, n = 256, 128, 256, 128
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    wg, wu, wd = _ffn_weights(rng, len(sizes), k, f, n)
    gs = jnp.asarray(sizes, jnp.int32)
    cfg = CFG.with_(wgrad_precision=wgrad_precision)

    def loss_fused(x, wg, wu, wd):
        y = grouped_linear_ffn(x, wg, wu, wd, gs, config=cfg)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    def loss_unfused(x, wg, wu, wd):
        g = grouped_linear(x, wg, gs, precision="fp8", config=cfg)
        u = grouped_linear(x, wu, gs, precision="fp8", config=cfg)
        y = grouped_linear_fused(g, u, wd, gs, config=cfg)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    gf = jax.grad(loss_fused, argnums=(0, 1, 2, 3))(x, wg, wu, wd)
    gu = jax.grad(loss_unfused, argnums=(0, 1, 2, 3))(x, wg, wu, wd)
    for name, a, b in zip(("dx", "dw_gate", "dw_up", "dw_down"), gf, gu):
        denom = float(jnp.max(jnp.abs(b))) + 1e-9
        rel = float(jnp.max(jnp.abs(a - b))) / denom
        assert rel < 0.15, f"{name}: rel={rel:.3f} ({wgrad_precision})"


def test_ffn_quantize_counts():
    """The headline contract: forward performs exactly ONE standalone
    quantize (x) — ZERO of g/u/h; forward+backward exactly four
    (x, dy, dg, du)."""
    from repro.core import quantization as qz
    rng = np.random.default_rng(10)
    sizes = [100, 156]
    m, k, f, n = 256, 128, 256, 128
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    wg, wu, wd = _ffn_weights(rng, len(sizes), k, f, n)
    gs = jnp.asarray(sizes, jnp.int32)

    calls = []
    orig = qz.quantize_tilewise
    qz.quantize_tilewise = lambda a, **kw: (calls.append(tuple(a.shape)),
                                            orig(a, **kw))[1]
    try:
        grouped_linear_ffn(x, wg, wu, wd, gs, config=CFG)
        assert calls == [(m, k)], calls        # one quantize, shape of x
        calls.clear()
        jax.grad(lambda *a: jnp.sum(grouped_linear_ffn(
            *a, gs, config=CFG).astype(jnp.float32) ** 2),
            argnums=(0, 1, 2, 3))(x, wg, wu, wd)
        assert len(calls) == 4, calls
        assert sorted(calls) == [(m, k), (m, n), (m, f), (m, f)], calls
    finally:
        qz.quantize_tilewise = orig


def test_ffn_gelu_and_dense_wrapper():
    rng = np.random.default_rng(11)
    m, k, f, n = 128, 128, 256, 128
    x = jnp.asarray(rng.standard_normal((2, m // 2, k)), jnp.float32)
    _, wu, wd = _ffn_weights(rng, 1, k, f, n)
    y = dense_ffn_fp8(x, None, wu[0], wd[0], act="gelu", config=CFG,
                      out_dtype=jnp.float32)
    assert y.shape == (2, m // 2, n) and y.dtype == jnp.float32
    g = jax.grad(lambda x_: jnp.sum(dense_ffn_fp8(
        x_, None, wu[0], wd[0], act="gelu", config=CFG) ** 2))(x)
    assert g.shape == x.shape and bool(jnp.all(jnp.isfinite(g)))
    with pytest.raises(ValueError, match="silu_mul"):
        grouped_linear_ffn(x.reshape(m, k), None, wu, wd,
                           jnp.array([m], jnp.int32), config=CFG)


def test_ffn_tail_rows_stay_zero():
    rng = np.random.default_rng(12)
    sizes = [100, 50]                            # sum 150, buffer 256
    m, k, f, n = 256, 128, 256, 128
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    wg, wu, wd = _ffn_weights(rng, len(sizes), k, f, n)
    gs = jnp.asarray(sizes, jnp.int32)
    y = grouped_linear_ffn(x, wg, wu, wd, gs, config=CFG)
    np.testing.assert_array_equal(
        np.asarray(y[150:]).astype(np.float32), 0)
