"""Selecting the all-fp8 training recipe from run config.

``wgrad_precision="fp8"`` (arXiv 2505.20524) threads through
``make_train_step`` and ``ModelConfig`` presets without hand-building a
``KernelConfig``, and a short training run under the all-fp8 recipe stays
loss-parity with the default bf16-wgrad recipe.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.configs.base import ModelConfig
from repro.core import moe as moe_mod
from repro.kernels import dispatch
from repro.kernels.plan import KernelConfig
from repro.models import transformer as tfm
from repro.optim.adamw import OptConfig
from repro.train.trainer import make_train_step


def test_model_config_folds_wgrad_precision():
    cfg = smoke_config("qwen2-moe-a2.7b")
    assert cfg.resolved_kernel_config is None          # nothing set: no pin
    cfg8 = dataclasses.replace(cfg, wgrad_precision="fp8")
    assert cfg8.resolved_kernel_config.wgrad_precision == "fp8"
    # an explicit kernel_config keeps its tile fields, gains the recipe
    pinned = dataclasses.replace(
        cfg, kernel_config=KernelConfig(block_m=64),
        wgrad_precision="fp8")
    rc = pinned.resolved_kernel_config
    assert rc.block_m == 64 and rc.wgrad_precision == "fp8"
    # and the MoE layer consumes the folded config
    mcfg = tfm.moe_config(dataclasses.replace(cfg8, precision="fp8"))
    assert mcfg.kernel_config.wgrad_precision == "fp8"


def _moe_loss_fn(cfg):
    def loss(params, batch):
        y, aux = moe_mod.moe_apply(params, batch["x"], cfg)
        l = jnp.mean((y.astype(jnp.float32) - batch["t"]) ** 2)
        return l, {"lb": aux["load_balance_loss"]}
    return loss


def _fixture():
    cfg = moe_mod.MoEConfig(num_experts=4, top_k=2, d_model=128,
                            d_ff_expert=128, precision="fp8",
                            backend="pallas_interpret")
    params = moe_mod.init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
    t = jax.random.normal(jax.random.PRNGKey(2), (32, cfg.d_model),
                          jnp.float32)
    return cfg, params, {"x": x, "t": t}


def _run_steps(wgrad_precision, steps=3):
    from repro.optim import adamw
    cfg, params, batch = _fixture()
    opt_cfg = OptConfig(lr=1e-2, warmup_steps=0)
    step = make_train_step(_moe_loss_fn(cfg), opt_cfg,
                           wgrad_precision=wgrad_precision)
    opt_state = adamw.init_opt_state(params, opt_cfg)
    losses = []
    for _ in range(steps):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    return losses


def test_make_train_step_wgrad_precision_routes_fp8_wgrad(monkeypatch):
    """The recipe flag must actually reach the dispatch seam: one train
    step under wgrad_precision='fp8' routes >=1 wgrad through the fp8
    operator; the default routes none."""
    from repro.optim import adamw
    cfg, params, batch = _fixture()
    calls = []
    real = dispatch.grouped_gemm_wgrad_fp8
    monkeypatch.setattr(dispatch, "grouped_gemm_wgrad_fp8",
                        lambda *a, **kw: calls.append(1) or real(*a, **kw))
    opt_cfg = OptConfig(lr=1e-2)
    opt_state = adamw.init_opt_state(params, opt_cfg)
    step8 = make_train_step(_moe_loss_fn(cfg), opt_cfg,
                            wgrad_precision="fp8")
    step8(params, opt_state, batch)
    assert calls, "fp8 recipe must route through grouped_gemm_wgrad_fp8"
    calls.clear()
    step16 = make_train_step(_moe_loss_fn(cfg), opt_cfg)
    step16(params, opt_state, batch)
    assert not calls, "default recipe must stay on the bf16 wgrad"


@pytest.mark.slow
def test_all_fp8_recipe_loss_parity_smoke():
    """3 steps under the all-fp8 recipe track the bf16-wgrad default:
    identical first loss (the forward is the same), and later losses
    within fp8-quantization-level relative deviation."""
    l16 = _run_steps(None)
    l8 = _run_steps("fp8")
    assert l8[0] == l16[0], (l8, l16)       # step-0 forward is untouched
    for a, b in zip(l8[1:], l16[1:]):
        assert abs(a - b) / max(abs(b), 1e-6) < 0.1, (l8, l16)
    # and both recipes actually learn on this toy objective
    assert l8[-1] < l8[0] and l16[-1] < l16[0], (l8, l16)


def test_train_step_kernel_config_plus_wgrad_precision_compose(monkeypatch):
    """An explicit kernel_config and the recipe flag compose: the folded
    config drives the step (block shapes from the pin, recipe from the
    flag)."""
    from repro.kernels import plan as plan_mod
    from repro.optim import adamw
    seen = {}
    orig = plan_mod.default_config

    def spy(cfg):
        seen["cfg"] = cfg
        return orig(cfg)

    monkeypatch.setattr(plan_mod, "default_config", spy)
    opt_cfg = OptConfig(lr=1e-2)
    step = make_train_step(
        lambda p, b: (jnp.sum(p["w"] ** 2), {}), opt_cfg,
        kernel_config=KernelConfig(block_m=64),
        wgrad_precision="fp8")
    params = {"w": jnp.zeros((2, 2))}
    step(params, adamw.init_opt_state(params, opt_cfg), {})
    assert seen["cfg"].block_m == 64
    assert seen["cfg"].wgrad_precision == "fp8"


def test_recipe_fold_respects_installed_default():
    """REGRESSION: selecting the recipe 'from the preset'
    (wgrad_precision set, kernel_config None) must land on top of the
    installed/per-device default tile shapes, not silently revert them
    to the untuned constructor defaults."""
    from repro.kernels import plan as plan_mod
    cfg = dataclasses.replace(smoke_config("qwen2-moe-a2.7b"),
                              wgrad_precision="fp8")
    with plan_mod.default_config(KernelConfig(block_m=512)):
        rc = cfg.resolved_kernel_config
        assert rc.block_m == 512 and rc.wgrad_precision == "fp8"
    # and make_train_step's fold goes through the same resolution
    seen = {}
    orig = plan_mod.default_config
    try:
        plan_mod.default_config = lambda c: seen.update(cfg=c) or orig(c)
        with orig(KernelConfig(block_m=512)):
            step = make_train_step(lambda p, b: (jnp.sum(p["w"] ** 2), {}),
                                   OptConfig(lr=1e-2),
                                   wgrad_precision="fp8")
            from repro.optim import adamw
            params = {"w": jnp.zeros((2, 2))}
            step(params, adamw.init_opt_state(params, OptConfig(lr=1e-2)),
                 {})
    finally:
        plan_mod.default_config = orig
    assert seen["cfg"].block_m == 512
    assert seen["cfg"].wgrad_precision == "fp8"


def test_audio_family_consumes_resolved_kernel_config(monkeypatch):
    """REGRESSION: whisper's mlp call sites passed the raw kernel_config,
    silently dropping a preset ``wgrad_precision`` for the audio family —
    every family must consume ``resolved_kernel_config``."""
    from repro.models import whisper as whs
    cfg = dataclasses.replace(smoke_config("whisper-tiny"),
                              wgrad_precision="fp8")
    seen = []
    real = whs.mlp
    monkeypatch.setattr(
        whs, "mlp",
        lambda p, x, act, **kw: seen.append(kw.get("config")) or
        real(p, x, act, **kw))
    model_cfg = cfg
    params = whs.init_whisper(jax.random.PRNGKey(0), model_cfg)
    tokens = jnp.zeros((1, 4), jnp.int32)
    frames = jnp.zeros((1, model_cfg.encoder_seq, model_cfg.d_model),
                       jnp.bfloat16)
    whs.whisper_loss(params, {"tokens": tokens, "labels": tokens,
                              "frames": frames}, model_cfg)
    assert seen and all(c is not None and c.wgrad_precision == "fp8"
                        for c in seen), seen


def test_wgrad_precision_field_survives_engine_phase_split():
    """`with_kernel_config` replaces kernel_config only — the preset's
    wgrad_precision keeps folding into whatever phase config is pinned."""
    cfg = dataclasses.replace(smoke_config("qwen2-moe-a2.7b"),
                              precision="fp8",
                              gemm_backend="pallas_interpret",
                              wgrad_precision="fp8")
    cfg2 = dataclasses.replace(cfg, kernel_config=KernelConfig(block_m=16))
    assert cfg2.resolved_kernel_config.wgrad_precision == "fp8"
    assert cfg2.resolved_kernel_config.block_m == 16
