"""Flash-attention Pallas kernel vs jnp oracle: shape/GQA/causal sweeps
in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention_kernel import (flash_attention,
                                                  flash_attention_ref)


def _mk(b, hq, hkv, s, d, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    return q, k, v


CASES = [
    # b, hq, hkv, s, d, block_q, block_k
    (1, 2, 2, 256, 64, 128, 128),    # MHA
    (2, 4, 2, 256, 64, 128, 64),     # GQA g=2, uneven blocks
    (1, 8, 1, 128, 32, 64, 64),      # MQA
    (1, 2, 2, 512, 128, 256, 256),   # bigger tiles
]


@pytest.mark.parametrize("b,hq,hkv,s,d,bq,bk", CASES)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle(b, hq, hkv, s, d, bq, bk, causal):
    q, k, v = _mk(b, hq, hkv, s, d)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_trainable_end_to_end():
    """attn_backend='flash': fused Pallas fwd + reference bwd trains a
    smoke model and matches the chunked path's loss."""
    import dataclasses
    from repro.configs import smoke_config
    from repro.models.model_zoo import make_model, synthetic_batch

    cfg = dataclasses.replace(smoke_config("qwen3-1.7b"),
                              dtype=jnp.float32, attn_backend="flash")
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = synthetic_batch(jax.random.PRNGKey(1), cfg, 128, 2)
    loss, _ = jax.jit(model.loss)(params, batch)
    g = jax.grad(lambda p, b: model.loss(p, b)[0])(params, batch)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))

    cfg2 = dataclasses.replace(cfg, attn_backend="chunked")
    loss2, _ = jax.jit(make_model(cfg2).loss)(params, batch)
    np.testing.assert_allclose(float(loss), float(loss2), rtol=1e-4)


def test_flash_matches_model_chunked_attention():
    """Cross-check against the model's XLA chunked attention path."""
    from repro.models.attention import chunked_attention
    b, hq, hkv, s, d = 2, 4, 2, 256, 64
    q, k, v = _mk(b, hq, hkv, s, d, seed=3)
    out_k = flash_attention(q, k, v, causal=True, interpret=True)
    # chunked_attention uses [B, S, H, D] layout
    out_c = chunked_attention(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3),
                              causal=True, window=None, chunk=64)
    np.testing.assert_allclose(np.asarray(out_k),
                               np.asarray(out_c.transpose(0, 2, 1, 3)),
                               rtol=2e-4, atol=2e-4)
