"""Differentiable grouped-linear: the fp8 custom VJP through the Pallas
kernel (interpret mode) — forward, dgrad AND wgrad all run padding-free
kernels through the dispatch registries.  Cross-checked against the
xla_exact path and finite-difference structure."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.grouped_gemm import grouped_linear
from repro.kernels import dispatch


def _setup(sizes=(40, 0, 57), k=128, n=128, seed=0):
    rng = np.random.default_rng(seed)
    m = sum(sizes)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((len(sizes), k, n)), jnp.float32)
    gs = jnp.asarray(sizes, jnp.int32)
    return x, w, gs


def test_fp8_pallas_fwd_matches_xla_exact():
    x, w, gs = _setup()
    y_pal = grouped_linear(x, w, gs, precision="fp8",
                           backend="pallas_interpret")
    y_ref = grouped_linear(x, w, gs, precision="fp8", backend="xla_exact")
    np.testing.assert_allclose(np.asarray(y_pal, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_fp8_pallas_grads_match_xla_exact():
    x, w, gs = _setup()

    def loss(x, w, backend):
        y = grouped_linear(x, w, gs, precision="fp8", backend=backend)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    gx_p, gw_p = jax.grad(loss, argnums=(0, 1))(x, w, "pallas_interpret")
    gx_r, gw_r = jax.grad(loss, argnums=(0, 1))(x, w, "xla_exact")
    assert bool(jnp.isfinite(gx_p).all()) and bool(jnp.isfinite(gw_p).all())
    np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_r),
                               rtol=5e-2, atol=5e-1)
    np.testing.assert_allclose(np.asarray(gw_p), np.asarray(gw_r),
                               rtol=5e-2, atol=5e-1)


def test_bf16_grouped_linear_grad_structure():
    """Gradients respect the group structure: dW[g] only sees rows of
    group g (zero-size group -> exactly zero gradient)."""
    x, w, gs = _setup(sizes=(40, 0, 57))

    def loss(w):
        y = grouped_linear(x, w, gs, precision="bf16")
        return jnp.sum(y.astype(jnp.float32) ** 2)

    gw = jax.grad(loss)(w)
    assert float(jnp.abs(gw[1]).max()) == 0.0      # empty group
    assert float(jnp.abs(gw[0]).max()) > 0.0
    assert float(jnp.abs(gw[2]).max()) > 0.0


def _grad_backends():
    """Every grouped-GEMM backend the fp8 VJP can run here (the gemm
    family drives the forward/dgrad; wgrad resolves the same name)."""
    names = []
    for name in ("pallas", "pallas_interpret", "xla_ragged", "xla_exact"):
        if dispatch.availability(name)[0]:
            names.append(name)
    return names


@pytest.mark.parametrize("backend", _grad_backends())
def test_fp8_tail_dx_rows_exactly_zero(backend):
    """REGRESSION (unowned-row gradient corruption): with
    sum(group_sizes) < M — the normal capacity-buffer case — jax.grad
    through grouped_linear(precision='fp8') must produce EXACTLY zero dx
    for rows beyond the last group on every backend.  Pre-fix, the
    kernel's masked store left those rows uninitialized (NaN in interpret
    mode) and moe_apply's take-VJP scatter-added them into real token
    gradients."""
    rng = np.random.default_rng(29)
    m_buf, k, n = 256, 128, 128
    sizes = (60, 0, 30)                         # sum=90 < 256
    total = sum(sizes)
    x = jnp.asarray(rng.standard_normal((m_buf, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((len(sizes), k, n)), jnp.float32)
    gs = jnp.asarray(sizes, jnp.int32)

    def loss(x, w):
        y = grouped_linear(x, w, gs, precision="fp8", backend=backend)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    tail = np.asarray(gx[total:])
    assert np.all(tail == 0.0), \
        (f"{backend}: tail dx rows must be exactly zero, got "
         f"{tail[np.nonzero(tail)][:4]} (nan count "
         f"{int(np.isnan(tail).sum())})")
    assert np.all(np.isfinite(np.asarray(gx[:total])))
    assert np.all(np.isfinite(np.asarray(gw)))
    assert float(jnp.abs(gw[1]).max()) == 0.0   # empty group's wgrad


def test_fp8_bwd_wgrad_runs_through_registry(monkeypatch):
    """The fp8 backward's dw goes through dispatch.grouped_gemm_wgrad —
    compat.ragged_wgrad is only the registry's fallback entry now."""
    x, w, gs = _setup()
    calls = []
    real = dispatch.grouped_gemm_wgrad

    def spying(*a, **kw):
        calls.append(kw.get("plan") is not None)
        return real(*a, **kw)

    monkeypatch.setattr(dispatch, "grouped_gemm_wgrad", spying)

    def loss(w):
        y = grouped_linear(x, w, gs, precision="fp8",
                           backend="pallas_interpret")
        return jnp.sum(y.astype(jnp.float32) ** 2)

    jax.grad(loss)(w)
    assert calls == [True], \
        "wgrad must route through the registry with the forward's plan"


def test_bf16_backend_kwarg_warns_instead_of_silent_drop():
    x, w, gs = _setup(sizes=(16, 16), k=128, n=128)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        grouped_linear(x, w, gs, precision="bf16", backend="pallas")
    assert any("ignores backend" in str(c.message) for c in caught)
    # backend='auto' and backend=None stay silent
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        grouped_linear(x, w, gs, precision="bf16", backend="auto")
        grouped_linear(x, w, gs, precision="bf16")
    assert not [c for c in caught if "ignores backend" in str(c.message)]
