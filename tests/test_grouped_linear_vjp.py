"""Differentiable grouped-linear: the fp8 custom VJP through the Pallas
kernel (interpret mode) — forward AND dgrad run the padding-free kernel;
wgrad runs the ragged contraction.  Cross-checked against the xla_exact
path and finite-difference structure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.grouped_gemm import grouped_linear


def _setup(sizes=(40, 0, 57), k=128, n=128, seed=0):
    rng = np.random.default_rng(seed)
    m = sum(sizes)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((len(sizes), k, n)), jnp.float32)
    gs = jnp.asarray(sizes, jnp.int32)
    return x, w, gs


def test_fp8_pallas_fwd_matches_xla_exact():
    x, w, gs = _setup()
    y_pal = grouped_linear(x, w, gs, precision="fp8",
                           backend="pallas_interpret")
    y_ref = grouped_linear(x, w, gs, precision="fp8", backend="xla_exact")
    np.testing.assert_allclose(np.asarray(y_pal, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_fp8_pallas_grads_match_xla_exact():
    x, w, gs = _setup()

    def loss(x, w, backend):
        y = grouped_linear(x, w, gs, precision="fp8", backend=backend)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    gx_p, gw_p = jax.grad(loss, argnums=(0, 1))(x, w, "pallas_interpret")
    gx_r, gw_r = jax.grad(loss, argnums=(0, 1))(x, w, "xla_exact")
    assert bool(jnp.isfinite(gx_p).all()) and bool(jnp.isfinite(gw_p).all())
    np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_r),
                               rtol=5e-2, atol=5e-1)
    np.testing.assert_allclose(np.asarray(gw_p), np.asarray(gw_r),
                               rtol=5e-2, atol=5e-1)


def test_bf16_grouped_linear_grad_structure():
    """Gradients respect the group structure: dW[g] only sees rows of
    group g (zero-size group -> exactly zero gradient)."""
    x, w, gs = _setup(sizes=(40, 0, 57))

    def loss(w):
        y = grouped_linear(x, w, gs, precision="bf16")
        return jnp.sum(y.astype(jnp.float32) ** 2)

    gw = jax.grad(loss)(w)
    assert float(jnp.abs(gw[1]).max()) == 0.0      # empty group
    assert float(jnp.abs(gw[0]).max()) > 0.0
    assert float(jnp.abs(gw[2]).max()) > 0.0
