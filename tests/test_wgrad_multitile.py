"""Multi-tile VMEM-resident wgrad schedule: bitwise parity with the
single-tile schedule in both precisions over ragged shapes, the span
axes' validation/pool/autotune plumbing, the resource-model footprint
growth, and the traffic model's strict byte reduction.

Bitwise (not allclose) parity is the load-bearing claim: the multi-tile
kernel assembles each visit's ``(k_span*bk, n_span*bn)`` update from the
SAME-shape ``(bm, bk) x (bm, bn)`` dots the single-tile grid runs and
applies it in one accumulator add, so the f32 accumulation order per
(k, n) output cell is preserved exactly."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import dispatch, ref
from repro.kernels import plan as plan_mod
from repro.kernels import resources
from repro.kernels.plan import KernelConfig
from repro.kernels.wgrad_kernel import gmm_pallas_wgrad, gmm_pallas_wgrad_fp8

# ragged: empty group + sum<M capacity tail; dims sized so spans 2 and 4
# both divide (K=N=512, bk=bn=128)
SIZES = [200, 0, 150, 100]
M, K, N, G = 512, 512, 512, 4
SPANS = [(2, 2), (4, 4), (2, 1), (1, 2), (4, 2)]


def _bf16_inputs(seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.bfloat16)
    dy = jnp.asarray(rng.standard_normal((M, N)), jnp.bfloat16)
    return x, dy, jnp.asarray(SIZES, jnp.int32)


def _fp8_inputs(seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    dy = jnp.asarray(rng.standard_normal((M, N)), jnp.float32)
    x8, sx = ref.quantize_tilewise_ref(x)
    d8, sd = ref.quantize_tilewise_ref(dy)
    return x8, sx, d8, sd, jnp.asarray(SIZES, jnp.int32)


@pytest.mark.parametrize("n_span,k_span", SPANS)
def test_multitile_bitwise_matches_single_tile_bf16(n_span, k_span):
    x, dy, gs = _bf16_inputs()
    single = gmm_pallas_wgrad(x, dy, gs, num_groups=G, interpret=True)
    multi = gmm_pallas_wgrad(x, dy, gs, num_groups=G,
                             n_span=n_span, k_span=k_span, interpret=True)
    assert np.array_equal(np.asarray(single), np.asarray(multi)), \
        f"span ({k_span},{n_span}) changed bf16 wgrad bits"


@pytest.mark.parametrize("n_span,k_span", SPANS)
def test_multitile_bitwise_matches_single_tile_fp8(n_span, k_span):
    x8, sx, d8, sd, gs = _fp8_inputs()
    single = gmm_pallas_wgrad_fp8(x8, sx, d8, sd, gs, num_groups=G,
                                  interpret=True)
    multi = gmm_pallas_wgrad_fp8(x8, sx, d8, sd, gs, num_groups=G,
                                 n_span=n_span, k_span=k_span,
                                 interpret=True)
    assert np.array_equal(np.asarray(single), np.asarray(multi)), \
        f"span ({k_span},{n_span}) changed fp8 wgrad bits"


def test_multitile_matches_oracle():
    x, dy, gs = _bf16_inputs()
    multi = gmm_pallas_wgrad(x, dy, gs, num_groups=G,
                             n_span=2, k_span=2, interpret=True)
    want = dispatch.wgrad_xla_exact(x, dy, gs, num_groups=G)
    np.testing.assert_allclose(np.asarray(multi), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_dispatch_routes_config_spans():
    """`KernelConfig.n_span/k_span` reach the kernel through the wgrad
    registry entries (same dispatch seam as every tile field)."""
    x, dy, gs = _bf16_inputs()
    cfg1 = KernelConfig(backend="pallas_interpret")
    cfg2 = cfg1.with_(n_span=2, k_span=2)
    out1 = dispatch.grouped_gemm_wgrad(x, dy, gs, config=cfg1)
    out2 = dispatch.grouped_gemm_wgrad(x, dy, gs, config=cfg2)
    assert np.array_equal(np.asarray(out1), np.asarray(out2))


def test_span_divisibility_validated():
    x, dy, gs = _bf16_inputs()
    with pytest.raises(ValueError, match="k_span"):
        # K=512: block_k=128 * k_span=8 = 1024 does not divide
        gmm_pallas_wgrad(x, dy, gs, num_groups=G, k_span=8, interpret=True)


def test_span_field_validation():
    with pytest.raises(ValueError, match="n_span"):
        KernelConfig(n_span=0)
    with pytest.raises(ValueError, match="k_span"):
        KernelConfig(k_span=-2)


def test_effective_blocks_and_compatible():
    cfg = KernelConfig(n_span=2, k_span=4)
    # spans only widen the wgrad family's effective tiles
    assert cfg.effective_blocks("wgrad") == (128 * 4, 128 * 2)
    assert cfg.effective_blocks("gemm") == (128, 128)
    assert cfg.compatible(512, 256, family="wgrad")
    assert not cfg.compatible(256, 256, family="wgrad")
    assert cfg.compatible(256, 256, family="gemm")


def test_config_span_roundtrip():
    cfg = KernelConfig(n_span=2, k_span=4)
    again = KernelConfig.from_dict(cfg.to_dict())
    assert (again.n_span, again.k_span) == (2, 4)
    # pre-span cache entries deserialize to spans=1
    legacy = {k: v for k, v in cfg.to_dict().items()
              if k not in ("n_span", "k_span")}
    assert KernelConfig.from_dict(legacy).n_span == 1


def test_pool_has_span_entries():
    spans = {(c.n_span, c.k_span) for c in plan_mod.CONFIG_POOL}
    assert (1, 1) in spans
    assert any(s != (1, 1) for s in spans), \
        "CONFIG_POOL lost its multi-tile wgrad span entries"
    for c in plan_mod.DECODE_POOL:
        assert (c.n_span, c.k_span) == (1, 1)


def test_candidate_pool_family_filters_spans():
    # wgrad at K=N=256 admits span-2 but not span-4 entries
    wgrad = plan_mod.candidate_pool(256, 256, family="wgrad")
    assert any(c.n_span == 2 for c in wgrad)
    assert not any(c.n_span == 4 for c in wgrad)
    # the gemm family never sees effective-tile widening
    gemm = plan_mod.candidate_pool(256, 256, family="gemm")
    assert all(c.compatible(256, 256) for c in gemm)


def test_autotune_non_wgrad_ops_skip_span_entries(tmp_path):
    cache = str(tmp_path / "cache.json")
    cfg = plan_mod.autotune(256, 512, 512, 4, measure=False, op="gemm",
                            cache_path=cache)
    assert (cfg.n_span, cfg.k_span) == (1, 1)


def test_autotune_wgrad_can_select_spans(tmp_path):
    cache = str(tmp_path / "cache.json")
    # cost-model-only ranking: the traffic model strictly prefers wider
    # spans at equal block_m, so the pick must carry a span > 1
    cfg = plan_mod.autotune(512, 512, 512, 4, measure=False, op="wgrad",
                            cache_path=cache)
    assert cfg.n_span > 1 or cfg.k_span > 1, \
        f"wgrad cost model picked single-tile {cfg} over a span entry"
    # the cached pick round-trips with its spans
    again = plan_mod.autotune(512, 512, 512, 4, measure=False, op="wgrad",
                              cache_path=cache)
    assert (again.n_span, again.k_span) == (cfg.n_span, cfg.k_span)


def test_wgrad_operand_bytes_strictly_fewer():
    base = KernelConfig()
    for prec in ("bf16", "fp8"):
        single = plan_mod.wgrad_operand_bytes(M, K, N, G, base,
                                              precision=prec)
        span = plan_mod.wgrad_operand_bytes(
            M, K, N, G, base.with_(n_span=2, k_span=2), precision=prec)
        wider = plan_mod.wgrad_operand_bytes(
            M, K, N, G, base.with_(n_span=4, k_span=4), precision=prec)
        assert span < single, (prec, span, single)
        assert wider < span, (prec, wider, span)


def test_footprint_grows_with_spans():
    fp1 = resources.wgrad_footprint(128, 128, 128, k=K, n=N,
                                    precision="bf16")
    fp2 = resources.wgrad_footprint(128, 128, 128, k=K, n=N,
                                    precision="bf16", n_span=2, k_span=2)
    assert fp2["total"] > fp1["total"]
    # the whole span pool stays VMEM-feasible at the lint REF shape for
    # both precisions under the v5e (16 MiB) budget
    for cfg in plan_mod.CONFIG_POOL:
        if cfg.n_span == 1 and cfg.k_span == 1:
            continue
        for prec in ("bf16", "fp8"):
            reason = resources.infeasible_reason(
                "wgrad", cfg, 8192, 4096, 4096,
                vmem_bytes=resources.VMEM_BYTES["tpu v5e"],
                wgrad_precision=prec)
            assert reason is None, f"{cfg} ({prec}): {reason}"
