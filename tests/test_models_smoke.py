"""Per-architecture smoke tests: reduced same-family config, one forward +
train step on CPU, asserting output shapes and no NaNs; plus a
prefill→decode consistency check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.configs.base import cell_is_runnable
from repro.models.model_zoo import make_model, synthetic_batch

BATCH, SEQ = 2, 128


@pytest.fixture(scope="module", params=ARCHS)
def arch(request):
    return request.param


def test_train_step_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = synthetic_batch(jax.random.PRNGKey(1), cfg, SEQ, BATCH)

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"

    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
    leaves = jax.tree.leaves(grads)
    assert leaves, f"{arch}: no grads"
    for g in leaves:
        assert bool(jnp.isfinite(g).all()), f"{arch}: non-finite grad"


def test_prefill_decode_consistency(arch):
    """Decoding token t+1 after prefill[0:t] must match a full prefill of
    [0:t+1] (same final-position logits, modulo accumulated fp error)."""
    cfg = smoke_config(arch)
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = synthetic_batch(jax.random.PRNGKey(1), cfg, SEQ, BATCH)

    logits_full, _ = jax.jit(model.prefill)(params, batch)

    # prefill on the first SEQ-1 tokens, then decode the last one
    batch_prefix = dict(batch)
    batch_prefix["tokens"] = batch["tokens"][:, :-1]
    batch_prefix["labels"] = batch["labels"][:, :-1]
    cap = SEQ + (cfg.num_patches if cfg.family == "vlm" else 0)
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, cache_capacity=cap))(
        params, batch_prefix)
    logits_step, _ = jax.jit(model.decode_step)(
        params, batch["tokens"][:, -1:], cache)

    a = np.asarray(logits_full[:, -1], np.float32)
    b = np.asarray(logits_step[:, 0], np.float32)
    # compare top-1 prediction + value closeness (bf16 paths)
    np.testing.assert_allclose(a, b, rtol=0.15, atol=0.15)
    assert (a.argmax(-1) == b.argmax(-1)).mean() > 0.9, arch
