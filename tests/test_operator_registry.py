"""The unified (family, precision) operator registry.

Covers the ISSUE-5 acceptance surface:

  * ``dispatch.py`` hosts exactly ONE registry dict (``_OPERATORS``,
    keyed by :class:`~repro.kernels.dispatch.OpKey`) and ONE resolution
    function (:func:`~repro.kernels.dispatch.resolve`); the per-family
    registry copies (``_REGISTRY`` / ``_WGRAD_REGISTRY``) are gone;
  * registry parity: every ``(family, precision, backend)`` combination
    that resolved before the refactor still resolves through the aliases,
    with bitwise-identical outputs (golden-checked against the PR 4 test
    fixtures' shapes and the oracle backends);
  * the quantize family is a first-class OpKey — including the
    ``op="quantize"`` autotune satellite (pool ranking + persistent
    cache + config-routed tile height);
  * the padded baseline's block-aligned plan comes from the PlanCache:
    two calls with the same static shape build exactly one plan
    (regression for the historical per-call re-planning).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import compat
from repro.core import padding_baseline as pb
from repro.kernels import dispatch, ref
from repro.kernels import plan as plan_mod
from repro.kernels.dispatch import OpKey
from repro.kernels.plan import KernelConfig


# PR 4 fixture shape: ragged, an empty group, sum < M would be the wgrad
# tests' variant — the registry-parity goldens reuse the same generator
SIZES = [100, 0, 37, 163]
K, N = 256, 128


@pytest.fixture(scope="module")
def fixtures():
    rng = np.random.default_rng(3)
    m = sum(SIZES)
    a = jnp.asarray(rng.standard_normal((m, K)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((len(SIZES), K, N)), jnp.float32)
    dy = jnp.asarray(rng.standard_normal((m, N)), jnp.float32)
    a8, sa = ref.quantize_tilewise_ref(a)
    b8, sb = jax.vmap(ref.quantize_blockwise_ref)(b)
    d8, sd = ref.quantize_tilewise_ref(dy)
    return dict(a=a, b=b, dy=dy, a8=a8, sa=sa, b8=b8, sb=sb, d8=d8, sd=sd,
                gs=jnp.asarray(SIZES, jnp.int32))


# ---------------------------------------------------------------------------
# Structure: one registry dict, one resolution function
# ---------------------------------------------------------------------------

def test_single_registry_dict_and_resolver():
    assert isinstance(dispatch._OPERATORS, dict)
    assert all(isinstance(k, OpKey) for k in dispatch._OPERATORS)
    # the per-family copies are gone — aliases are views over _OPERATORS
    for legacy in ("_REGISTRY", "_WGRAD_REGISTRY"):
        assert not hasattr(dispatch, legacy), legacy
    assert callable(dispatch.resolve)


def test_registered_op_keys():
    keys = set(dispatch.op_keys())
    assert {OpKey("gemm", "fp8"), OpKey("gemm", "bf16"),
            OpKey("wgrad", "bf16"), OpKey("wgrad", "fp8"),
            OpKey("quantize", "fp8")} <= keys


def test_op_key_validation():
    with pytest.raises(ValueError, match="op family"):
        OpKey("dgrad", "fp8")
    with pytest.raises(ValueError, match="precision"):
        OpKey("gemm", "int4")
    with pytest.raises(ValueError, match="no operator registered"):
        dispatch.resolve(("quantize", "bf16"))


def test_plan_and_tile_membership_is_registry_derived():
    assert dispatch.op_uses_plan(("gemm", "fp8"), "pallas_interpret")
    assert not dispatch.op_uses_plan(("gemm", "fp8"), "xla_exact")
    assert dispatch.op_ignores_tiles(("gemm", "fp8"), "xla_ragged")
    assert not dispatch.op_ignores_tiles(("gemm", "fp8"), "padded_baseline")
    assert dispatch.op_uses_plan(("wgrad", "fp8"), "pallas_interpret")
    # the derived back-compat frozensets keep their historical contents
    assert dispatch.PLAN_BACKENDS == frozenset(
        {"pallas", "pallas_interpret", "pallas_fp8",
         "pallas_interpret_fp8"})
    assert dispatch.TILE_FREE_BACKENDS == frozenset(
        {"xla_ragged", "xla_exact", "xla_ragged_fp8", "xla_exact_fp8"})


# ---------------------------------------------------------------------------
# Registry parity: every pre-refactor combination still resolves
# ---------------------------------------------------------------------------

def test_every_prerefactor_combination_resolves():
    # (alias call, requested names) exactly as PRs 1-4 published them
    for name in ("pallas_interpret", "xla_ragged", "xla_exact",
                 "padded_baseline", "xla", "auto", None):
        assert dispatch.resolve_backend(name) in dispatch.backend_names()
    for precision in ("bf16", "fp8"):
        suffix = "_fp8" if precision == "fp8" else ""
        for name in ("pallas_interpret", "xla_ragged", "xla_exact"):
            got = dispatch.resolve_wgrad_backend(name, precision=precision)
            assert got == name + suffix
            # the suffixed historical spelling resolves to the same entry
            assert dispatch.resolve_wgrad_backend(
                name + "_fp8", precision=precision) == got
    for name in ("pallas_interpret", "xla_ragged", "padded_baseline",
                 "ref", None):
        q, s = dispatch.quantize_tilewise(jnp.ones((8, 128)), backend=name)
        assert q.shape == (8, 128) and s.shape == (8, 1)


def test_resolve_is_what_the_aliases_call(monkeypatch):
    monkeypatch.setattr(compat, "has_tpu", lambda: True)
    assert dispatch.resolve(("gemm", "fp8"), "auto") == \
        dispatch.resolve_backend("auto") == "pallas"
    assert dispatch.resolve(("wgrad", "fp8"), "auto") == "pallas"
    assert dispatch.resolve_wgrad_backend("auto", precision="fp8") == \
        "pallas_fp8"


def test_gemm_alias_output_bitwise_vs_direct_registry_run(fixtures):
    f = fixtures
    cfg = KernelConfig(backend="pallas_interpret", out_dtype=jnp.float32)
    via_alias = dispatch.grouped_gemm_fp8(f["a8"], f["sa"], f["b8"],
                                          f["sb"], f["gs"], config=cfg)
    key = OpKey("gemm", "fp8")
    direct = dispatch._OPERATORS[key]["pallas_interpret"].run(
        f["a8"], f["sa"], f["b8"], f["sb"], f["gs"],
        num_groups=len(SIZES), config=cfg, plan=None)
    np.testing.assert_array_equal(np.asarray(via_alias),
                                  np.asarray(direct))


@pytest.mark.parametrize("backend", ["pallas_interpret", "xla_exact"])
def test_wgrad_alias_outputs_bitwise_both_precisions(fixtures, backend):
    f = fixtures
    x16 = f["a"].astype(jnp.bfloat16)
    dy16 = f["dy"].astype(jnp.bfloat16)
    via_alias = dispatch.grouped_gemm_wgrad(x16, dy16, f["gs"],
                                            backend=backend)
    direct = dispatch._OPERATORS[OpKey("wgrad", "bf16")][backend].run(
        x16, dy16, f["gs"], num_groups=len(SIZES),
        config=KernelConfig(out_dtype=jnp.float32), plan=None)
    np.testing.assert_array_equal(np.asarray(via_alias), np.asarray(direct))
    via_alias8 = dispatch.grouped_gemm_wgrad_fp8(
        f["a8"], f["sa"], f["d8"], f["sd"], f["gs"], backend=backend)
    direct8 = dispatch._OPERATORS[OpKey("wgrad", "fp8")][backend].run(
        f["a8"], f["sa"], f["d8"], f["sd"], f["gs"], num_groups=len(SIZES),
        config=KernelConfig(out_dtype=jnp.float32), plan=None)
    np.testing.assert_array_equal(np.asarray(via_alias8),
                                  np.asarray(direct8))


def test_bf16_gemm_family_matches_ragged_dot(fixtures):
    """The bf16 baseline is now a registry citizen; its output must be
    bitwise what the pre-refactor direct compat.ragged_dot produced."""
    f = fixtures
    x16 = f["a"].astype(jnp.bfloat16)
    w16 = f["b"].astype(jnp.bfloat16)
    got = dispatch.grouped_gemm_bf16(x16, w16, f["gs"],
                                     out_dtype=jnp.float32)
    want = compat.ragged_dot(x16, w16, f["gs"],
                             preferred_element_type=jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_quantize_family_entries_and_explicit_semantics(monkeypatch):
    x = jnp.ones((8, 128), jnp.float32)
    qr, sr = ref.quantize_tilewise_ref(x)
    # kernel entries are bitwise vs ref on this input; xla/ref entries ARE ref
    for name in ("pallas_interpret", "xla_ragged", "ref"):
        q, s = dispatch.quantize_tilewise(x, backend=name)
        np.testing.assert_array_equal(np.asarray(q, np.float32),
                                      np.asarray(qr, np.float32))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))
    # explicitly requested unavailable entries still refuse (parity with
    # the pre-refactor resolve-through-gemm behaviour)
    monkeypatch.setattr(compat, "has_tpu", lambda: False)
    with pytest.raises(dispatch.BackendUnavailableError):
        dispatch.quantize_tilewise(x, backend="pallas")
    monkeypatch.setattr(compat, "has_ragged_dot", lambda: False)
    with pytest.raises(dispatch.BackendUnavailableError):
        dispatch.quantize_tilewise(x, backend="xla_ragged")


def test_quantize_config_routes_tile_height_bitwise(fixtures):
    """An autotuned quantizer tile height is pure scheduling: any
    block_m produces the identical (q, s) pair."""
    f = fixtures
    base = dispatch.quantize_tilewise(f["a"], backend="pallas_interpret")
    for bm in (8, 64, 512):
        q, s = dispatch.quantize_tilewise(
            f["a"], backend="pallas_interpret",
            config=KernelConfig(block_m=bm))
        np.testing.assert_array_equal(np.asarray(q, np.float32),
                                      np.asarray(base[0], np.float32))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(base[1]))


def test_register_operator_plugs_into_unified_table():
    key = OpKey("gemm", "fp8")
    try:
        dispatch.register_operator(
            key, "test_backend", description="unit-test entry",
            available=lambda: (True, ""),
            run=lambda *a, **kw: jnp.zeros(()))
        assert "test_backend" in dispatch.backend_names()
        assert dispatch.resolve(key, "test_backend") == "test_backend"
    finally:
        del dispatch._OPERATORS[key]["test_backend"]


def test_backend_matrix_all_covers_every_operator():
    full = dispatch.backend_matrix("all")
    assert set(full) == {f"{k.family}/{k.precision}"
                         for k in dispatch.op_keys()}
    assert full["wgrad/fp8"]["pallas_interpret"]["available"]
    table = dispatch.format_backend_matrix()
    for label in ("`gemm` | `fp8`", "`wgrad` | `fp8`", "`quantize` | `fp8`",
                  "`gemm` | `bf16`", "`pallas_interpret_fp8`"):
        assert label in table, label


def test_tile_fallback_owned_by_resolve():
    cfg = KernelConfig(block_n=256)         # N=128 not divisible
    # auto: falls to a tile-free entry of the same op
    name = dispatch.resolve(("wgrad", "bf16"), None,
                            tile=(cfg, 64, 128, 128))
    assert name in ("xla_ragged", "xla_exact")
    # explicit: raises via KernelConfig.validate
    with pytest.raises(ValueError, match="block_n"):
        dispatch.resolve(("wgrad", "bf16"), "pallas_interpret",
                         tile=(cfg.with_(backend="pallas_interpret"),
                               64, 128, 128))


# ---------------------------------------------------------------------------
# Satellite: op="quantize" autotune family
# ---------------------------------------------------------------------------

def test_autotune_quantize_caches_under_distinct_key(tmp_path):
    cache = str(tmp_path / "c.json")
    cfg_q = plan_mod.autotune(512, 256, 0, 0, backend="pallas_interpret",
                              cache_path=cache, measure=False,
                              op="quantize")
    assert cfg_q.backend == "pallas_interpret"
    key_q = plan_mod.cache_key(plan_mod._device_kind(), "pallas_interpret",
                               512, 256, 0, 0, op="quantize")
    entries = plan_mod.load_cache(cache)
    assert key_q in entries and entries[key_q]["op"] == "quantize"
    plan_mod.clear_cache_memo()
    again = plan_mod.autotune(512, 256, 0, 0, backend="pallas_interpret",
                              cache_path=cache, measure=False,
                              op="quantize")
    assert again == cfg_q


def test_autotune_quantize_measures_the_quantize_dispatch(tmp_path,
                                                         monkeypatch):
    cache = str(tmp_path / "c.json")
    seen = []
    real = plan_mod._measure_candidate

    def spying(*a, **kw):
        seen.append(kw.get("op", "gemm"))
        return real(*a, iters=1, warmup=0,
                    **{k: v for k, v in kw.items()
                       if k not in ("iters", "warmup")})

    monkeypatch.setattr(plan_mod, "_measure_candidate", spying)
    plan_mod.autotune(256, 128, 0, 0, backend="pallas_interpret",
                      cache_path=cache, max_candidates=2, op="quantize")
    assert seen and all(op == "quantize" for op in seen)


def test_autotune_quantize_dedupes_tile_heights(tmp_path):
    """Pool entries differing only in (block_n, block_k) are one
    candidate for the quantizer — the cost model must rank tile heights,
    not duplicates."""
    cache = str(tmp_path / "c.json")
    plan_mod.autotune(256, 128, 0, 0, backend="pallas_interpret",
                      cache_path=cache, measure=False, op="quantize")
    entries = plan_mod.load_cache(cache)
    (entry,) = entries.values()
    pool_heights = {c.block_m for c in plan_mod.CONFIG_POOL}
    assert entry["pool_size"] == len(pool_heights)


# ---------------------------------------------------------------------------
# Satellite/bugfix: padded_baseline plans once per static shape
# ---------------------------------------------------------------------------

def _padded_inputs(sizes, k, n, seed=0):
    rng = np.random.default_rng(seed)
    m = sum(sizes)
    a8, sa = ref.quantize_tilewise_ref(
        jnp.asarray(rng.standard_normal((m, k)), jnp.float32))
    b8, sb = jax.vmap(ref.quantize_blockwise_ref)(
        jnp.asarray(rng.standard_normal((len(sizes), k, n)), jnp.float32))
    return a8, sa, b8, sb, jnp.asarray(sizes, jnp.int32)


def test_padded_baseline_plans_once_per_static_shape(monkeypatch):
    """REGRESSION: the baseline re-planned its block-aligned schedule on
    every call.  Two calls with the same static shape must build exactly
    one plan (the PlanCache replays the compiled builder); a different
    static shape builds a second one."""
    a8, sa, b8, sb, gs = _padded_inputs([60, 30, 40], 128, 128, seed=1)
    plan_mod.PLAN_CACHE.clear()
    calls = []
    inner = plan_mod.make_group_metadata
    monkeypatch.setattr(plan_mod, "make_group_metadata",
                        lambda *a, **kw: calls.append(a) or inner(*a, **kw))
    cfg = KernelConfig(backend="pallas_interpret", out_dtype=jnp.float32)
    out1 = pb.grouped_gemm_fp8_padded(a8, sa, b8, sb, gs, config=cfg)
    assert len(calls) == 1, f"first call must build the plan: {len(calls)}"
    # same static shape, different group sizes: replay, not re-plan
    gs2 = jnp.asarray([20, 70, 40], jnp.int32)
    out2 = pb.grouped_gemm_fp8_padded(a8, sa, b8, sb, gs2, config=cfg)
    assert len(calls) == 1, \
        f"same static shape must not re-plan: {len(calls)}"
    assert plan_mod.PLAN_CACHE.builds == 1
    # a different block_m is a different static plan shape
    pb.grouped_gemm_fp8_padded(a8, sa, b8, sb, gs,
                               config=cfg.with_(block_m=64))
    assert len(calls) == 2 and plan_mod.PLAN_CACHE.builds == 2
    assert out1.shape == out2.shape == (130, 128)


def test_padded_baseline_cached_plan_is_bitwise_neutral(fixtures):
    """The cached plan must not change the baseline's output — the
    paper's bitwise pad->GEMM->unpad equivalence still holds through the
    dispatch entry (which routes through the PlanCache)."""
    f = fixtures
    ours = dispatch.grouped_gemm_fp8(f["a8"], f["sa"], f["b8"], f["sb"],
                                     f["gs"], backend="pallas_interpret",
                                     out_dtype=jnp.bfloat16)
    for _ in range(2):                      # second call hits the cache
        base = dispatch.grouped_gemm_fp8(f["a8"], f["sa"], f["b8"],
                                         f["sb"], f["gs"],
                                         backend="padded_baseline",
                                         out_dtype=jnp.bfloat16)
        assert np.array_equal(np.asarray(ours, np.float32),
                              np.asarray(base, np.float32))


def test_plan_cache_key_includes_dtype_and_shape():
    plan_mod.PLAN_CACHE.clear()
    gs32 = jnp.asarray([8, 8], jnp.int32)
    p1 = plan_mod.shared_plan(gs32, 16, block_m=8)
    p2 = plan_mod.shared_plan(jnp.asarray([4, 12], jnp.int32), 16,
                              block_m=8)
    assert plan_mod.PLAN_CACHE.builds == 1          # same static key
    plan_mod.shared_plan(gs32.astype(jnp.int16), 16, block_m=8)
    assert plan_mod.PLAN_CACHE.builds == 2          # dtype is part of key
    plan_mod.shared_plan(gs32, 32, block_m=8)
    assert plan_mod.PLAN_CACHE.builds == 3          # m is part of key
    # and the cached builder's output equals a fresh make_tile_plan
    fresh = plan_mod.make_tile_plan(jnp.asarray([4, 12], jnp.int32), 16,
                                    block_m=8)
    np.testing.assert_array_equal(np.asarray(p2.group_ids),
                                  np.asarray(fresh.group_ids))
    np.testing.assert_array_equal(np.asarray(p2.m_tile_ids),
                                  np.asarray(fresh.m_tile_ids))
    assert p1.block_m == 8 and p1.m == 16
