"""MoE layer: routing correctness, capacity clipping, EP/TP equivalence
(single-process shard_map over fake devices lives in test_distributed.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.moe import (MoEConfig, _capacity, init_moe_params,
                            moe_apply, ep_size_for)


def _cfg(**kw):
    base = dict(num_experts=8, top_k=2, d_model=128, d_ff_expert=128,
                num_shared_experts=1, capacity_factor=2.0)
    base.update(kw)
    return MoEConfig(**base)


def test_moe_matches_manual_dense_computation():
    """Padding-free grouped-GEMM MoE == explicit per-token loop."""
    cfg = _cfg()
    params = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
    y, aux = moe_apply(params, x, cfg)

    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    w, ids = jax.lax.top_k(probs, cfg.top_k)
    y_ref = np.zeros_like(np.asarray(x))
    for t in range(32):
        for j in range(cfg.top_k):
            e = int(ids[t, j])
            g = np.asarray(x[t] @ params["w_gate"][e])
            u = np.asarray(x[t] @ params["w_up"][e])
            h = (g / (1 + np.exp(-g))) * u
            y_ref[t] += float(w[t, j]) * np.asarray(h @ params["w_down"][e])
    sg = np.asarray(x @ params["shared_gate"])
    su = np.asarray(x @ params["shared_up"])
    sh = (sg / (1 + np.exp(-sg))) * su
    y_ref += sh @ np.asarray(params["shared_down"])
    # layer runs its GEMMs in bf16 (production default) -> ~1e-3 rel err
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=3e-2, atol=3e-2)


def test_moe_zero_routed_expert_ok():
    """An expert that receives zero tokens must not corrupt the output
    (zero-size groups are the ragged edge case the paper handles)."""
    cfg = _cfg(num_experts=4, top_k=1)
    params = init_moe_params(jax.random.PRNGKey(0), cfg)
    # rig the router so everything goes to expert 2
    params = dict(params)
    router = np.zeros((cfg.d_model, 4), np.float32)
    router[:, 2] = 1.0
    params["router"] = jnp.asarray(router)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d_model)))
    y, aux = moe_apply(params, x, cfg)
    assert bool(jnp.isfinite(y).all())
    assert float(aux["dropped_fraction"]) == 0.0


def test_capacity_clipping_drops_overflow():
    cfg = _cfg(num_experts=4, top_k=1, capacity_factor=0.5)
    params = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (512, cfg.d_model))
    # EP shard sees only its local expert slice (rank 0 of 4)
    local = dict(params)
    for k in ("w_gate", "w_up", "w_down"):
        local[k] = params[k][0:1]
    y, aux = moe_apply(local, x, cfg, ep_rank=0, ep_size=4)
    assert bool(jnp.isfinite(y).all())


def test_capacity_helper_bounds():
    # EP capacities are ALWAYS an integral number of M-tiles — the clamp
    # rounds up to the alignment instead of returning a raw slot count
    # (pre-fix, 48 slots came back as capacity 48, breaking the docstring
    # invariant and mis-bucketing autotune cache keys)
    assert _capacity(48, 16, 2.0) == 128         # decode: one aligned tile
    assert _capacity(49152, 16, 2.0) == 6144
    assert _capacity(1000, 1, 2.0) == 1000       # TP mode: exact
    assert _capacity(10000, 8, 1.0) % 128 == 0


def test_capacity_alignment_boundary():
    """Every EP capacity is a multiple of the alignment, bounded by the
    aligned ceiling of the slot count (at most align-1 dead tail rows)."""
    for num_slots in (1, 47, 48, 127, 128, 129, 1000, 10000):
        for ep in (2, 4, 16):
            for cf in (0.5, 1.0, 1.5, 2.0):
                for align in (64, 128, 256):
                    c = _capacity(num_slots, ep, cf, align=align)
                    assert c % align == 0, (num_slots, ep, cf, align, c)
                    assert c >= align
                    cap_all = -(-num_slots // align) * align
                    assert c <= cap_all


def test_moe_capacity_exceeding_slots_pads_buffer():
    """When the aligned capacity exceeds num_slots (tiny decode shapes)
    the packed buffer pads with dead rows beyond sum(group_sizes) — the
    layer must stay finite and keep every routed token."""
    cfg = _cfg(num_experts=4, top_k=1, capacity_factor=8.0,
               num_shared_experts=0)
    params = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d_model))
    # ep_size=4: num_slots=16 -> capacity rounds up to 128 > 16
    from repro.core.moe import _capacity as cap_fn
    assert cap_fn(16, 4, 8.0) > 16
    total = jnp.zeros((16, cfg.d_model), jnp.float32)
    for rank in range(4):
        local = dict(params)
        for k in ("w_gate", "w_up", "w_down"):
            local[k] = params[k][rank:rank + 1]
        y, aux = moe_apply(local, x, cfg, ep_rank=rank, ep_size=4)
        assert bool(jnp.isfinite(y).all())
        total = total + y.astype(jnp.float32)
    # partial EP outputs sum to the unsharded layer's output
    y_full, _ = moe_apply(params, x, cfg)
    np.testing.assert_allclose(np.asarray(total),
                               np.asarray(y_full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_init_moe_params_distinct_subkey_draws():
    """REGRESSION: shared_down used to draw from the PARENT key instead of
    a fresh split — its init was correlated with the subkey stream.  All
    seven params must come from pairwise-distinct draws, and shared_down
    must not be reproducible from the parent key."""
    cfg = _cfg(num_experts=4, top_k=1, d_model=64, d_ff_expert=64,
               num_shared_experts=1)
    key = jax.random.PRNGKey(7)
    p = init_moe_params(key, cfg)
    fs = cfg.d_ff_expert * cfg.num_shared_experts
    parent_draw = np.asarray(
        jax.random.normal(key, (fs, cfg.d_model), jnp.float32) * fs ** -0.5)
    assert not np.allclose(np.asarray(p["shared_down"]), parent_draw), \
        "shared_down reuses the parent key"
    # pairwise-distinct: compare equal-size prefixes of every pair
    names = sorted(p)
    flats = {n: np.asarray(p[n], np.float32).ravel() for n in names}
    m = min(v.size for v in flats.values())
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            assert not np.allclose(flats[a][:m], flats[b][:m]), (a, b)


def test_dense_dispatch_fractional_capacity_keeps_ragged_tokens():
    """REGRESSION: the dense (GShard-style) dispatch truncated
    ``capacity_factor`` with ``int()``, so 1.5 became 1x and tokens the
    ragged path keeps were silently dropped.  With 12 of 32 tokens routed
    to one expert and capacity_factor=1.5 (per-expert cap 12, truncated
    cap 8), dense and ragged dispatch must now agree."""
    import dataclasses
    cfg = _cfg(num_experts=4, top_k=1, capacity_factor=1.5,
               num_shared_experts=0)
    params = init_moe_params(jax.random.PRNGKey(0), cfg)
    # one-hot tokens turn the router matrix into per-token logits: token t
    # goes to assign[t]
    assign = np.array([0] * 12 + [1] * 7 + [2] * 7 + [3] * 6)
    router = np.zeros((cfg.d_model, 4), np.float32)
    router[np.arange(32), assign] = 10.0
    params = dict(params, router=jnp.asarray(router))
    x = jnp.eye(32, cfg.d_model, dtype=jnp.float32)

    y_ragged, aux_r = moe_apply(params, x, cfg)
    y_dense, aux_d = moe_apply(
        params, x, dataclasses.replace(cfg, dispatch="dense"))
    # every expert-0 token must survive the dense capacity bucket
    # (pre-fix, 4 of the 12 came back as zero rows)
    e0_norms = np.linalg.norm(np.asarray(y_dense[:12], np.float32), axis=1)
    assert np.all(e0_norms > 0), f"dense dispatch dropped tokens: {e0_norms}"
    np.testing.assert_allclose(np.asarray(y_dense, np.float32),
                               np.asarray(y_ragged, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_ep_size_selection():
    assert ep_size_for(_cfg(num_experts=64), 16) == 16
    assert ep_size_for(_cfg(num_experts=60), 16) == 1   # qwen2-moe -> TP
    assert ep_size_for(_cfg(num_experts=8), 1) == 1


def test_moe_gradients_flow_to_all_param_groups():
    cfg = _cfg()
    params = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))

    def loss(p):
        y, aux = moe_apply(p, x, cfg)
        return jnp.mean(y ** 2) + 0.01 * aux["load_balance_loss"]

    g = jax.grad(loss)(params)
    for name, gv in g.items():
        assert float(jnp.linalg.norm(gv)) > 0, f"no grad for {name}"
