"""CLI for the kernel contract checker.

    python -m repro.analysis [--contracts] [--registry] [--ast]
                             [--resources] [--retrace] [--all]
                             [--paths P ...] [--baseline FILE] [--json]
                             [--list-rules] [--no-run-contracts]

Exit status 0 iff no findings outside the baseline.  Layers:

* ``--contracts``  — layer 1: jaxpr contracts over the fp8 entry points
  (includes one real Engine generate unless ``--no-run-contracts``)
* ``--registry``   — layer 2: operator-registry + tile-pool alignment lint
* ``--ast``        — layer 3: AST lint over ``--paths`` (default src/repro)
* ``--resources``  — layer 4: static VMEM/alignment budget proofs over the
  registered operator families x the whole tile pool (pure arithmetic)
* ``--retrace``    — layer 5: compile contracts (jit-retrace detector;
  executes, so skipped under ``--no-run-contracts``)
* ``--all``        — everything (the CI invocation); also the default
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import findings as fmod


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="kernel contract checker (padding-free / "
                    "quantize-once / alignment / resource invariants)")
    p.add_argument("--contracts", action="store_true",
                   help="run layer 1 jaxpr contracts")
    p.add_argument("--registry", action="store_true",
                   help="run layer 2 registry/alignment lint")
    p.add_argument("--ast", action="store_true",
                   help="run layer 3 AST lint")
    p.add_argument("--resources", action="store_true",
                   help="run layer 4 kernel-resource lint (VMEM budgets)")
    p.add_argument("--retrace", action="store_true",
                   help="run layer 5 compile contracts (retrace detector)")
    p.add_argument("--all", action="store_true",
                   help="run every layer (default when no layer given)")
    p.add_argument("--paths", nargs="*", default=None,
                   help="files/dirs for the AST layer (default: src/repro)")
    p.add_argument("--baseline", default=None,
                   help="JSON baseline of accepted finding keys")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit findings as JSON")
    p.add_argument("--list-rules", action="store_true",
                   help="print every rule ID with its rationale and exit")
    p.add_argument("--no-run-contracts", action="store_true",
                   help="skip executing contracts (the Engine generate "
                        "and the layer 5 compile contracts)")
    args = p.parse_args(argv)

    if args.list_rules:
        print(fmod.describe_rules())
        return 0

    if not (args.contracts or args.registry or args.ast
            or args.resources or args.retrace):
        args.all = True
    if args.all:
        args.contracts = args.registry = args.ast = True
        args.resources = args.retrace = True

    findings: "list[fmod.Finding]" = []
    if args.ast:
        from repro.analysis import ast_lint
        findings.extend(ast_lint.scan_paths(args.paths))
    if args.registry:
        from repro.analysis import registry_lint
        findings.extend(registry_lint.run())
    if args.resources:
        from repro.analysis import resource_lint
        findings.extend(resource_lint.run())
    if args.contracts:
        from repro.analysis import contracts
        findings.extend(contracts.run_registered(
            include_run_mode=not args.no_run_contracts))
    if args.retrace and not args.no_run_contracts:
        from repro.analysis import retrace
        findings.extend(retrace.run_registered())

    baseline = fmod.load_baseline(args.baseline)
    live = fmod.filter_baselined(findings, baseline)
    suppressed = len(findings) - len(live)

    if args.as_json:
        print(json.dumps({"findings": [f.to_dict() for f in live],
                          "suppressed": suppressed}, indent=2))
    else:
        for f in live:
            print(f.format())
        tail = f" ({suppressed} baselined)" if suppressed else ""
        print(f"repro.analysis: {len(live)} finding(s){tail}")
    return 1 if live else 0


if __name__ == "__main__":
    sys.exit(main())
