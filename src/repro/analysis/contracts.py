"""Layer 1: jaxpr contracts over the public fp8 entry points.

A :class:`Contract` declares, for one traced path (``grouped_linear``
forward, ``moe_apply`` fwd+bwd, an Engine generate, ...), the structural
invariants the paper's recipe promises:

* exact standalone-quantize counts (REPRO-C01) and shape multisets,
* one TilePlan build per routing decision (REPRO-C02),
* zero padding primitives on the padding-free path (REPRO-C03),
* zero wide (non-fp8) materialization of fused intermediates (REPRO-C04),
* producer-GEMM dispatch counts (REPRO-C05),
* decode plan discipline (REPRO-C06).

Counts come from the :mod:`repro.analysis.events` bus (product modules
emit one event per plan build / standalone quantize / producer dispatch /
decode selection); the padding and wide-intermediate rules walk the
traced jaxpr.  ``mode="jaxpr"`` contracts trace abstractly with
``jax.make_jaxpr`` (never cached, no kernel execution — fast enough for
CI on CPU); ``mode="run"`` contracts execute for real (the Engine path:
jit with concrete args compiles and runs, exactly like the serving smoke
it replaced).

Product modules register their contracts at import time
(:func:`register_contract` at the bottom of ``core/grouped_gemm.py``,
``core/moe.py``, ``serve/engine.py``); :func:`load_registered` imports
them.  :func:`check_contract` is the reusable API that replaced the
monkeypatch-count tests.
"""
from __future__ import annotations

import dataclasses
import importlib
import sys
from typing import Any, Callable, List, Optional, Sequence, Tuple

from repro.analysis import events as ev
from repro.analysis.findings import Finding, relpath

# primitives whose (rank>=2, inexact-dtype) output constitutes padding /
# copy-for-alignment on the hot path.  rank-1 / integer pads (e.g. the MoE
# slot-order edge-pad) are bookkeeping, not the paper's padding.
PADDING_PRIMS = ("pad", "dynamic_update_slice")

# primitives that merely re-label an existing wide value (no new
# materialization): a stop_gradient/astype of an *input* is not the fused
# path recomputing the activation wide
TRANSPARENT_PRIMS = frozenset({"stop_gradient", "convert_element_type",
                               "copy", "broadcast_in_dim", "reshape",
                               "squeeze", "transpose"})

# modules whose import registers the repo's contracts
CONTRACT_MODULES = ("repro.core.grouped_gemm", "repro.core.moe",
                    "repro.serve.engine")


@dataclasses.dataclass(frozen=True)
class Contract:
    """Declarative invariants for one traced path.  ``None`` expectation
    fields are unchecked — removing an expectation demonstrably lets the
    matching violation through (the coverage property CI pins)."""
    name: str
    description: str = ""
    # () -> (fn, args); deferred so registration stays import-cheap.
    # None when the contract is only used via check_contract(fn, c, *args).
    build: "Optional[Callable[[], Tuple[Callable, tuple]]]" = None
    mode: str = "jaxpr"                 # "jaxpr" | "run"
    quantize_count: Optional[int] = None        # REPRO-C01
    quantize_shapes: "Optional[tuple]" = None   # sorted multiset, C01
    plan_builds: Optional[int] = None           # REPRO-C02
    forbid_padding: bool = False                # REPRO-C03
    padding_prims: "tuple" = PADDING_PRIMS
    forbid_wide_shapes: "tuple" = ()            # REPRO-C04
    gemm_quant_calls: Optional[int] = None      # REPRO-C05
    decode_selects: Optional[int] = None        # REPRO-C06
    # (result, events) -> [messages]; reported under REPRO-C06
    extra: "Optional[Callable[[Any, list], List[str]]]" = None
    path: str = ""                      # registration site, for findings
    line: int = 1


CONTRACTS: "dict[str, Contract]" = {}
_loaded = False


def register_contract(name: str, **kw) -> Contract:
    """Register a named contract (product modules call this at import).
    The registration site becomes the finding location."""
    frame = sys._getframe(1)
    kw.setdefault("path", relpath(frame.f_code.co_filename))
    kw.setdefault("line", frame.f_lineno)
    c = Contract(name=name, **kw)
    CONTRACTS[name] = c
    return c


def load_registered() -> "dict[str, Contract]":
    """Import the contract-carrying product modules, then return the
    registry."""
    global _loaded
    if not _loaded:
        for mod in CONTRACT_MODULES:
            importlib.import_module(mod)
        _loaded = True
    return CONTRACTS


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------

def _sub_jaxprs(eqn):
    out = []
    for v in eqn.params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for item in vs:
            # ClosedJaxpr has .jaxpr; open Jaxpr has .eqns directly
            if hasattr(item, "jaxpr") and hasattr(item.jaxpr, "eqns"):
                out.append(item.jaxpr)
            elif hasattr(item, "eqns") and hasattr(item, "outvars"):
                out.append(item)
    return out


def iter_eqns(jaxpr):
    """Every equation of ``jaxpr`` and its sub-jaxprs, EXCEPT the bodies
    of ``pallas_call`` equations: a kernel body runs on block-shaped refs
    whose pads/copies are tile-local staging, not hot-path HBM padding."""
    for eqn in jaxpr.eqns:
        yield eqn
        if "pallas_call" in eqn.primitive.name:
            continue
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub)


def _is_call_eqn(eqn) -> bool:
    return bool(_sub_jaxprs(eqn))


def _inexact(dtype) -> bool:
    import jax.numpy as jnp
    return jnp.issubdtype(dtype, jnp.inexact)


def _padding_findings(closed_jaxpr, c: Contract) -> "List[Finding]":
    findings = []
    for eqn in iter_eqns(closed_jaxpr.jaxpr):
        if eqn.primitive.name not in c.padding_prims:
            continue
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is None or not hasattr(aval, "shape"):
                continue
            if eqn.primitive.name == "pad":
                # a zero-width pad (jnp.pad with all-zero widths, e.g.
                # the blockwise quantizer's already-aligned case) adds no
                # elements — XLA elides it; it is not hot-path padding
                in_aval = getattr(eqn.invars[0], "aval", None)
                if in_aval is not None \
                        and tuple(in_aval.shape) == tuple(aval.shape):
                    continue
            if len(aval.shape) >= 2 and _inexact(aval.dtype):
                findings.append(Finding(
                    "REPRO-C03", c.path, c.line,
                    f"[{c.name}] padding primitive "
                    f"'{eqn.primitive.name}' materializes "
                    f"{aval.dtype.name}{list(aval.shape)} on the "
                    f"padding-free path",
                    "the ragged grouped GEMM must consume the unpadded "
                    "buffer; use the TilePlan schedule, not an aligned "
                    "copy"))
    return findings


def _wide_findings(closed_jaxpr, c: Contract) -> "List[Finding]":
    shapes = {tuple(s) for s in c.forbid_wide_shapes}
    findings = []
    for eqn in iter_eqns(closed_jaxpr.jaxpr):
        if _is_call_eqn(eqn) or eqn.primitive.name in TRANSPARENT_PRIMS:
            continue
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is None or not hasattr(aval, "shape"):
                continue
            if (tuple(aval.shape) in shapes and _inexact(aval.dtype)
                    and aval.dtype.itemsize > 1):
                findings.append(Finding(
                    "REPRO-C04", c.path, c.line,
                    f"[{c.name}] '{eqn.primitive.name}' materializes a "
                    f"wide {aval.dtype.name}{list(aval.shape)} "
                    f"intermediate on a fused path",
                    "the fused epilogue must emit fp8 payload + 1x128 "
                    "scales directly (act_quantize / grouped_gemm_quant)"
                ))
    return findings


# ---------------------------------------------------------------------------
# Checking
# ---------------------------------------------------------------------------

def _count_findings(captured, c: Contract) -> "List[Finding]":
    findings = []
    quants = ev.of_kind(captured, "quantize_tilewise")
    if c.quantize_count is not None and len(quants) != c.quantize_count:
        shapes = [e.data.get("shape") for e in quants]
        findings.append(Finding(
            "REPRO-C01", c.path, c.line,
            f"[{c.name}] expected exactly {c.quantize_count} standalone "
            f"quantize_tilewise call(s), traced {len(quants)} "
            f"(shapes: {shapes})",
            "share one QuantizedActivation per buffer (quantize-once) "
            "and let the fused epilogues own g/u/h"))
    if c.quantize_shapes is not None:
        got = sorted(tuple(e.data.get("shape", ())) for e in quants)
        want = sorted(tuple(s) for s in c.quantize_shapes)
        if got != want:
            findings.append(Finding(
                "REPRO-C01", c.path, c.line,
                f"[{c.name}] standalone-quantize shape multiset "
                f"{got} != expected {want}",
                "a shape drift here usually means an activation "
                "intermediate (g/u/h) is being re-quantized"))
    builds = ev.count(captured, "plan_build")
    if c.plan_builds is not None and builds != c.plan_builds:
        findings.append(Finding(
            "REPRO-C02", c.path, c.line,
            f"[{c.name}] expected {c.plan_builds} TilePlan build(s) per "
            f"routing decision, traced {builds}",
            "build the plan once (make_tile_plan) and pass it to every "
            "GEMM sharing the routing's group_sizes"))
    gq = ev.count(captured, "gemm_quant")
    if c.gemm_quant_calls is not None and gq != c.gemm_quant_calls:
        findings.append(Finding(
            "REPRO-C05", c.path, c.line,
            f"[{c.name}] expected {c.gemm_quant_calls} grouped_gemm_quant "
            f"dispatch(es), traced {gq}",
            "the producer-fused path's gate/up GEMMs must route through "
            "the (gemm_quant, fp8) operator"))
    sel = ev.count(captured, "decode_select")
    if c.decode_selects is not None and sel != c.decode_selects:
        findings.append(Finding(
            "REPRO-C06", c.path, c.line,
            f"[{c.name}] expected {c.decode_selects} decode-config "
            f"selection(s), observed {sel}",
            "the Engine resolves its decode pool entry exactly once at "
            "construction"))
    return findings


def check_contract(fn: Callable, contract: Contract, *args) -> "List[Finding]":
    """Check ``fn(*args)`` against ``contract`` — the reusable API that
    replaced the monkeypatch-count CI gates.

    ``mode="jaxpr"``: traces abstractly (``jax.make_jaxpr``; never
    cached, so the event counts are exact) and walks the jaxpr for the
    padding / wide-intermediate rules.  ``mode="run"``: executes for
    real (events only; no jaxpr walk) and passes the result to the
    contract's ``extra`` checker.
    """
    import jax
    c = contract
    findings: "List[Finding]" = []
    with ev.capture() as captured:
        if c.mode == "run":
            result = fn(*args)
            closed = None
        else:
            closed = jax.make_jaxpr(fn)(*args)
            result = None
    findings.extend(_count_findings(captured, c))
    if closed is not None:
        jaxpr_findings = []
        if c.forbid_padding:
            jaxpr_findings.extend(_padding_findings(closed, c))
        if c.forbid_wide_shapes:
            jaxpr_findings.extend(_wide_findings(closed, c))
        # a violating primitive typically recurs once per weight/GEMM of
        # the same path — one finding per distinct message is the signal
        seen = set()
        for f in jaxpr_findings:
            if f.message not in seen:
                seen.add(f.message)
                findings.append(f)
    if c.extra is not None:
        for msg in c.extra(result, captured):
            findings.append(Finding("REPRO-C06", c.path, c.line,
                                    f"[{c.name}] {msg}",
                                    "see the contract's description"))
    return findings


def run_contract(contract: Contract) -> "List[Finding]":
    if contract.build is None:
        raise ValueError(f"contract {contract.name!r} has no build(); use "
                         "check_contract(fn, contract, *args) directly")
    fn, args = contract.build()
    return check_contract(fn, contract, *args)


def run_registered(names: "Optional[Sequence[str]]" = None,
                   include_run_mode: bool = True) -> "List[Finding]":
    """Run every registered contract (or the named subset).  Set
    ``include_run_mode=False`` to skip the executing contracts (the
    Engine generate) when only the fast abstract traces are wanted."""
    registry = load_registered()
    if names is None:
        names = sorted(registry)
    findings: "List[Finding]" = []
    for name in names:
        c = registry[name]
        if not include_run_mode and c.mode == "run":
            continue
        findings.extend(run_contract(c))
    return findings
