"""Kernel contract checker: static analysis for the padding-free /
quantize-once / alignment invariants.

Three layers (``python -m repro.analysis --all`` runs them all):

1. **jaxpr contracts** (:mod:`repro.analysis.contracts`, REPRO-C*) —
   trace the public fp8 entry points and verify declarative contracts:
   exact standalone-quantize counts, one TilePlan build per routing
   decision, zero padding primitives, zero wide fused intermediates.
2. **registry/alignment lint** (:mod:`repro.analysis.registry_lint`,
   REPRO-R*) — validate the ``_OPERATORS`` table and the
   ``CONFIG_POOL``/``DECODE_POOL``/``KernelConfig`` constants.
3. **AST lint** (:mod:`repro.analysis.ast_lint`, REPRO-A*) — repo rules:
   no direct kernel calls outside kernels/, no bare asserts in kernel
   files, no block-shape literals outside kernels/.

This ``__init__`` is import-cheap on purpose: hot-path product modules
import :mod:`repro.analysis.events` through the package, so nothing here
may pull in jax or the product modules at import time.
"""
from __future__ import annotations

_LAZY = {
    "events": ("repro.analysis.events", None),
    "Finding": ("repro.analysis.findings", "Finding"),
    "RULES": ("repro.analysis.findings", "RULES"),
    "Contract": ("repro.analysis.contracts", "Contract"),
    "check_contract": ("repro.analysis.contracts", "check_contract"),
    "register_contract": ("repro.analysis.contracts", "register_contract"),
    "run_registered": ("repro.analysis.contracts", "run_registered"),
    "load_registered": ("repro.analysis.contracts", "load_registered"),
    "run_registry_lint": ("repro.analysis.registry_lint", "run"),
    "run_ast_lint": ("repro.analysis.ast_lint", "scan_paths"),
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    import importlib
    try:
        modname, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module 'repro.analysis' has no attribute "
                             f"{name!r}") from None
    mod = importlib.import_module(modname)
    return mod if attr is None else getattr(mod, attr)
