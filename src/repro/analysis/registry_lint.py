"""Layer 2: static validation of the operator registry and tile pools.

Walks ``dispatch._OPERATORS`` (the one registry every (family, precision)
operator lives in) and ``plan``'s pool/default constants against the
paper's structural rules — no tracing, no kernel execution.  Rules
REPRO-R01..R07; see ``findings.RULES``.
"""
from __future__ import annotations

from typing import List

from repro.analysis.findings import Finding, relpath

# GEMM-shaped families whose Pallas entries walk TilePlans; the
# element-wise families never do
PLAN_FAMILIES = ("gemm", "gemm_quant", "wgrad")
ELEMENTWISE_FAMILIES = ("quantize", "act_quant")

# fp8 payload is 1 byte/element: a block_k-wide payload row is 16-byte
# aligned iff block_k % 16 == 0 (the TMA-style minimum the paper's §2.3
# bookkeeping guarantees); 128-multiples imply it, but the lint states
# the load-bearing bound separately so a future relaxation of the 128s
# cannot silently drop it
FP8_STRIDE_ALIGN = 16


def _loc(mod) -> str:
    return relpath(getattr(mod, "__file__", mod.__name__) or mod.__name__)


def run() -> "List[Finding]":
    from repro.kernels import dispatch, plan

    findings: "List[Finding]" = []
    dloc = _loc(dispatch)
    ploc = _loc(plan)

    # ---- R01/R02/R04: per-operator table shape -------------------------
    for key in dispatch.op_keys():
        table = dispatch._OPERATORS[key]
        names = set(table)
        if key.precision == "fp8" and "pallas" in names \
                and "pallas_interpret" not in names:
            findings.append(Finding(
                "REPRO-R01", dloc, 1,
                f"({key.family}, {key.precision}) has a compiled 'pallas' "
                f"entry but no 'pallas_interpret' twin",
                "register the interpret-mode entry so CPU CI can prove "
                "the kernel's numerics bit-identically"))
        if not any(spec.available()[0] for spec in table.values()):
            findings.append(Finding(
                "REPRO-R02", dloc, 1,
                f"({key.family}, {key.precision}) has no available "
                f"backend on this host "
                f"(entries: {sorted(names)})",
                "register at least one entry with an always-true "
                "availability probe (xla/ref/interpret)"))
        for spec in table.values():
            if spec.uses_plan and not spec.uses_tiles:
                findings.append(Finding(
                    "REPRO-R04", dloc, 1,
                    f"({key.family}, {key.precision}) '{spec.name}': "
                    f"uses_plan=True but uses_tiles=False — a "
                    f"plan-walking backend necessarily honours tile "
                    f"shapes",
                    "set uses_tiles=True (the TilePlan schedule is built "
                    "from block_m)"))
            if key.family in PLAN_FAMILIES \
                    and spec.name in ("pallas", "pallas_interpret") \
                    and not spec.uses_plan:
                findings.append(Finding(
                    "REPRO-R04", dloc, 1,
                    f"({key.family}, {key.precision}) '{spec.name}': "
                    f"Pallas GEMM-family entries must consume TilePlans "
                    f"(uses_plan=True)",
                    "plan-once/run-many is the point — wire the plan "
                    "kwarg through to the kernel"))
            if key.family in ELEMENTWISE_FAMILIES and spec.uses_plan:
                findings.append(Finding(
                    "REPRO-R04", dloc, 1,
                    f"({key.family}, {key.precision}) '{spec.name}': "
                    f"element-wise operators have no visitation schedule "
                    f"to plan (uses_plan must be False)",
                    "drop uses_plan; tile height still rides uses_tiles"))

    # ---- R03: wgrad precision twins ------------------------------------
    wg_bf16 = dispatch._OPERATORS.get(dispatch.OpKey("wgrad", "bf16"), {})
    wg_fp8 = dispatch._OPERATORS.get(dispatch.OpKey("wgrad", "fp8"), {})
    for missing in sorted(set(wg_bf16) ^ set(wg_fp8)):
        side = "fp8" if missing in wg_bf16 else "bf16"
        findings.append(Finding(
            "REPRO-R03", dloc, 1,
            f"wgrad backend '{missing}' has no {side} precision twin",
            "register the same backend name in both (wgrad, bf16) and "
            "(wgrad, fp8) so wgrad_precision can flip per KernelConfig"))
    for name in sorted(wg_fp8):
        spelled = dispatch._canonical(dispatch.OpKey("wgrad", "fp8"),
                                      name + "_fp8")
        if spelled != name:
            findings.append(Finding(
                "REPRO-R03", dloc, 1,
                f"historical spelling '{name}_fp8' does not normalize "
                f"onto the (wgrad, fp8) entry '{name}'",
                "keep _canonical()'s _fp8-suffix stripping in sync with "
                "the registered names"))

    # ---- R05: pool / default alignment ---------------------------------
    def check_cfg(cfg, where, spans_allowed=True):
        out = []
        ns = getattr(cfg, "n_span", 1)
        ks = getattr(cfg, "k_span", 1)
        for axis, v in (("n_span", ns), ("k_span", ks)):
            if not isinstance(v, int) or isinstance(v, bool) or v < 1:
                out.append((f"{where}: {axis}={v!r} is not an int >= 1",
                            "spans are whole super-tile multiples of the "
                            "base tile"))
        if not spans_allowed and (ns != 1 or ks != 1):
            out.append((f"{where}: spans ns{ns}xks{ks} on a non-wgrad "
                        f"pool entry — only the wgrad family's multi-tile "
                        f"schedule consumes them",
                        "keep n_span=k_span=1 outside CONFIG_POOL's wgrad "
                        "span entries"))
        if cfg.block_m % 8:
            out.append((f"{where}: block_m={cfg.block_m} not a multiple "
                        f"of 8 (sublane)", "align block_m to 8"))
        if cfg.block_n % 128:
            out.append((f"{where}: block_n={cfg.block_n} not a multiple "
                        f"of 128 (lane width / 128x128 weight blocks)",
                        "align block_n to 128"))
        if cfg.block_k % plan.QUANT_BLOCK:
            out.append((f"{where}: block_k={cfg.block_k} not a multiple "
                        f"of QUANT_BLOCK={plan.QUANT_BLOCK}",
                        "align block_k to the 1x128 scale granularity"))
        if cfg.block_k % FP8_STRIDE_ALIGN or cfg.block_n % FP8_STRIDE_ALIGN:
            out.append((f"{where}: fp8 payload stride "
                        f"({cfg.block_k}x{cfg.block_n}) not "
                        f"{FP8_STRIDE_ALIGN}-byte aligned",
                        "fp8 is 1 byte/element; keep both tile dims "
                        f"multiples of {FP8_STRIDE_ALIGN}"))
        return out

    for i, cfg in enumerate(plan.CONFIG_POOL):
        for msg, hint in check_cfg(cfg, f"CONFIG_POOL[{i}]"):
            findings.append(Finding("REPRO-R05", ploc, 1, msg, hint))
    for i, cfg in enumerate(plan.DECODE_POOL):
        for msg, hint in check_cfg(cfg, f"DECODE_POOL[{i}]",
                                   spans_allowed=False):
            findings.append(Finding("REPRO-R05", ploc, 1, msg, hint))
        if cfg.block_m > 16:
            findings.append(Finding(
                "REPRO-R05", ploc, 1,
                f"DECODE_POOL[{i}]: block_m={cfg.block_m} > 16 — decode "
                f"M is batch*top_k rows total; a tall tile wastes the "
                f"fetch",
                "keep decode entries at block_m<=16 (DECODE_BLOCK_MS)"))
    for prefix, kw in plan._DEVICE_DEFAULTS:
        try:
            cfg = plan.KernelConfig(**kw)
        except (TypeError, ValueError) as e:
            findings.append(Finding(
                "REPRO-R05", ploc, 1,
                f"_DEVICE_DEFAULTS[{prefix!r}] does not construct: {e}",
                "device defaults must be valid KernelConfig kwargs"))
            continue
        for msg, hint in check_cfg(cfg, f"_DEVICE_DEFAULTS[{prefix!r}]",
                                   spans_allowed=False):
            findings.append(Finding("REPRO-R05", ploc, 1, msg, hint))

    # ---- R06: scale-layout constant agreement --------------------------
    from repro.core import quantization as qz
    from repro.kernels import ref as kref
    from repro.kernels import resources as kres
    blocks = {"kernels.plan": plan.QUANT_BLOCK,
              "kernels.ref": kref.QUANT_BLOCK,
              "core.quantization": qz.QUANT_BLOCK,
              "kernels.resources": kres.QUANT_BLOCK}
    if len(set(blocks.values())) != 1 or plan.QUANT_BLOCK != 128:
        findings.append(Finding(
            "REPRO-R06", ploc, 1,
            f"QUANT_BLOCK drift: {blocks} (paper's granularity is 128)",
            "all modules must read one constant; scales are 1x128 "
            "(activations) / 128x128 (weights)"))

    # ---- R07: contract facts cover the registry ------------------------
    facts = dispatch.op_contract_facts()
    for key in dispatch.op_keys():
        f = facts.get(key)
        if f is None:
            findings.append(Finding(
                "REPRO-R07", dloc, 1,
                f"({key.family}, {key.precision}) has no registered "
                f"contract facts",
                "call register_operator_contract next to the operator's "
                "register_operator block"))
            continue
        entry = f.get("entry_point")
        if not entry or not hasattr(dispatch, entry):
            findings.append(Finding(
                "REPRO-R07", dloc, 1,
                f"({key.family}, {key.precision}) contract facts name a "
                f"missing dispatch entry point {entry!r}",
                "entry_point must be a public function of "
                "repro.kernels.dispatch"))
    return findings
