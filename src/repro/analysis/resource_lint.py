"""Layer 4 — static kernel-resource lint (REPRO-V01..V07).

Proves, without running a single kernel, that every tile config the
dispatch/plan machinery can hand to a kernel fits the device it targets:
the registered operator families in ``dispatch._OPERATORS`` are crossed
with every ``CONFIG_POOL`` / ``DECODE_POOL`` / ``_DEVICE_DEFAULTS``
entry, and each ``(family, config, device)`` triple is checked against
the :mod:`repro.kernels.resources` footprint model and the
``plan.DEVICE_SPECS`` VMEM budget.  This is the Pallas/TPU analogue of
the paper's static TMA-descriptor validation: 16B/128B alignment becomes
sublane/lane/QUANT_BLOCK divisibility, and the shared-memory budget
becomes the per-core VMEM budget.

Rules:

* **REPRO-V01** — footprint exceeds the device VMEM budget even
  single-buffered: the kernel cannot be resident at all.
* **REPRO-V02** — ``block_m`` not a multiple of 8 (sublane granularity).
* **REPRO-V03** — ``block_n`` not a multiple of 128 (lane width).
* **REPRO-V04** — ``block_k`` not a multiple of ``QUANT_BLOCK``: the
  tile covers a fractional 1x128 scale column.
* **REPRO-V05** — grid degeneracy: a tile wider/taller than the operand
  it walks at the family's reference shape.
* **REPRO-V06** — decode-pool hazard: a decode entry taller than
  ``DECODE_MAX_BLOCK_M`` rows fetches rows a decode step can never fill.
* **REPRO-V07** — pipeline headroom: the footprint fits single-buffered
  but exceeds the budget double-buffered, so the grid pipeline would
  serialize (or Mosaic would refuse the allocation).

The default ``run()`` needs the real registry/pool (imports
``repro.kernels``); ``scan_file`` checks JSON fixture entries with no
jax dependency, which is what the known-bad fixture tests use.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

from .findings import Finding, relpath
from ..kernels import resources as res

#: reference shapes the pool is proved against, per family — the bench
#: suite's large training shape for the GEMM-shaped families and the
#: whole-row elementwise kernels' FFN hidden size (elementwise kernels
#: keep (block_m, K) rows resident, so K is the budget driver there)
REF_SHAPES: "Dict[str, Dict[str, int]]" = {
    "gemm": {"m": 8192, "k": 4096, "n": 4096},
    "gemm_quant": {"m": 8192, "k": 4096, "n": 4096},
    "wgrad": {"m": 8192, "k": 4096, "n": 4096},
    "quantize": {"m": 8192, "k": 2048, "n": 2048},
    "act_quant": {"m": 8192, "k": 2048, "n": 2048},
}

#: reference decode step: a full serving batch of 16 token-rows
DECODE_REF_M = 16

_ALIGN_RULES = {"sublane": "REPRO-V02", "lane": "REPRO-V03",
                "quant": "REPRO-V04"}


def check_entry(family: str, config: Any, shape: "Dict[str, int]", *,
                device: str = "tpu v5e", decode: bool = False,
                where: str = "", path: str = "", line: int = 1,
                vmem_bytes: Optional[int] = None,
                wgrad_precision: Optional[str] = None) -> "List[Finding]":
    """Check one ``(family, config, device)`` triple at ``shape``.

    Checks short-circuit in severity order — an entry that is misaligned
    gets only its alignment rule (its footprint under a geometry the
    hardware cannot tile is meaningless), a degenerate grid only V05,
    and only a structurally-sound entry is budget-checked (V01/V07).
    """
    m, k, n = shape["m"], shape["k"], shape.get("n", shape["k"])
    budget = res.vmem_budget(device) if vmem_bytes is None else vmem_bytes
    bm, bn, bk = res.config_blocks(config)
    triple = (f"{family} x {where or f'block_m={bm},block_n={bn},block_k={bk}'}"
              f" x {device}")
    out: "List[Finding]" = []

    align = res.alignment_issues(config)
    if align:
        for code, msg in align:
            out.append(Finding(rule_id=_ALIGN_RULES[code], path=path,
                               line=line, message=f"{triple}: {msg}"))
        return out

    elementwise = family in ("quantize", "act_quant")
    degen = res.degeneracy_issues(config, m=m, k=k, n=n,
                                  elementwise=elementwise)
    if degen:
        for msg in degen:
            out.append(Finding(rule_id="REPRO-V05", path=path, line=line,
                               message=f"{triple}: {msg}"))
        return out

    if decode and not elementwise and bm > res.DECODE_MAX_BLOCK_M:
        out.append(Finding(
            rule_id="REPRO-V06", path=path, line=line,
            message=f"{triple}: decode entry block_m={bm} exceeds the "
                    f"largest decode step ({res.DECODE_MAX_BLOCK_M} "
                    f"token-rows) — fetched A rows can never be filled"))
        return out

    fp = res.footprint(family, config, m=m, k=k, n=n,
                       wgrad_precision=wgrad_precision)
    if fp["total_single"] > budget:
        out.append(Finding(
            rule_id="REPRO-V01", path=path, line=line,
            message=f"{triple}: VMEM footprint {fp['total_single']} B "
                    f"(single-buffered) exceeds the {budget} B budget"))
    elif fp["total"] > budget:
        out.append(Finding(
            rule_id="REPRO-V07", path=path, line=line,
            message=f"{triple}: footprint {fp['total_single']} B fits "
                    f"single-buffered but {fp['total']} B double-buffered "
                    f"exceeds the {budget} B budget — the grid pipeline "
                    f"cannot keep a block in flight"))
    return out


def _registry_families() -> "List[str]":
    from ..kernels import dispatch
    fams = {key.family for key in dispatch._OPERATORS}
    return [f for f in res.FAMILIES if f in fams]


def run(paths: "Optional[List[str]]" = None) -> "List[Finding]":
    """Prove the whole tuning surface: registered operator families x
    (CONFIG_POOL + DECODE_POOL + _DEVICE_DEFAULTS) x DEVICE_SPECS.

    With ``paths``, instead scan JSON fixture files (jax-free).
    """
    if paths:
        out: "List[Finding]" = []
        for p in paths:
            out.extend(scan_file(p))
        return out

    from ..kernels import plan
    plan_path = relpath(plan.__file__)
    findings: "List[Finding]" = []
    families = _registry_families()
    devices = sorted(plan.DEVICE_SPECS)

    for family in families:
        ref = REF_SHAPES.get(family, REF_SHAPES["gemm"])
        wp = "fp8" if family == "wgrad" else None
        for device in devices:
            for cfg in plan.CONFIG_POOL:
                where = (f"CONFIG_POOL[block_m={cfg.block_m},"
                         f"block_n={cfg.block_n},block_k={cfg.block_k}]")
                findings.extend(check_entry(
                    family, cfg, ref, device=device, where=where,
                    path=plan_path, wgrad_precision=wp))
            if family in ("gemm", "gemm_quant"):
                dref = dict(ref, m=DECODE_REF_M)
                for cfg in plan.DECODE_POOL:
                    where = (f"DECODE_POOL[block_m={cfg.block_m},"
                             f"block_n={cfg.block_n},block_k={cfg.block_k}]")
                    findings.extend(check_entry(
                        family, cfg, dref, device=device, decode=True,
                        where=where, path=plan_path))

    # device defaults are checked against their OWN device's budget
    for dev_key, kw in plan._DEVICE_DEFAULTS:
        try:
            cfg = plan.KernelConfig(**kw)
        except (TypeError, ValueError) as exc:  # pragma: no cover - lint net
            findings.append(Finding(
                rule_id="REPRO-V02", path=plan_path, line=1,
                message=f"_DEVICE_DEFAULTS[{dev_key!r}] does not construct "
                        f"a KernelConfig: {exc}"))
            continue
        for family in families:
            ref = REF_SHAPES.get(family, REF_SHAPES["gemm"])
            findings.extend(check_entry(
                family, cfg, ref, device=dev_key,
                where=f"_DEVICE_DEFAULTS[{dev_key!r}]", path=plan_path,
                wgrad_precision="fp8" if family == "wgrad" else None))
    return findings


def scan_file(path: str) -> "List[Finding]":
    """Check fixture entries from a JSON file: either one entry object or
    a list of ``{"family", "config", "shape", "device"?, "decode"?,
    "where"?}`` objects.  Pure arithmetic — no jax import."""
    with open(path) as f:
        data = json.load(f)
    entries = data if isinstance(data, list) else [data]
    rel = relpath(os.path.abspath(path))
    out: "List[Finding]" = []
    for entry in entries:
        out.extend(check_entry(
            entry["family"], entry["config"], entry["shape"],
            device=entry.get("device", "tpu v5e"),
            decode=bool(entry.get("decode", False)),
            where=entry.get("where", ""), path=rel,
            wgrad_precision=entry.get("wgrad_precision")))
    return out
