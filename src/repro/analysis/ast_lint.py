"""Layer 3: AST lint over ``src/repro/`` — repo-specific structural rules.

Three rules (see ``findings.RULES`` for rationale):

* **REPRO-A01** — no direct calls to kernel-internal entry points
  (``gmm_pallas*``, ``act_quantize_pallas``, ``quantize_tilewise_pallas``,
  ``quantize_blockwise_pallas``) outside ``kernels/``: everything else
  goes through the dispatch registry.
* **REPRO-A02** — no bare ``assert`` in kernel files (any file under a
  ``kernels`` directory): ``python -O`` strips them.
* **REPRO-A03** — no hardcoded ``block_m=``/``block_n=``/``block_k=``
  integer literals outside ``kernels/``: tile geometry lives in
  ``kernels/plan.py`` (pool, ``KernelConfig``) and kernel signatures.

Stdlib-only (``ast``), so the linter runs before jax imports.
"""
from __future__ import annotations

import ast
import os
from typing import Iterable, List, Optional

from repro.analysis.findings import Finding, relpath

# kernel-internal callables: the Pallas entry points the dispatch registry
# wraps.  Calling one directly skips resolve()'s availability / fallback /
# tile policy — only kernels/ itself (and tests/benchmarks, which are not
# in the default scan scope) may.
KERNEL_INTERNAL_CALLS = frozenset({
    "gmm_pallas",
    "gmm_pallas_quant",
    "gmm_pallas_wgrad",
    "gmm_pallas_wgrad_fp8",
    "act_quantize_pallas",
    "quantize_tilewise_pallas",
    "quantize_blockwise_pallas",
})

BLOCK_KWARGS = ("block_m", "block_n", "block_k")
_BLOCK_ALIGN = {"block_m": 8, "block_n": 128, "block_k": 128}


def is_kernel_file(path: str) -> bool:
    parts = os.path.normpath(path).split(os.sep)
    return "kernels" in parts


def _call_name(node: ast.Call) -> Optional[str]:
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


def scan_source(source: str, path: str) -> "List[Finding]":
    """Lint one module's source text (``path`` is only used for reporting
    and for the kernel-file predicate — handy for fixture tests)."""
    rel = relpath(path)
    kernel = is_kernel_file(rel)
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding("REPRO-A00", rel, e.lineno or 1,
                        f"unparseable module: {e.msg}",
                        "fix the syntax error so the linter can run")]
    findings: "List[Finding]" = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = _call_name(node)
            if name in KERNEL_INTERNAL_CALLS and not kernel:
                findings.append(Finding(
                    "REPRO-A01", rel, node.lineno,
                    f"direct call to kernel-internal {name}() outside "
                    f"kernels/",
                    "route through repro.kernels.dispatch (grouped_gemm_"
                    "fp8 / grouped_gemm_quant / act_quantize / "
                    "quantize_tilewise) so backend resolution applies"))
            if not kernel:
                for kw in node.keywords:
                    if (kw.arg in BLOCK_KWARGS
                            and isinstance(kw.value, ast.Constant)
                            and isinstance(kw.value.value, int)
                            and not isinstance(kw.value.value, bool)):
                        val = kw.value.value
                        align = _BLOCK_ALIGN[kw.arg]
                        mis = ("" if val % align == 0 else
                               f" (and {val} is not a multiple of "
                               f"{align})")
                        findings.append(Finding(
                            "REPRO-A03", rel, kw.value.lineno,
                            f"hardcoded {kw.arg}={val} outside "
                            f"kernels/{mis}",
                            "take the tile shape from a KernelConfig / "
                            "the plan.py pool (autotune or "
                            "KernelConfig.default()) instead of a "
                            "literal"))
        elif isinstance(node, ast.Assert) and kernel:
            findings.append(Finding(
                "REPRO-A02", rel, node.lineno,
                "bare assert in a kernel file (stripped under python -O)",
                "raise ValueError with a shape message instead"))
    return findings


def scan_file(path: str) -> "List[Finding]":
    with open(path, encoding="utf-8") as f:
        return scan_source(f.read(), path)


def iter_py_files(paths: Iterable[str]):
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        else:
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames
                                     if d != "__pycache__")
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        yield os.path.join(dirpath, fn)


def default_scan_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def scan_paths(paths: "Optional[Iterable[str]]" = None) -> "List[Finding]":
    """Lint every ``.py`` under ``paths`` (default: ``src/repro/``)."""
    if paths is None:
        paths = [default_scan_root()]
    findings: "List[Finding]" = []
    for f in iter_py_files(paths):
        findings.extend(scan_file(f))
    return findings
