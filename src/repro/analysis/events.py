"""Trace-time event bus for the kernel contract checker.

Product modules (``kernels.plan``, ``core.quantization``,
``kernels.dispatch``) emit one event per structurally-interesting action —
a TilePlan schedule build, a standalone tilewise quantization, a
producer-GEMM dispatch, a decode-config pool selection.  Because those
actions all happen while Python runs (at trace time for jitted code), a
capture window around ``jax.make_jaxpr`` or a real call observes exactly
one event per occurrence — the declarative replacement for the
monkeypatch-a-counter pattern the CI gates used.

Zero-cost by default: :func:`emit` is a no-op (one truthiness check on a
module-level list) unless a :func:`capture` window is open.  This module
is stdlib-only and imported by hot-path modules — keep it free of jax /
repro imports.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Iterator, List


@dataclasses.dataclass(frozen=True)
class Event:
    """One emitted occurrence.  ``data`` holds only static (Python-level)
    values — shapes, block sizes, group counts — never traced arrays."""
    kind: str
    data: "dict[str, Any]"


# stack of open capture windows; emit() appends to every open one so
# nested captures (a contract check inside a larger capture) stay correct
_SINKS: "List[List[Event]]" = []


def emit(kind: str, **data: Any) -> None:
    """Record one occurrence.  No-op unless a capture window is open."""
    if _SINKS:
        ev = Event(kind, data)
        for sink in _SINKS:
            sink.append(ev)


@contextlib.contextmanager
def capture() -> Iterator["List[Event]"]:
    """Open a capture window; yields the (live) list of events emitted
    while the window is open."""
    sink: "List[Event]" = []
    _SINKS.append(sink)
    try:
        yield sink
    finally:
        _SINKS.remove(sink)


def count(events: "List[Event]", kind: str) -> int:
    return sum(1 for e in events if e.kind == kind)


def of_kind(events: "List[Event]", kind: str) -> "List[Event]":
    return [e for e in events if e.kind == kind]
