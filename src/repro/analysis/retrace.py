"""Layer 5 — retrace detector (REPRO-T01..T03).

Layer 1's event contracts pin *plan* discipline (one TilePlan build per
routing decision); this layer pins *compile* discipline: the jit caches
in front of every hot path must actually hit on shape-stable repeat
calls.  A silent retrace is invisible to correctness tests and to the
event bus — it only shows up as latency — yet it is exactly what sinks
a trace-once-per-bucket serving engine, and it is the failure mode the
paper's configure-once descriptor pool exists to rule out.

Mechanism: :func:`trace_jits` monkeypatches ``jax.jit`` so that every
function jitted inside the window carries a spy whose *Python body* runs
only when jax actually traces it (a jit cache miss).  Each trace emits a
``jit_trace`` event on the :mod:`repro.analysis.events` bus, tagged with
the wrapped function's name.  A :class:`CompileContract` then declares,
for one call sequence, the exact trace count each jitted entry point may
accumulate:

* **REPRO-T01** — ``grouped_linear`` / ``grouped_linear_ffn`` fwd+bwd
  compile once across shape-stable repeat calls (routing changes, i.e.
  new ``group_sizes`` values of the same shape, must not retrace);
* **REPRO-T02** — ``Engine.generate`` compiles exactly once per phase
  (one prefill trace, one decode-loop trace) across repeat generates;
* **REPRO-T03** — the padded baseline compiles once per M-bucket.

Product modules register their compile contracts at import time next to
their layer-1 ``Contract``s (``core/grouped_gemm.py``,
``serve/engine.py``, ``core/padding_baseline.py``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import importlib
import importlib.util
import sys
from collections import Counter
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis import events as ev
from repro.analysis.findings import Finding, relpath

#: modules whose import registers compile contracts (superset of the
#: layer-1 list: the padded baseline carries only a compile contract)
COMPILE_CONTRACT_MODULES = ("repro.core.grouped_gemm", "repro.core.moe",
                            "repro.serve.engine",
                            "repro.core.padding_baseline")


def _fn_name(fun) -> str:
    # functools.partial objects have no __name__; fall back to the
    # wrapped callable's (Engine jits partial(self._prefill_impl))
    return (getattr(fun, "__name__", None)
            or getattr(getattr(fun, "func", None), "__name__", None)
            or "<anonymous>")


@contextlib.contextmanager
def trace_jits():
    """Monkeypatch ``jax.jit`` so every function jitted inside the window
    emits one ``jit_trace`` event per actual trace (jit cache miss).

    The spy wraps the to-be-jitted Python callable: jax only re-enters
    the Python body when the jit cache misses, so counting body entries
    counts compilations exactly.  Existing jitted functions (created
    before the window opened) are not observed — a compile contract must
    construct its subject inside the window (``Engine`` jits in
    ``__init__``, so building the engine inside is sufficient).
    """
    import jax
    real_jit = jax.jit

    def spying_jit(fun=None, **kw):
        if fun is None:                       # decorator form @jit(...)
            return lambda f: spying_jit(f, **kw)
        name = _fn_name(fun)

        @functools.wraps(fun, assigned=("__module__", "__qualname__",
                                        "__doc__"), updated=())
        def spy(*args, **kwargs):
            ev.emit("jit_trace", name=name)
            return fun(*args, **kwargs)
        spy.__name__ = name
        # static_argnames et al. resolve against the wrapper's signature
        # via functools.wraps' __wrapped__
        spy.__wrapped__ = fun
        return real_jit(spy, **kw)

    jax.jit = spying_jit
    try:
        yield
    finally:
        jax.jit = real_jit


@dataclasses.dataclass(frozen=True)
class CompileContract:
    """Exact compile counts for one call sequence.

    ``build`` returns ``(fn, calls)`` where ``calls`` is a sequence of
    argument tuples; the checker constructs everything and runs
    ``fn(*args)`` for each inside one :func:`trace_jits` window, then
    compares the per-name trace tally against ``expected``.  Jitted
    helpers not named in ``expected`` are unconstrained (PlanCache's
    schedule builds jit too, once per distinct group count)."""
    name: str
    description: str = ""
    build: "Optional[Callable[[], Tuple[Callable, Sequence[tuple]]]]" = None
    expected: "Dict[str, int]" = dataclasses.field(default_factory=dict)
    rule: str = "REPRO-T01"
    path: str = ""
    line: int = 1


COMPILE_CONTRACTS: "dict[str, CompileContract]" = {}
_loaded = False


def register_compile_contract(name: str, **kw) -> CompileContract:
    """Register a compile contract (product modules call this at import).
    The registration site becomes the finding location."""
    frame = sys._getframe(1)
    kw.setdefault("path", relpath(frame.f_code.co_filename))
    kw.setdefault("line", frame.f_lineno)
    c = CompileContract(name=name, **kw)
    COMPILE_CONTRACTS[name] = c
    return c


def load_registered() -> "dict[str, CompileContract]":
    global _loaded
    if not _loaded:
        for mod in COMPILE_CONTRACT_MODULES:
            importlib.import_module(mod)
        _loaded = True
    return COMPILE_CONTRACTS


def _tally_findings(tally: "Counter", expected: "Dict[str, int]",
                    c_name: str, rule: str, path: str,
                    line: int) -> "List[Finding]":
    findings = []
    for fn_name, want in sorted(expected.items()):
        got = tally.get(fn_name, 0)
        if got != want:
            verb = "retraced" if got > want else "traced"
            findings.append(Finding(
                rule, path, line,
                f"[{c_name}] {fn_name!r} {verb} {got} time(s) over the "
                f"call sequence; the jit cache must bound it to {want}",
                "shape-stable repeat calls must hit the jit cache — "
                "check for weak-type / dtype drift, python scalars in "
                "traced positions, or non-static aux arguments"))
    return findings


def check_compile_contract(c: CompileContract) -> "List[Finding]":
    """Run one compile contract: build + call sequence inside a single
    trace window, then compare trace tallies against ``expected``."""
    if c.build is None:
        raise ValueError(f"compile contract {c.name!r} has no build()")
    with trace_jits(), ev.capture() as captured:
        fn, calls = c.build()
        for args in calls:
            fn(*args)
    tally = Counter(e.data.get("name", "<anonymous>")
                    for e in ev.of_kind(captured, "jit_trace"))
    return _tally_findings(tally, c.expected, c.name, c.rule, c.path, c.line)


def run_registered(names: "Optional[Sequence[str]]" = None
                   ) -> "List[Finding]":
    registry = load_registered()
    if names is None:
        names = sorted(registry)
    findings: "List[Finding]" = []
    for name in names:
        findings.extend(check_compile_contract(registry[name]))
    return findings


def check_fixture(path: str) -> "List[Finding]":
    """Check a fixture module declaring ``EXPECTED_TRACES`` (name ->
    count) and ``run()`` (executed inside the trace window).  Used by the
    known-bad fixture tests: a shape-varying loop trips REPRO-T01."""
    spec = importlib.util.spec_from_file_location("_retrace_fixture", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    with trace_jits(), ev.capture() as captured:
        mod.run()
    tally = Counter(e.data.get("name", "<anonymous>")
                    for e in ev.of_kind(captured, "jit_trace"))
    return _tally_findings(tally, mod.EXPECTED_TRACES,
                           getattr(mod, "NAME", path), "REPRO-T01",
                           relpath(path), 1)
