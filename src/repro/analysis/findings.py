"""Findings, rule registry, and the checked-in baseline.

Every checker layer (contracts / registry lint / AST lint) reports
:class:`Finding` records carrying a rule ID, ``file:line``, a message,
and a fix hint.  CI fails on any finding whose :meth:`Finding.key` is not
in the checked-in baseline (``scripts/analysis_baseline.json`` — empty on
a clean tree; the baseline exists so a rule can be tightened before every
historical violation is fixed, without turning the checker off).

Stdlib-only: imported by the AST linter and the CLI before jax loads.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Iterable, List, Optional


@dataclasses.dataclass(frozen=True)
class Finding:
    rule_id: str
    path: str        # repo-relative where possible
    line: int        # 1-based; 1 when the rule is module/table-level
    message: str
    hint: str = ""

    def key(self) -> str:
        """Baseline identity: line numbers drift under unrelated edits, so
        the key is (rule, file, message) — stable across reformatting."""
        return f"{self.rule_id}|{self.path}|{self.message}"

    def format(self) -> str:
        s = f"{self.rule_id} {self.path}:{self.line} {self.message}"
        if self.hint:
            s += f"\n    hint: {self.hint}"
        return s

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# rule ID -> (title, rationale).  The README's "Static analysis & kernel
# contracts" section mirrors this table; ``--list-rules`` prints it.
RULES: "dict[str, tuple[str, str]]" = {
    # ---- layer 1: jaxpr contracts (REPRO-C*) ---------------------------
    "REPRO-C01": (
        "standalone-quantize count",
        "each fp8 path performs an exact number of standalone "
        "quantize_tilewise calls (quantize-once: fwd=1 for x, fwd+bwd="
        "{x, dy, dg, du}; never g/u/h — the fused epilogues own those)"),
    "REPRO-C02": (
        "one TilePlan build per routing decision",
        "plan-once/run-many: one make_group_metadata schedule build "
        "serves every GEMM sharing one routing's group_sizes, forward "
        "and backward (the paper's configure-once descriptor pool)"),
    "REPRO-C03": (
        "padding primitive on the padding-free path",
        "no pad / dynamic_update_slice of a rank>=2 floating buffer may "
        "appear in the traced fp8 hot path — eliminating that padding "
        "is the paper's core claim"),
    "REPRO-C04": (
        "wide intermediate on a fused path",
        "fused forwards must never materialize the activation "
        "intermediates (h, and for the producer-fused FFN g/u) wider "
        "than fp8 — the fused epilogue emits payload+scales directly"),
    "REPRO-C05": (
        "producer-GEMM routing",
        "a producer-fused path must dispatch its gate/up GEMMs through "
        "grouped_gemm_quant exactly as many times as it has producers"),
    "REPRO-C06": (
        "decode plan discipline",
        "an Engine resolves its decode config exactly once, the decode "
        "pool entry stays block_m<=16, and a full generate builds plan "
        "metadata once per phase per expert group"),
    # ---- layer 2: registry / alignment lint (REPRO-R*) ----------------
    "REPRO-R01": (
        "fp8 operator without an interpret entry",
        "every fp8 (family, precision) operator with a compiled Pallas "
        "entry needs the bit-identical pallas_interpret twin — CPU CI "
        "proves kernel numerics through it"),
    "REPRO-R02": (
        "operator without an always-available entry",
        "resolve()'s auto-fallback contract requires at least one entry "
        "whose availability probe passes on any host"),
    "REPRO-R03": (
        "wgrad precision-twin gap",
        "the wgrad family's bf16/fp8 tables must expose the same backend "
        "names and the historical <name>_fp8 spellings must normalize "
        "onto the fp8 table"),
    "REPRO-R04": (
        "uses_plan/uses_tiles flag inconsistency",
        "a plan-walking backend necessarily honours tile shapes; Pallas "
        "GEMM-family entries must consume TilePlans; quantize/act_quant "
        "entries never do"),
    "REPRO-R05": (
        "tile pool misalignment",
        "every CONFIG_POOL/DECODE_POOL/_DEVICE_DEFAULTS entry follows "
        "the paper's alignment rules: block_m%8, block_n%128, "
        "block_k%128 (=> fp8 payload rows are 16-byte aligned), decode "
        "entries block_m<=16"),
    "REPRO-R06": (
        "scale-layout constant drift",
        "the 1x128 / 128x128 quantization granularity (QUANT_BLOCK=128) "
        "must agree across plan, ref, and quantization modules — a "
        "drifted copy silently mis-shapes every scale buffer"),
    "REPRO-R07": (
        "operator without contract facts",
        "every registered OpKey declares its contract facts "
        "(entry point, padding-free claim, standalone-quantize budget) "
        "via register_operator_contract, so layer 1 can trace it"),
    # ---- layer 3: AST lint (REPRO-A*) ----------------------------------
    "REPRO-A01": (
        "direct kernel call outside kernels/",
        "gmm_pallas* / act_quantize_pallas / quantize_tilewise_pallas "
        "are kernel-internal; all other code must go through the "
        "dispatch registry so fallback/availability/tile policy applies"),
    "REPRO-A02": (
        "bare assert in a kernel file",
        "python -O strips asserts; kernel-entry shape checks must raise "
        "ValueError with a shape message"),
    "REPRO-A03": (
        "hardcoded block-shape literal outside kernels/",
        "tile geometry lives in kernels/plan.py (pool + KernelConfig "
        "defaults) and kernel signatures only; literals elsewhere dodge "
        "the alignment validation and the autotuner"),
    # ---- layer 4: kernel-resource lint (REPRO-V*) ----------------------
    "REPRO-V01": (
        "VMEM footprint over budget",
        "the per-program footprint (operand + scale + output tiles + "
        "accumulator scratch, at physical lane/sublane tiling) exceeds "
        "the device VMEM budget even single-buffered — the kernel "
        "cannot be resident at all"),
    "REPRO-V02": (
        "block_m sublane misalignment",
        "block_m must be a multiple of 8 (VMEM sublane granularity); a "
        "misaligned tile height forces relayouts on every load"),
    "REPRO-V03": (
        "block_n lane misalignment",
        "block_n must be a multiple of 128 (VMEM lane width, and the "
        "paper's 128B shared-alignment analogue for fp8 payload rows)"),
    "REPRO-V04": (
        "block_k scale-granularity misalignment",
        "block_k must be a multiple of QUANT_BLOCK=128 so each K tile "
        "covers a whole number of 1x128 scale columns — a fractional "
        "scale column cannot be fetched as one block"),
    "REPRO-V05": (
        "degenerate grid at reference shape",
        "a tile wider than the operand it walks (block_n>N, block_k>K) "
        "gives the grid zero full steps, and block_m>=2*M wastes >=50% "
        "of every A fetch — the half-size tile does the same work"),
    "REPRO-V06": (
        "decode tile cannot fill an MXU pass",
        "decode-pool entries serve <=16 token-rows per step; a taller "
        "block_m fetches A rows no decode step can ever fill"),
    "REPRO-V07": (
        "no double-buffering headroom",
        "the footprint fits single-buffered but exceeds the VMEM budget "
        "with the grid pipeline's double-buffering — the kernel would "
        "serialize fetch against compute (or Mosaic rejects it)"),
    # ---- layer 5: retrace detector (REPRO-T*) --------------------------
    "REPRO-T01": (
        "shape-stable call retraces",
        "repeat calls at identical abstract shapes must hit the jit "
        "cache: grouped_linear / grouped_linear_ffn fwd+bwd compile "
        "exactly once across routing changes of the same shape"),
    "REPRO-T02": (
        "engine phase recompiles",
        "Engine.generate compiles exactly once per phase (one prefill "
        "trace, one decode-loop trace) across repeat generate calls — "
        "the serving analogue of the paper's configure-once pool"),
    "REPRO-T03": (
        "padded baseline compiles off-bucket",
        "the padded baseline compiles once per M-bucket; a bucket-"
        "stable call sequence that retraces reintroduces the "
        "recompilation cost padding was supposed to amortize"),
}


def describe_rules() -> str:
    lines = []
    for rid, (title, rationale) in RULES.items():
        lines.append(f"{rid}  {title}\n    {rationale}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------

def load_baseline(path: Optional[str]) -> "set[str]":
    if path is None or not os.path.exists(path):
        return set()
    with open(path) as f:
        data = json.load(f)
    return set(data.get("findings", []))


def filter_baselined(findings: Iterable[Finding],
                     baseline: "set[str]") -> "List[Finding]":
    return [f for f in findings if f.key() not in baseline]


def relpath(path: str, root: Optional[str] = None) -> str:
    """Repo-relative spelling when the path is under the repo root."""
    if root is None:
        root = repo_root()
    try:
        rel = os.path.relpath(os.path.abspath(path), root)
    except ValueError:          # different drive (windows)
        return path
    return path if rel.startswith("..") else rel


def repo_root() -> str:
    """The directory holding src/ — derived from this file's location."""
    here = os.path.abspath(os.path.dirname(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))
