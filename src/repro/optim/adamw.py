"""AdamW with warmup+cosine schedule, global-norm clipping, optional f32
master weights (for bf16 models) and optional int8 error-feedback gradient
compression (the distributed-optimization trick for cross-pod reduction).

Pure JAX; state is a plain pytree so it checkpoints and shards trivially
(m/v inherit the parameter's PartitionSpec).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    clip_norm: float = 1.0
    use_master: bool = True          # keep f32 master copy of bf16 params
    compress_grads: bool = False     # int8 + error feedback (cross-pod AR)


def schedule(step, cfg: OptConfig):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params, cfg: OptConfig):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {"m": zeros,
             "v": jax.tree.map(jnp.zeros_like, zeros),
             "step": jnp.zeros((), jnp.int32)}
    if cfg.use_master:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    if cfg.compress_grads:
        state["ef"] = jax.tree.map(jnp.zeros_like, zeros)
    return state


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _compress_int8(g, ef):
    """Error-feedback int8 compression: quantize (g + residual) per-tensor,
    return the dequantized value actually 'transmitted' + new residual."""
    t = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(t)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(t / scale), -127, 127)
    deq = q * scale
    return deq, t - deq


def apply_updates(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(step, cfg)
    gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    new_ef = state.get("ef")
    if cfg.compress_grads:
        pairs = jax.tree.map(_compress_int8, gf, state["ef"])
        gf = jax.tree.map(lambda p: p[0], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree.map(lambda p: p[1], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))

    gnorm = global_norm(gf)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    gf = jax.tree.map(lambda g: g * clip, gf)

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    m = jax.tree.map(lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g,
                     state["m"], gf)
    v = jax.tree.map(lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * g * g,
                     state["v"], gf)

    masters = state.get("master", params)

    def upd(p, m_, v_):
        mh = m_ / b1c
        vh = v_ / b2c
        return (p.astype(jnp.float32)
                - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                        + cfg.weight_decay * p.astype(jnp.float32)))

    new_master = jax.tree.map(upd, masters, m, v)
    new_params = jax.tree.map(
        lambda nm, p: nm.astype(p.dtype), new_master, params)

    new_state = {"m": m, "v": v, "step": step}
    if cfg.use_master:
        new_state["master"] = new_master
    if cfg.compress_grads:
        new_state["ef"] = new_ef
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics
