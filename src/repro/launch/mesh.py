"""Production mesh definitions.

Functions, not module-level constants, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first
init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(n_devices: int, *, model_parallel: int | None = None):
    """Elastic re-mesh: build the best (data, model) mesh for however many
    devices survive — used on restart after node loss."""
    if model_parallel is None:
        model_parallel = 1
        for cand in (16, 8, 4, 2, 1):
            if n_devices % cand == 0 and cand <= n_devices:
                model_parallel = cand
                break
    data = n_devices // model_parallel
    return jax.make_mesh((data, model_parallel), ("data", "model"))


def local_mesh():
    """Whatever this process has (CPU tests: 1 device)."""
    n = len(jax.devices())
    return make_mesh_for(n)
