"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production mesh, with 512 placeholder host devices.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM, or unsupported collectives fail here.
Outputs one JSON per cell (memory analysis, HLO cost, collective bytes,
roofline terms) consumed by EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
# The VERY FIRST lines, before ANY other import: jax locks the device count
# at first init.  512 placeholder CPU devices for the production meshes.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro import compat
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config, run_hints
from repro.configs.base import SHAPES, cell_is_runnable
from repro.distributed import context as dctx
from repro.distributed.sharding import build_param_specs
from repro.launch.mesh import make_production_mesh
from repro.models.model_zoo import make_model, batch_struct
from repro.optim import adamw
from repro.train.trainer import make_train_step

# --- TPU v5e hardware model (roofline constants) --------------------------
PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "c64": 8}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^ ]*))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

# ring-algorithm wire-cost weights (bytes actually serialized per device)
_WIRE_WEIGHT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def collective_bytes(hlo_text: str):
    """Sum result-buffer bytes of every collective in the partitioned HLO,
    weighted by ring wire cost.  Returns (per_type, weighted_total)."""
    per_type = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shapes):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        per_type.setdefault(op, [0, 0])
        per_type[op][0] += 1
        per_type[op][1] += nbytes
    total = sum(_WIRE_WEIGHT[op] * b for op, (_, b) in per_type.items())
    return {op: {"count": c, "bytes": b} for op, (c, b) in per_type.items()}, \
        total


def _batch_shardings(bstruct, mesh):
    def spec(k, v):
        parts = [("pod", "data") if all(a in mesh.axis_names
                                        for a in ("pod", "data"))
                 else "data"]
        size = np.prod([mesh.shape[a] for a in
                        (parts[0] if isinstance(parts[0], tuple)
                         else (parts[0],))])
        if v.shape[0] % size != 0:
            parts = [None]
        parts += [None] * (len(v.shape) - 1)
        return NamedSharding(mesh, P(*parts))
    return {k: spec(k, v) for k, v in bstruct.items()}


_CACHE_RULES = {
    "k": ("batch", "kv_seq", None, None),
    "v": ("batch", "kv_seq", None, None),
    "xkv": ("batch", None, None, None),
    "C": ("batch", None, None, None),
    "n": ("batch", None, None),
    "c": ("batch", None),
    "h": ("batch", None),
    "conv": ("batch", None, None),
    "enc_out": ("batch", None, None),
    "len": (),
}


def _cache_shardings(cache_struct, mesh):
    def spec_of(path, leaf):
        name = None
        for part in reversed(path):
            if hasattr(part, "key"):
                name = str(part.key)
                break
        rule = _CACHE_RULES.get(name, ())
        nd = len(leaf.shape)
        logical = list(rule[:nd])
        lead = nd - len(logical)
        logical = [None] * lead + logical
        return NamedSharding(mesh, dctx.spec_for(leaf.shape, logical))
    return jax.tree_util.tree_map_with_path(spec_of, cache_struct)


def _replicate(tree, mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def _named(specs_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               precision=None, overrides=None):
    """Build + lower + compile one (arch x shape x mesh) cell.
    Returns the result record (dict)."""
    cfg = get_config(arch)
    if precision:
        import dataclasses
        cfg = dataclasses.replace(cfg, precision=precision)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    hints = run_hints(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    dctx.set_mesh(mesh, rules={"seq": "model"} if cfg.seq_shard else None)
    model = make_model(cfg)
    moe_mode = "ep" if (cfg.moe and cfg.moe.num_experts %
                        mesh.shape["model"] == 0) else "tp"

    params_s = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0)))
    # ZeRO-3/FSDP storage sharding over 'data' for params + optimizer state
    pspecs = build_param_specs(params_s, mesh, moe_mode=moe_mode, fsdp=True)
    pshard = _named(pspecs, mesh)
    batch_shards = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                                if a in mesh.axis_names]))

    t0 = time.time()
    if shape.kind == "train":
        # microbatch must keep >= 1 sample per batch shard
        micro = max(hints.get("train_microbatch", 16), batch_shards)
        accum = max(1, shape.global_batch // micro)
        opt_cfg = adamw.OptConfig(use_master=True)
        opt_s = jax.eval_shape(
            lambda p: adamw.init_opt_state(p, opt_cfg), params_s)
        oshard = {"m": pshard, "v": pshard, "master": pshard,
                  "step": NamedSharding(mesh, P())}
        step_fn = make_train_step(model.loss, opt_cfg, grad_accum=accum)
        bstruct = batch_struct(cfg, shape)
        bshard = _batch_shardings(bstruct, mesh)
        jitted = jax.jit(step_fn,
                         in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_s, opt_s, bstruct)
    elif shape.kind == "prefill":
        bstruct = batch_struct(cfg, shape)
        bshard = _batch_shardings(bstruct, mesh)
        step_fn = lambda p, b: model.prefill(p, b,
                                             cache_capacity=shape.seq_len)
        jitted = jax.jit(step_fn, in_shardings=(pshard, bshard))
        lowered = jitted.lower(params_s, bstruct)
    else:  # decode
        b = shape.global_batch
        s = shape.seq_len
        if cfg.family == "audio":
            frames_s = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model),
                                            jnp.bfloat16)
            cache_s = jax.eval_shape(
                lambda p, f: model.init_cache(p, {"frames": f}, b, s),
                params_s, frames_s)
        else:
            cache_s = jax.eval_shape(
                lambda: model.init_cache(None, None, b, s))
        cshard = _cache_shardings(cache_s, mesh)
        tok_s = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        tshard = _batch_shardings({"tokens": tok_s}, mesh)["tokens"]
        jitted = jax.jit(model.decode_step,
                         in_shardings=(pshard, tshard, cshard),
                         out_shardings=(None, cshard),
                         donate_argnums=(2,))
        lowered = jitted.lower(params_s, tok_s, cache_s)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compat.cost_analysis(compiled)
    hlo_text = compiled.as_text()
    per_type, wire = collective_bytes(hlo_text)

    # loop-aware re-analysis (XLA cost_analysis counts while bodies once —
    # see repro.launch.hlo_analysis); these are the roofline inputs
    from repro.launch.hlo_analysis import analyze as hlo_analyze
    scaled = hlo_analyze(hlo_text)

    chips = int(np.prod(list(mesh.shape.values())))
    # real parameter count from the abstract tree (the analytic formula
    # drifts for recurrent blocks); MoE active count stays analytic
    n_real = int(sum(int(np.prod(x.shape)) for x in
                     jax.tree.leaves(params_s)))
    flops = float(scaled["dot_flops"])
    # roofline memory term uses the fused-bound traffic (TPU XLA fuses
    # elementwise chains; the CPU artifact doesn't) — both are recorded
    bytes_acc = float(scaled["hbm_bytes_fused"])
    bytes_unfused = float(scaled["hbm_bytes"])
    per_type = scaled["collectives"]
    wire = float(scaled["wire_bytes"])
    n_params = n_real
    if cfg.moe is not None:
        # subtract inactive routed-expert params
        n_active = n_real - (cfg.num_layers - cfg.moe.first_dense_layers) * (
            3 * cfg.d_model * cfg.moe.d_ff_expert *
            (cfg.moe.num_experts - cfg.moe.top_k))
    else:
        n_active = n_real
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * n_active * tokens

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "precision": cfg.precision,
        "ok": True,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "cost": {"flops_per_device": flops,
                 "bytes_accessed_per_device": bytes_acc,
                 "bytes_accessed_unfused": bytes_unfused,
                 "xla_raw_flops": float(cost.get("flops", 0.0)),
                 "xla_raw_bytes": float(cost.get("bytes accessed", 0.0))},
        "collectives": {"per_type": per_type,
                        "wire_bytes_per_device": wire},
        "top_flops": scaled["top_flops"][:8],
        "top_bytes": scaled["top_bytes"][:8],
        "roofline": {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": wire / ICI_BW,
            "model_flops_total": model_flops,
            "model_flops_per_device": model_flops / chips,
            "useful_flops_ratio": (model_flops / chips) / max(flops, 1.0),
        },
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: rec["roofline"][k])
    rec["roofline"]["dominant"] = dom
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--precision", default=None)
    ap.add_argument("--moe-dispatch", default=None,
                    choices=[None, "ragged", "dense"])
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--moe-reduce-bf16", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                if cell_is_runnable(a, s):
                    cells.append((a, s))
    else:
        cells = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    for arch, shape in cells:
        tag = f"{arch}_{shape}_{'multi' if args.multi_pod else 'single'}"
        if args.precision:
            tag += f"_{args.precision}"
        if args.moe_dispatch:
            tag += f"_{args.moe_dispatch}"
        if args.seq_shard:
            tag += "_sp"
        if args.moe_reduce_bf16:
            tag += "_rbf16"
        overrides = {}
        if args.moe_dispatch:
            overrides["moe_dispatch"] = args.moe_dispatch
        if args.seq_shard:
            overrides["seq_shard"] = True
        if args.moe_reduce_bf16:
            overrides["moe_reduce_bf16"] = True
        overrides = overrides or None
        path = os.path.join(args.out, tag + ".json")
        print(f"=== {tag} ===", flush=True)
        try:
            rec = lower_cell(arch, shape, multi_pod=args.multi_pod,
                             precision=args.precision, overrides=overrides)
        except Exception as e:  # a failing cell is a bug; record it
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "ok": False, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if rec["ok"]:
            r = rec["roofline"]
            print(f"  compile {rec['compile_s']}s | "
                  f"compute {r['compute_s']:.4f}s mem {r['memory_s']:.4f}s "
                  f"coll {r['collective_s']:.4f}s -> {r['dominant']} | "
                  f"useful {r['useful_flops_ratio']:.2f}", flush=True)
        else:
            print(f"  FAILED: {rec['error']}", flush=True)


if __name__ == "__main__":
    main()
