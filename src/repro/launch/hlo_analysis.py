"""Loop-aware cost analysis of partitioned HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts a while-loop body ONCE,
which makes it useless for scan-over-layers / grad-accumulation programs
(it under-counts flops by the product of all trip counts).  This module
re-derives the three roofline inputs directly from the scheduled HLO:

  * dot FLOPs           — 2 x |result| x |contraction|, per dot op
  * HBM traffic bytes   — sum of operand+result buffer sizes of every
                          top-level op (fusion internals excluded: a fused
                          kernel touches HBM only at its boundary)
  * collective bytes    — result-buffer bytes per collective, weighted by
                          ring wire cost (AR 2x, AG/RS/A2A/CP 1x)

all scaled by the product of enclosing ``while`` trip counts
(``backend_config.known_trip_count``, emitted by XLA for counted loops).

Everything is computed for the per-device SPMD module, so terms divide by
per-chip peak rates directly.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "f8e4m3": 1, "s32": 4, "u32": 4, "s16": 2,
                "u16": 2, "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8,
                "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s+(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*?)\s+([a-z][a-z0-9\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\((.*)\)\s*->")
_PARAM_RE = re.compile(r"(%?[\w\.\-]+):\s*(\([^()]*\)|[a-z][a-z0-9]*\[[0-9,]*\])")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=(%[\w\.\-]+)")
_BODY_RE = re.compile(r"body=(%[\w\.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w\.\-]+)")
_METADATA_RE = re.compile(r'op_name="([^"]*)"')

_SKIP_BYTES = {"tuple", "get-tuple-element", "parameter", "constant",
               "bitcast", "while", "after-all", "iota", "conditional",
               "call"}
# elementwise ops an aggressive fuser (TPU XLA) would fuse with their
# producers: in the fused-bound traffic model they cost result-bytes only
_ELEMENTWISE = {"add", "multiply", "subtract", "divide", "select",
                "compare", "convert", "exponential", "exponential-minus-one",
                "log", "log-plus-one", "tanh", "rsqrt", "sqrt", "power",
                "negate", "abs", "maximum", "minimum", "and", "or", "not",
                "xor", "clamp", "floor", "ceil", "round-nearest-afz",
                "sign", "cosine", "sine", "logistic", "broadcast",
                "select-n"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}
_WIRE_WEIGHT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None, 1
    dims = [int(d) for d in m.group(2).split(",") if d]
    n = 1
    for d in dims:
        n *= d
    return dims, n


@dataclass
class Op:
    name: str
    result: str
    opcode: str
    rest: str              # everything after the '(' of the operand list
    line: str


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # %name -> shape text


def parse_module(text: str):
    comps = {}
    cur = None
    entry = None
    for line in text.splitlines():
        if not line:
            continue
        if not line.startswith(" "):
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                for pname, pshape in _PARAM_RE.findall(m.group(2)):
                    key = pname if pname.startswith("%") else "%" + pname
                    cur.shapes[key] = pshape
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(2), m.group(3), m.group(4), line)
            cur.ops.append(op)
            cur.shapes[op.name] = op.result
    return comps, entry


def _dot_flops(op: Op, comp: Computation) -> float:
    dims, n_out = _shape_elems(op.result)
    # contraction size from lhs operand shape + lhs_contracting_dims.
    # Operand lists are typed on some XLA versions ("dot(f32[..] %a, ..)")
    # and bare on others ("dot(%a, ..)") — take the first %ref either way.
    mo = re.search(r"(%[\w\.\-]+)", op.rest)
    k = 0
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    if mo and mc and mo.group(1) in comp.shapes:
        lhs_dims, _ = _shape_elems(comp.shapes[mo.group(1)])
        if lhs_dims:
            k = 1
            for i in [int(x) for x in mc.group(1).split(",") if x]:
                if i < len(lhs_dims):
                    k *= lhs_dims[i]
    if not k:
        k = 1
    return 2.0 * n_out * k


def _operand_bytes_list(op: Op, comp: Computation):
    # operand list = %name refs up to the closing paren of the call
    depth = 1
    out = []
    for ch in op.rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        out.append(ch)
    operand_text = "".join(out)
    return [_shape_bytes(comp.shapes[name])
            for name in re.findall(r"%[\w\.\-]+", operand_text)
            if name in comp.shapes]


def _operand_bytes(op: Op, comp: Computation) -> int:
    return sum(_operand_bytes_list(op, comp))


def _op_traffic(op: Op, comp: Computation) -> float:
    """HBM traffic model for one top-level op.

    Slice/in-place ops must not be charged for the whole buffer:
      * dynamic-slice reads only the slice it returns;
      * dynamic-update-slice writes only the update (buffer is aliased);
      * fusions rooted in a DUS behave like DUS (scan stacking / KV-cache
        update).
    Everything else: operands read once + result written once.
    """
    res = _shape_bytes(op.result)
    ops_b = _operand_bytes_list(op, comp)
    if op.opcode == "dynamic-slice":
        return 2.0 * res
    if op.opcode == "dynamic-update-slice" or (
            op.opcode == "fusion" and "dynamic_update_slice" in op.line):
        small = sum(ops_b) - (max(ops_b) if ops_b else 0)
        return 2.0 * small
    if op.opcode == "fusion" and "dynamic_slice" in op.line:
        return 2.0 * res
    return res + sum(ops_b)


def analyze(text: str, *, top_k: int = 12):
    comps, entry = parse_module(text)
    flops = 0.0
    hbm = 0.0
    hbm_fused = 0.0     # lower bound: elementwise ops fuse with producers
    coll = defaultdict(lambda: [0, 0.0])
    by_label_flops = defaultdict(float)
    by_label_bytes = defaultdict(float)

    fusion_flops_memo = {}

    def fusion_dot_flops(cname):
        if cname in fusion_flops_memo:
            return fusion_flops_memo[cname]
        c = comps.get(cname)
        total = 0.0
        if c:
            for op in c.ops:
                if op.opcode == "dot":
                    total += _dot_flops(op, c)
                m = _CALLS_RE.search(op.line)
                if m:
                    total += fusion_dot_flops(m.group(1))
        fusion_flops_memo[cname] = total
        return total

    def label_of(op: Op):
        m = _METADATA_RE.search(op.line)
        if not m:
            return op.opcode
        parts = m.group(1).split("/")
        return "/".join(parts[-2:]) if len(parts) >= 2 else parts[-1]

    seen = set()
    stack = [(entry, 1.0)]
    while stack:
        cname, mult = stack.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        key = (cname, mult)
        if key in seen:
            continue
        seen.add(key)
        for op in comp.ops:
            oc = op.opcode
            base = oc.replace("-start", "")
            if base in _COLLECTIVES and not oc.endswith("-done"):
                b = _shape_bytes(op.result)
                coll[base][0] += mult
                coll[base][1] += b * mult
                hbm += (b + _operand_bytes(op, comp)) * mult
                continue
            if oc == "while":
                trip = 1
                mt = _TRIP_RE.search(op.line)
                if mt:
                    trip = int(mt.group(1))
                mb = _BODY_RE.search(op.line)
                mc = _COND_RE.search(op.line)
                if mb:
                    stack.append((mb.group(1), mult * trip))
                if mc:
                    stack.append((mc.group(1), mult * trip))
                continue
            if oc == "conditional":
                # expected cost: each branch weighted 1/n_branches (the
                # causal chunk-skip takes the cheap branch for ~half the
                # (i, j) pairs — documented approximation)
                branches = re.findall(
                    r"(?:true_computation|false_computation)=(%[\w\.\-]+)",
                    op.line)
                if not branches:
                    mset = re.search(r"branch_computations=\{([^}]*)\}",
                                     op.line)
                    if mset:
                        branches = re.findall(r"%[\w\.\-]+", mset.group(1))
                w = mult / max(len(branches), 1)
                for bname in branches:
                    stack.append((bname, w))
                continue
            if oc == "call":
                for m in re.finditer(r"(?:to_apply|calls)=(%[\w\.\-]+)",
                                     op.line):
                    stack.append((m.group(1), mult))
            if oc == "dot":
                f = _dot_flops(op, comp) * mult
                flops += f
                by_label_flops[label_of(op)] += f
            if oc == "fusion":
                f = fusion_dot_flops(_CALLS_RE.search(op.line).group(1)) \
                    * mult if _CALLS_RE.search(op.line) else 0.0
                flops += f
                if f:
                    by_label_flops[label_of(op)] += f
            if oc not in _SKIP_BYTES:
                b = _op_traffic(op, comp) * mult
                hbm += b
                by_label_bytes[label_of(op)] += b
                if oc in _ELEMENTWISE:
                    hbm_fused += _shape_bytes(op.result) * mult
                else:
                    hbm_fused += b

    wire = sum(_WIRE_WEIGHT[k] * v[1] for k, v in coll.items())
    top_f = sorted(by_label_flops.items(), key=lambda kv: -kv[1])[:top_k]
    top_b = sorted(by_label_bytes.items(), key=lambda kv: -kv[1])[:top_k]
    return {
        "dot_flops": flops,
        "hbm_bytes": hbm,
        "hbm_bytes_fused": hbm_fused,
        "collectives": {k: {"count": v[0], "bytes": v[1]}
                        for k, v in coll.items()},
        "wire_bytes": wire,
        "top_flops": top_f,
        "top_bytes": top_b,
    }


def find_padding_ops(text: str):
    """Locate HLO-level padding in a compiled module — the compiled-program
    counterpart of the REPRO-C03 jaxpr contract (repro.analysis.contracts).

    The jaxpr check proves the *traced* program is padding-free; this proves
    nothing re-introduced padding downstream (a rewrite pass, a fusion
    boundary).  Reported:

      * ``pad`` ops that actually grow their operand — a zero-width pad
        (result shape == operand shape, e.g. the blockwise quantizer's
        already-aligned case) is elided by XLA and is not padding traffic;
      * ``copy``/``fusion`` ops whose ``op_name`` metadata traces back to a
        ``pad`` primitive, which is where fused pads end up after
        optimization.

    Returns a list of dicts: {computation, op, opcode, result, label}.
    """
    comps, _ = parse_module(text)
    hits = []
    for comp in comps.values():
        for op in comp.ops:
            meta = _METADATA_RE.search(op.line)
            label = meta.group(1) if meta else ""
            if op.opcode == "pad":
                res_dims, _ = _shape_elems(op.result)
                mo = re.search(r"(%[\w\.\-]+)", op.rest)
                if mo and mo.group(1) in comp.shapes:
                    in_dims, _ = _shape_elems(comp.shapes[mo.group(1)])
                    if in_dims is not None and in_dims == res_dims:
                        continue            # zero-width: no elements added
            elif op.opcode in ("copy", "fusion"):
                segs = label.split("/") if label else []
                if not any(s == "pad" or s.startswith("pad[")
                           for s in segs):
                    continue
            else:
                continue
            hits.append({"computation": comp.name, "op": op.name,
                         "opcode": op.opcode, "result": op.result,
                         "label": label})
    return hits


if __name__ == "__main__":
    import sys
    res = analyze(open(sys.argv[1]).read())
    res["top_flops"] = res["top_flops"][:8]
    res["top_bytes"] = res["top_bytes"][:8]
    print(json.dumps(res, indent=1))
