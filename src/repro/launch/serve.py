"""Batched serving driver.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 64 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, smoke_config
from repro.models.model_zoo import make_model, synthetic_batch
from repro.serve.engine import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    engine = Engine(model, params, max_new_tokens=args.max_new,
                    temperature=args.temperature)

    batch = synthetic_batch(jax.random.PRNGKey(args.seed + 1), cfg,
                            args.prompt_len, args.batch)
    # warmup (compile)
    res = engine.generate(batch)
    res.tokens.block_until_ready()

    t0 = time.time()
    res = engine.generate(batch)
    res.tokens.block_until_ready()
    dt = time.time() - t0
    total_new = int(res.num_generated.sum())
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.max_new}")
    print(f"generated {total_new} tokens in {dt*1e3:.1f} ms "
          f"({total_new/dt:.1f} tok/s)")
    print("sample:", res.tokens[0][:16].tolist())
    return res


if __name__ == "__main__":
    main()
