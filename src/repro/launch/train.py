"""End-to-end training driver with fault tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-moe-16b \
      --smoke --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault tolerance: checkpoints are atomic + versioned; on start the driver
auto-resumes from the latest complete checkpoint; the data pipeline is
stateless (batch = f(seed, step)) so the restarted run consumes exactly
the batches it would have.  ``--fail-at-step`` injects a crash to exercise
the path (see tests/test_train_restart.py).

Straggler / failure model (documented for fleet scale): steps are
synchronous; a lost host surfaces as a collective timeout -> the job
restarts from the last checkpoint on the surviving mesh
(launch/mesh.py:make_mesh_for re-meshes to the new device count; param
shardings are re-derived from the logical specs, checkpoints are
resharding-safe because they store full logical arrays).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpointer as ckpt
from repro.configs import get_config, smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed import context as dctx
from repro.distributed.sharding import named_shardings
from repro.launch.mesh import local_mesh
from repro.models.model_zoo import make_model
from repro.optim import adamw
from repro.train.trainer import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-moe-16b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--precision", default=None, choices=[None, "bf16", "fp8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="inject a crash (restart testing)")
    ap.add_argument("--dtype", default=None, choices=[None, "f32", "bf16"])
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    repl = {}
    if args.precision:
        repl["precision"] = args.precision
    if args.dtype:
        repl["dtype"] = jnp.float32 if args.dtype == "f32" else jnp.bfloat16
    if repl:
        cfg = dataclasses.replace(cfg, **repl)

    mesh = local_mesh() if len(jax.devices()) > 1 else None
    if mesh is not None:
        dctx.set_mesh(mesh)
    model = make_model(cfg)

    params = model.init_params(jax.random.PRNGKey(args.seed))
    opt_cfg = adamw.OptConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(args.steps // 20, 5),
                              use_master=cfg.dtype == jnp.bfloat16)
    opt_state = adamw.init_opt_state(params, opt_cfg)

    if mesh is not None:
        pshard = named_shardings(params, mesh)
        params = jax.device_put(params, pshard)

    step_fn = jax.jit(make_train_step(model.loss, opt_cfg,
                                      grad_accum=args.grad_accum),
                      donate_argnums=(0, 1))

    start_step = 0
    if args.ckpt_dir:
        restored, meta, s = ckpt.restore_latest(
            args.ckpt_dir, {"params": params, "opt": opt_state})
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
            start_step = s + 1
            print(f"[resume] restored step {s} from {args.ckpt_dir}")

    data = SyntheticLM(DataConfig(seed=args.seed, batch_size=args.batch,
                                  seq_len=args.seq), cfg)

    t0 = time.time()
    tokens_done = 0
    for step in range(start_step, args.steps):
        if step == args.fail_at_step:
            raise SystemExit(f"[injected failure] at step {step}")
        batch = data.batch_at(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        tokens_done += args.batch * args.seq
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            tps = tokens_done / max(time.time() - t0, 1e-9)
            print(f"step {step:5d}  loss {m['loss']:.4f}  "
                  f"gnorm {m.get('grad_norm', 0):.3f}  "
                  f"lr {m.get('lr', 0):.2e}  tok/s {tps:,.0f}", flush=True)
        if args.ckpt_dir and args.save_every and \
                (step + 1) % args.save_every == 0:
            path = ckpt.save(args.ckpt_dir, step,
                             {"params": params, "opt": opt_state})
            print(f"[ckpt] step {step} -> {path}", flush=True)
    print("done.")
    return params


if __name__ == "__main__":
    main()
