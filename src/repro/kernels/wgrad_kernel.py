"""Ragged-contraction (wgrad) grouped GEMM — Pallas TPU kernel.

``dw[g] = x_g^T @ dy_g`` where the *contraction* dimension is the ragged M
axis: groups own contiguous, dynamically-sized row ranges of the
concatenated token buffer, and each group's rows contract against the same
rows of the upstream gradient.  This is the last GEMM of the fp8 training
step (paper's training workload) and the ROADMAP's "N-side raggedness"
item — before this kernel the backward detoured through XLA's
``ragged_dot_general`` fallback (``compat.ragged_wgrad``).

The forward kernel's insight transfers unchanged: the *schedule* depends
only on ``(group_sizes, M, block_m)``, so the same :class:`TilePlan` built
once per routing decision serves gate/up/down forwards, both dgrads, and
every wgrad.  What changes is the role of a visit:

  * the grid walks ``(K super-tiles, N super-tiles, visits)`` with the
    visit axis innermost; visit t touches M-tile ``m_tile_ids[t]`` on
    behalf of group ``group_ids[t]``;
  * instead of a masked *store* of an output row tile, each visit performs
    a masked *accumulation* into the group's dense ``[k_span*block_k,
    n_span*block_n]`` output super-tile: rows of the M-tile owned by other
    groups (or beyond ``sum(group_sizes)``) are zeroed before the
    transposed dot, so boundary tiles contribute exactly their owned rows;
  * the multi-tile spans are the VMEM-residency lever: one grid cell
    fetches its ``(block_m, k_span*block_k)`` x tile and ``(block_m,
    n_span*block_n)`` dy tile ONCE and sweeps every ``(block_k, block_n)``
    sub-tile of the super-tile from those resident copies — at span 1 the
    x tile is re-fetched from HBM on every N step and dy on every K step
    (the old schedule, still the exact per-cell accumulation this kernel
    reproduces bitwise: the sub-tile dots have the same shapes, operand
    values and visit order regardless of span);
  * consecutive visits of one group share the output block (``group_ids``
    is non-decreasing), so Pallas keeps it resident in VMEM across the
    group's M-tiles and flushes once per group — the accumulation analogue
    of the forward's "safe overlapping write";
  * padding visits either sweep tail tiles (no owned rows -> zero
    contribution) or duplicate the last real visit (detected by comparing
    ``(group_ids, m_tile_ids)`` against the previous visit and skipped —
    accumulation, unlike the forward's store, is not idempotent).

Groups that receive zero rows are never visited, so their output blocks
are undefined on exit; a ``jnp.where`` epilogue pins them to the
mathematically correct zeros.

Two operand precisions share the schedule machinery:

  * :func:`gmm_pallas_wgrad` — operands arrive un-quantized (bf16/f32):
    DeepSeek-V3 (and the paper) keep wgrad at the highest precision of the
    three training GEMMs, so there is no scale bookkeeping — just f32
    accumulation of bf16 products, matching ``compat.ragged_wgrad``
    numerics.  This is the default.
  * :func:`gmm_pallas_wgrad_fp8` — the all-fp8 step of arXiv 2505.20524:
    x and dy arrive as fp8 with their 1x128 per-row tile scales (the SAME
    ``(a8, sa)`` the forward GEMM consumed and the SAME ``(d8, sd)`` the
    dgrad quantized — nothing is re-quantized for the wgrad).  Each visit
    dequantizes its owned rows on the fly: the scale-multiply is folded
    into the masked ``jnp.where`` prologue, so unowned/garbage rows are
    zeroed and owned rows are rescaled in one VPU pass before the
    f32-accumulated transposed dot.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels.plan import (QUANT_BLOCK, KernelConfig, TilePlan,
                                make_tile_plan)


def _visit_bookkeeping(group_offsets_ref, group_ids_ref, m_tile_ids_ref,
                       *, block_m, max_visits):
    """Shared per-visit schedule logic of BOTH wgrad kernels (bf16 and
    fp8 operands walk the identical visitation schedule).

    Returns ``(first, last, owned)``:

      * ``first``/``last`` — visit-run boundaries: group_ids is
        non-decreasing, so a group's visits are adjacent and its output
        block stays resident in VMEM between them;
      * ``owned`` — (block_m, 1) row mask: rows of this M-tile inside the
        visit's group range, with *duplicate* padding visits masked out
        entirely (padding visits with no tail tiles to sweep replicate
        the last real visit; re-accumulating it would double-count).
    """
    t = pl.program_id(2)
    g = group_ids_ref[t]
    m_tile = m_tile_ids_ref[t]
    prev_g = group_ids_ref[jnp.maximum(t - 1, 0)]
    prev_tile = m_tile_ids_ref[jnp.maximum(t - 1, 0)]
    next_g = group_ids_ref[jnp.minimum(t + 1, max_visits - 1)]

    first = (t == 0) | (g != prev_g)
    last = (t == max_visits - 1) | (next_g != g)
    dup = (t > 0) & (g == prev_g) & (m_tile == prev_tile)

    start = group_offsets_ref[g]
    end = group_offsets_ref[g + 1]
    rows = m_tile * block_m + jax.lax.broadcasted_iota(
        jnp.int32, (block_m, 1), 0)
    owned = (rows >= start) & (rows < end) & jnp.logical_not(dup)
    return first, last, owned


def _zero_empty_groups(dw, plan, out_dtype):
    """Empty groups are never visited, so their output blocks are
    undefined on exit — pin them to the mathematically correct zeros
    (shared epilogue of both wgrad drivers)."""
    nonempty = (plan.group_offsets[1:] - plan.group_offsets[:-1]) > 0
    return jnp.where(nonempty[:, None, None], dw, jnp.zeros((), out_dtype))


def _run_ragged_contraction(kernel_body, operands, in_specs, group_sizes, *,
                            m, k, n, num_groups, block_m, block_n, block_k,
                            out_dtype, interpret, plan,
                            n_span=1, k_span=1):
    """Shared driver of both wgrad precisions: M=0 short-circuit,
    plan-or-build, the (K super-tiles, N super-tiles, visits) grid, the
    pallas_call scaffold (dense [G, K, N] output, f32 super-tile
    accumulator scratch, parallel/parallel/arbitrary semantics), and the
    empty-group epilogue.  The precision variants differ only in their
    operand list + BlockSpecs and the kernel body; everything
    scheduling-related lives HERE once."""
    if m == 0:
        return jnp.zeros((num_groups, k, n), out_dtype)
    if plan is None:
        plan = make_tile_plan(group_sizes, m, block_m=block_m,
                              num_groups=num_groups)
    wk = block_k * k_span
    wn = block_n * n_span
    grid = (k // wk, n // wn, plan.max_visits)
    kernel = functools.partial(
        kernel_body, block_m=block_m, block_k=block_k, block_n=block_n,
        max_visits=plan.max_visits, out_dtype=out_dtype,
        n_span=n_span, k_span=k_span)
    dw = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                (1, wk, wn),
                lambda k_i, n_i, t, go, gi, mi: (gi[t], k_i, n_i)),
            scratch_shapes=[pltpu.VMEM((wk, wn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((num_groups, k, n), out_dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(plan.group_offsets, plan.group_ids, plan.m_tile_ids, *operands)
    return _zero_empty_groups(dw, plan, out_dtype)


def _span_accumulate(acc_ref, x, dy, *, block_k, block_n, n_span, k_span):
    """Accumulate every (block_k, block_n) sub-tile dot of one visit into
    the f32 super-tile accumulator.  The sub-tile dots are EXACTLY the
    single-tile kernel's per-(k, n)-cell dots — same operand shapes, same
    values, same per-cell f32 addition order across visits — assembled
    into one super-tile update, so any span is bitwise-equal to span 1.
    ``x``/``dy`` are the visit's masked f32 operand tiles, ``(block_m,
    k_span*block_k)`` and ``(block_m, n_span*block_n)``, already resident
    in VMEM — the static sub-tile loop re-slices them instead of
    re-fetching from HBM."""
    rows = []
    for kk in range(k_span):
        xs = x[:, kk * block_k:(kk + 1) * block_k]
        cells = [
            jax.lax.dot_general(
                xs, dy[:, nn * block_n:(nn + 1) * block_n],
                dimension_numbers=(((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            for nn in range(n_span)
        ]
        rows.append(cells[0] if n_span == 1
                    else jnp.concatenate(cells, axis=1))
    update = rows[0] if k_span == 1 else jnp.concatenate(rows, axis=0)
    acc_ref[...] += update


def _gmm_wgrad_kernel(group_offsets_ref, group_ids_ref, m_tile_ids_ref,
                      x_ref, dy_ref,                     # VMEM in
                      out_ref,                           # VMEM out
                      acc_ref,                           # scratch
                      *, block_m, block_k, block_n, max_visits, out_dtype,
                      n_span, k_span):
    first, last, owned = _visit_bookkeeping(
        group_offsets_ref, group_ids_ref, m_tile_ids_ref,
        block_m=block_m, max_visits=max_visits)

    @pl.when(first)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # mask BOTH operands: rows beyond M (the block-padded tail of the last
    # tile) or beyond sum(group_sizes) may hold garbage/NaN, and 0 * NaN
    # would still poison the accumulation.  One mask covers the whole
    # fetched span tile — the sub-tile loop slices the resident copy.
    x = jnp.where(owned, x_ref[...].astype(jnp.float32), 0.0)    # (bm, wk)
    dy = jnp.where(owned, dy_ref[...].astype(jnp.float32), 0.0)  # (bm, wn)
    _span_accumulate(acc_ref, x, dy, block_k=block_k, block_n=block_n,
                     n_span=n_span, k_span=k_span)

    @pl.when(last)
    def _store():
        out_ref[0] = acc_ref[...].astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("num_groups", "block_m", "block_n", "block_k",
                     "out_dtype", "interpret", "n_span", "k_span"))
def gmm_pallas_wgrad(x: jax.Array, dy: jax.Array, group_sizes: jax.Array, *,
                     num_groups: int | None = None,
                     block_m: int = 128, block_n: int = 128,
                     block_k: int = 128,
                     out_dtype: Any = jnp.float32, interpret: bool = False,
                     plan: TilePlan | None = None,
                     n_span: int = 1, k_span: int = 1):
    """Padding-free ragged-contraction grouped GEMM (wgrad orientation).

    x:  [M, K] float — concatenated groups, arbitrary (ragged) M^g,
        ``sum(group_sizes) <= M`` (rows beyond the last group, and any
        garbage they hold, are excluded from the contraction)
    dy: [M, N] float — upstream gradient over the same row buffer
    group_sizes: [G] int32
    plan: optional precomputed :class:`TilePlan` for this
        ``(group_sizes, M)`` — the SAME plan the forward/dgrad GEMMs of
        this routing decision used (the schedule is orientation-agnostic).
        When given, its ``block_m`` governs the contraction tiling and the
        ``block_m`` argument is ignored.  The usual TilePlan contract
        applies: it must have been built from these ``group_sizes``.
    n_span/k_span: multi-tile schedule — one grid cell owns a
        ``(k_span*block_k, n_span*block_n)`` output super-tile and keeps
        its x/dy operand tiles VMEM-resident across the sub-tiles, so x
        is fetched once per ``n_span`` N steps and dy once per ``k_span``
        K steps.  Bitwise-equal to span 1 (the per-cell dots and their
        accumulation order are unchanged); K must divide by
        ``block_k*k_span`` and N by ``block_n*n_span``.
    returns [G, K, N] out_dtype with ``dw[g] = x_g^T @ dy_g`` in f32
        accumulation; groups with zero rows come back exactly zero.
    """
    m, k = x.shape
    m2, n = dy.shape
    if m != m2:
        raise ValueError(
            f"x and dy disagree on M: x is [M={m}, K={k}] but dy is "
            f"[M={m2}, N={n}]")
    num_groups = num_groups or group_sizes.shape[0]
    if plan is not None:
        block_m = plan.block_m
        plan.check_against(m, block_m, num_groups)
    KernelConfig(block_m=block_m, block_n=block_n, block_k=block_k,
                 n_span=n_span, k_span=k_span).validate(m, k, n,
                                                        family="wgrad")

    wk = block_k * k_span
    wn = block_n * n_span
    in_specs = [
        # x tile: globally block-aligned copy of the visit's M-tile,
        # K-span slice (resident across the super-tile's N sub-steps)
        pl.BlockSpec((block_m, wk),
                     lambda k_i, n_i, t, go, gi, mi: (mi[t], k_i)),
        # dy tile: same M-tile, N-span slice
        pl.BlockSpec((block_m, wn),
                     lambda k_i, n_i, t, go, gi, mi: (mi[t], n_i)),
    ]
    return _run_ragged_contraction(
        _gmm_wgrad_kernel, (x, dy), in_specs, group_sizes,
        m=m, k=k, n=n, num_groups=num_groups, block_m=block_m,
        block_n=block_n, block_k=block_k, out_dtype=out_dtype,
        interpret=interpret, plan=plan, n_span=n_span, k_span=k_span)


# ---------------------------------------------------------------------------
# fp8-operand variant (arXiv 2505.20524: the all-fp8 training step)
# ---------------------------------------------------------------------------

def _gmm_wgrad_fp8_kernel(group_offsets_ref, group_ids_ref, m_tile_ids_ref,
                          x_ref, sx_ref, dy_ref, sdy_ref,   # VMEM in
                          out_ref,                          # VMEM out
                          acc_ref,                          # scratch
                          *, block_m, block_k, block_n, max_visits,
                          out_dtype, n_span, k_span):
    k_i = pl.program_id(0)
    n_i = pl.program_id(1)
    first, last, owned = _visit_bookkeeping(
        group_offsets_ref, group_ids_ref, m_tile_ids_ref,
        block_m=block_m, max_visits=max_visits)

    @pl.when(first)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # per-row 1x128 tile scales for this visit's K-span / N-span slice
    # (whole scale rows travel on the M-tile like the forward's S_A
    # over-fetch; the span widens the slice, not the fetch)
    kq = block_k // QUANT_BLOCK
    nq = block_n // QUANT_BLOCK
    sx = jax.lax.dynamic_slice(sx_ref[...], (0, k_i * k_span * kq),
                               (block_m, k_span * kq))
    sdy = jax.lax.dynamic_slice(sdy_ref[...], (0, n_i * n_span * nq),
                                (block_m, n_span * nq))
    sx_full = jnp.repeat(sx, QUANT_BLOCK, axis=1)       # (bm, wk)
    sdy_full = jnp.repeat(sdy, QUANT_BLOCK, axis=1)     # (bm, wn)

    # dequantize-on-visit with the scale-multiply folded into the masked
    # prologue: one jnp.where zeroes unowned rows (whose fp8 payload AND
    # scale rows may be garbage — 0 * NaN would poison the accumulation)
    # and rescales owned ones, then the sub-tile dots accumulate in f32
    x = jnp.where(owned, x_ref[...].astype(jnp.float32) * sx_full, 0.0)
    dy = jnp.where(owned, dy_ref[...].astype(jnp.float32) * sdy_full, 0.0)
    _span_accumulate(acc_ref, x, dy, block_k=block_k, block_n=block_n,
                     n_span=n_span, k_span=k_span)

    @pl.when(last)
    def _store():
        out_ref[0] = acc_ref[...].astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("num_groups", "block_m", "block_n", "block_k",
                     "out_dtype", "interpret", "n_span", "k_span"))
def gmm_pallas_wgrad_fp8(x_fp8: jax.Array, s_x: jax.Array,
                         dy_fp8: jax.Array, s_dy: jax.Array,
                         group_sizes: jax.Array, *,
                         num_groups: int | None = None,
                         block_m: int = 128, block_n: int = 128,
                         block_k: int = 128,
                         out_dtype: Any = jnp.float32,
                         interpret: bool = False,
                         plan: TilePlan | None = None,
                         n_span: int = 1, k_span: int = 1):
    """Padding-free ragged-contraction grouped GEMM with fp8 operands.

    x_fp8:  [M, K]  fp8 e4m3 — the forward's quantized activation (the
            VJP residual; NOT re-quantized for the wgrad)
    s_x:    [M, KB] f32 — its 1x128 tile scales (KB = ceil(K/128))
    dy_fp8: [M, N]  fp8 e4m3 — the upstream gradient as quantized for the
            dgrad (one ``quantize_tilewise(dy)`` serves both backward GEMMs)
    s_dy:   [M, NB] f32 — its 1x128 tile scales (NB = ceil(N/128))
    group_sizes: [G] int32, ``sum <= M`` (tail rows excluded)
    plan:   optional precomputed :class:`TilePlan` — the SAME plan every
            other GEMM of this routing decision used; its ``block_m``
            governs the contraction tiling when given.
    n_span/k_span: multi-tile schedule (see :func:`gmm_pallas_wgrad`) —
            the scale rows stay resident with their operand tile, so the
            span cuts the scale-row re-fetch too.
    returns [G, K, N] out_dtype with ``dw[g] = x_g^T @ dy_g`` where each
            visit dequantizes its owned rows (scale-multiply in the masked
            prologue) before the f32-accumulated transposed dot; groups
            with zero rows come back exactly zero.
    """
    m, k = x_fp8.shape
    m2, n = dy_fp8.shape
    if m != m2:
        raise ValueError(
            f"x and dy disagree on M: x_fp8 is [M={m}, K={k}] but dy_fp8 "
            f"is [M={m2}, N={n}]")
    kb = (k + QUANT_BLOCK - 1) // QUANT_BLOCK
    nb = (n + QUANT_BLOCK - 1) // QUANT_BLOCK
    if s_x.shape != (m, kb):
        raise ValueError(
            f"s_x must be [M={m}, ceil(K/{QUANT_BLOCK})={kb}], got "
            f"{s_x.shape} (x_fp8 {x_fp8.shape})")
    if s_dy.shape != (m, nb):
        raise ValueError(
            f"s_dy must be [M={m}, ceil(N/{QUANT_BLOCK})={nb}], got "
            f"{s_dy.shape} (dy_fp8 {dy_fp8.shape})")
    num_groups = num_groups or group_sizes.shape[0]
    if plan is not None:
        block_m = plan.block_m
        plan.check_against(m, block_m, num_groups)
    KernelConfig(block_m=block_m, block_n=block_n, block_k=block_k,
                 wgrad_precision="fp8", n_span=n_span,
                 k_span=k_span).validate(m, k, n, family="wgrad")

    wk = block_k * k_span
    wn = block_n * n_span
    in_specs = [
        # x tile: the visit's M-tile, K-span slice (fp8 payload, resident
        # across the super-tile's N sub-steps)
        pl.BlockSpec((block_m, wk),
                     lambda k_i, n_i, t, go, gi, mi: (mi[t], k_i)),
        # S_x: whole scale row per M-tile (forward-style over-fetch,
        # padded to the 128-lane VMEM tile)
        pl.BlockSpec((block_m, kb),
                     lambda k_i, n_i, t, go, gi, mi: (mi[t], 0)),
        # dy tile: same M-tile, N-span slice (fp8 payload)
        pl.BlockSpec((block_m, wn),
                     lambda k_i, n_i, t, go, gi, mi: (mi[t], n_i)),
        # S_dy: whole scale row per M-tile
        pl.BlockSpec((block_m, nb),
                     lambda k_i, n_i, t, go, gi, mi: (mi[t], 0)),
    ]
    return _run_ragged_contraction(
        _gmm_wgrad_fp8_kernel, (x_fp8, s_x, dy_fp8, s_dy), in_specs,
        group_sizes, m=m, k=k, n=n, num_groups=num_groups, block_m=block_m,
        block_n=block_n, block_k=block_k, out_dtype=out_dtype,
        interpret=interpret, plan=plan, n_span=n_span, k_span=k_span)
