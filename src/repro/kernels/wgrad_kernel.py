"""Ragged-contraction (wgrad) grouped GEMM — Pallas TPU kernel.

``dw[g] = x_g^T @ dy_g`` where the *contraction* dimension is the ragged M
axis: groups own contiguous, dynamically-sized row ranges of the
concatenated token buffer, and each group's rows contract against the same
rows of the upstream gradient.  This is the last GEMM of the fp8 training
step (paper's training workload) and the ROADMAP's "N-side raggedness"
item — before this kernel the backward detoured through XLA's
``ragged_dot_general`` fallback (``compat.ragged_wgrad``).

The forward kernel's insight transfers unchanged: the *schedule* depends
only on ``(group_sizes, M, block_m)``, so the same :class:`TilePlan` built
once per routing decision serves gate/up/down forwards, both dgrads, and
every wgrad.  What changes is the role of a visit:

  * the grid walks ``(K tiles, N tiles, visits)`` with the visit axis
    innermost; visit t touches M-tile ``m_tile_ids[t]`` on behalf of group
    ``group_ids[t]``;
  * instead of a masked *store* of an output row tile, each visit performs
    a masked *accumulation* into the group's dense ``[block_k, block_n]``
    output tile: rows of the M-tile owned by other groups (or beyond
    ``sum(group_sizes)``) are zeroed before the transposed dot, so
    boundary tiles contribute exactly their owned rows;
  * consecutive visits of one group share the output block (``group_ids``
    is non-decreasing), so Pallas keeps it resident in VMEM across the
    group's M-tiles and flushes once per group — the accumulation analogue
    of the forward's "safe overlapping write";
  * padding visits either sweep tail tiles (no owned rows -> zero
    contribution) or duplicate the last real visit (detected by comparing
    ``(group_ids, m_tile_ids)`` against the previous visit and skipped —
    accumulation, unlike the forward's store, is not idempotent).

Groups that receive zero rows are never visited, so their output blocks
are undefined on exit; a ``jnp.where`` epilogue pins them to the
mathematically correct zeros.

Operands arrive un-quantized (bf16/f32): DeepSeek-V3 (and the paper) keep
wgrad at the highest precision of the three training GEMMs, so there is no
scale bookkeeping here — just f32 accumulation of bf16 products, matching
``compat.ragged_wgrad`` numerics.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels.plan import KernelConfig, TilePlan, make_tile_plan


def _gmm_wgrad_kernel(group_offsets_ref, group_ids_ref, m_tile_ids_ref,
                      x_ref, dy_ref,                     # VMEM in
                      out_ref,                           # VMEM out
                      acc_ref,                           # scratch
                      *, block_m, block_k, block_n, max_visits, out_dtype):
    t = pl.program_id(2)

    g = group_ids_ref[t]
    m_tile = m_tile_ids_ref[t]
    prev_g = group_ids_ref[jnp.maximum(t - 1, 0)]
    prev_tile = m_tile_ids_ref[jnp.maximum(t - 1, 0)]
    next_g = group_ids_ref[jnp.minimum(t + 1, max_visits - 1)]

    # visit-run boundaries: group_ids is non-decreasing, so a group's
    # visits are adjacent and its output block stays resident between them
    first = (t == 0) | (g != prev_g)
    last = (t == max_visits - 1) | (next_g != g)
    # padding visits with no tail tiles to sweep replicate the last real
    # visit; re-accumulating it would double-count — skip duplicates
    dup = (t > 0) & (g == prev_g) & (m_tile == prev_tile)

    @pl.when(first)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = group_offsets_ref[g]
    end = group_offsets_ref[g + 1]
    rows = m_tile * block_m + jax.lax.broadcasted_iota(
        jnp.int32, (block_m, 1), 0)
    owned = (rows >= start) & (rows < end) & jnp.logical_not(dup)

    # mask BOTH operands: rows beyond M (the block-padded tail of the last
    # tile) or beyond sum(group_sizes) may hold garbage/NaN, and 0 * NaN
    # would still poison the accumulation
    x = jnp.where(owned, x_ref[...].astype(jnp.float32), 0.0)    # (bm, bk)
    dy = jnp.where(owned, dy_ref[...].astype(jnp.float32), 0.0)  # (bm, bn)
    acc_ref[...] += jax.lax.dot_general(
        x, dy, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(last)
    def _store():
        out_ref[0] = acc_ref[...].astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("num_groups", "block_m", "block_n", "block_k",
                     "out_dtype", "interpret"))
def gmm_pallas_wgrad(x: jax.Array, dy: jax.Array, group_sizes: jax.Array, *,
                     num_groups: int | None = None,
                     block_m: int = 128, block_n: int = 128,
                     block_k: int = 128,
                     out_dtype: Any = jnp.float32, interpret: bool = False,
                     plan: TilePlan | None = None):
    """Padding-free ragged-contraction grouped GEMM (wgrad orientation).

    x:  [M, K] float — concatenated groups, arbitrary (ragged) M^g,
        ``sum(group_sizes) <= M`` (rows beyond the last group, and any
        garbage they hold, are excluded from the contraction)
    dy: [M, N] float — upstream gradient over the same row buffer
    group_sizes: [G] int32
    plan: optional precomputed :class:`TilePlan` for this
        ``(group_sizes, M)`` — the SAME plan the forward/dgrad GEMMs of
        this routing decision used (the schedule is orientation-agnostic).
        When given, its ``block_m`` governs the contraction tiling and the
        ``block_m`` argument is ignored.  The usual TilePlan contract
        applies: it must have been built from these ``group_sizes``.
    returns [G, K, N] out_dtype with ``dw[g] = x_g^T @ dy_g`` in f32
        accumulation; groups with zero rows come back exactly zero.
    """
    m, k = x.shape
    m2, n = dy.shape
    if m != m2:
        raise ValueError(
            f"x and dy disagree on M: x is [M={m}, K={k}] but dy is "
            f"[M={m2}, N={n}]")
    num_groups = num_groups or group_sizes.shape[0]
    if plan is not None:
        block_m = plan.block_m
        plan.check_against(m, block_m, num_groups)
    KernelConfig(block_m=block_m, block_n=block_n,
                 block_k=block_k).validate(m, k, n)

    if m == 0:
        return jnp.zeros((num_groups, k, n), out_dtype)

    if plan is None:
        plan = make_tile_plan(group_sizes, m, block_m=block_m,
                              num_groups=num_groups)
    grid = (k // block_k, n // block_n, plan.max_visits)

    kernel = functools.partial(
        _gmm_wgrad_kernel, block_m=block_m, block_k=block_k,
        block_n=block_n, max_visits=plan.max_visits, out_dtype=out_dtype)

    def _run_kernel(group_offsets, group_ids, m_tile_ids):
        return pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=3,
                grid=grid,
                in_specs=[
                    # x tile: globally block-aligned copy of the visit's
                    # M-tile, K-slice
                    pl.BlockSpec((block_m, block_k),
                                 lambda k_i, n_i, t, go, gi, mi: (mi[t], k_i)),
                    # dy tile: same M-tile, N-slice
                    pl.BlockSpec((block_m, block_n),
                                 lambda k_i, n_i, t, go, gi, mi: (mi[t], n_i)),
                ],
                out_specs=pl.BlockSpec(
                    (1, block_k, block_n),
                    lambda k_i, n_i, t, go, gi, mi: (gi[t], k_i, n_i)),
                scratch_shapes=[pltpu.VMEM((block_k, block_n), jnp.float32)],
            ),
            out_shape=jax.ShapeDtypeStruct((num_groups, k, n), out_dtype),
            compiler_params=compat.tpu_compiler_params(
                dimension_semantics=("parallel", "parallel", "arbitrary"),
            ),
            interpret=interpret,
        )(group_offsets, group_ids, m_tile_ids, x, dy)

    dw = _run_kernel(plan.group_offsets, plan.group_ids, plan.m_tile_ids)
    # empty groups are never visited, so their output blocks are undefined
    # on exit — pin them to the mathematically correct zeros
    nonempty = (plan.group_offsets[1:] - plan.group_offsets[:-1]) > 0
    return jnp.where(nonempty[:, None, None], dw,
                     jnp.zeros((), out_dtype))
