"""Static kernel-resource model: per-program VMEM footprints + feasibility.

The paper's second pillar is *static* TMA-alignment-aware management:
every descriptor's tile geometry is decided before launch, against known
alignment (16B global / 128B shared) and SMEM budgets.  This module is
the Pallas/TPU analogue — a pure-arithmetic model of what one kernel
program keeps resident in VMEM under a given ``(block_m, block_n,
block_k)`` geometry, mirroring the BlockSpecs the kernels in this
package actually declare:

* grouped GEMM (``gmm_pallas``): A tile ``(bm, bk)`` fp8, the whole S_A
  scale row ``(bm, ceil(K/128))`` f32 (over-fetched per M-tile), B tile
  ``(bk, bn)`` fp8, S_B block ``(ceil(K/128), ceil(N/128))`` f32, the
  output tile, and one f32 accumulator scratch ``(bm, bn)``;
* the quantizing-epilogue twin (``gmm_pallas_quant``): fp8 payload tile
  + ``(bm, bn/128)`` f32 scale tile instead of the wide output;
* ragged wgrad: x ``(bm, k_span*bk)`` / dy ``(bm, n_span*bn)`` operand
  tiles (bf16, or fp8 + their 1x128 scale rows) — the multi-tile spans
  keep each operand tile VMEM-resident across the sub-tiles of one
  ``(k_span*bk, n_span*bn)`` output super-tile — plus that super-tile's
  f32 dw block and accumulator;
* tilewise quantize / fused act_quant: whole-K row blocks ``(bm, K)``
  (one input for quantize, gate AND up for the fused epilogue) plus the
  fp8 payload and f32 scale outputs.

Tiles are costed at the TPU's physical VMEM layout (last dim padded to
128 lanes, second-to-last to the dtype's sublane granularity), and
pipelined blocks are double-buffered (:data:`PIPELINE_BUFFERS`) — the
standard Pallas grid pipeline keeps the next block in flight while the
current one computes.

Consumers: ``analysis/resource_lint.py`` proves every pool entry fits
every device budget (REPRO-V01..V07); ``plan.autotune`` prunes
statically-infeasible candidates before measuring; and
``KernelConfig.validate`` raises with the computed footprint instead of
letting Mosaic fail opaquely at compile time.

Stdlib-only — no jax import, so the budget math runs device-free (the
CI's fast pre-suite lint step).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

#: bump when the footprint formulas or budgets change: the autotune JSON
#: cache namespaces its keys by this, so selections made under an older
#: model (e.g. pre-pruning) are ignored rather than trusted
RESOURCE_MODEL_VERSION = 3

QUANT_BLOCK = 128   # 1x128 / 128x128 scale granularity (must agree with
                    # plan/ref/quantization — REPRO-R06 checks the set)
LANE = 128          # VMEM lane width: last tile dim pads to this
MXU_M = 128         # rows of one MXU pass (cost + degeneracy granularity)

#: pipelined in/out blocks are double-buffered by the Pallas grid
#: pipeline; scratch (accumulators) is single-buffered
PIPELINE_BUFFERS = 2

#: decode pool entries never exceed this tile height (serving M is
#: batch*top_k rows TOTAL; see plan.DECODE_BLOCK_MS)
DECODE_MAX_BLOCK_M = 16

#: per-device VMEM budget in bytes (the ``plan.DEVICE_SPECS`` limit).
#: TPU VMEM is ~16 MiB/core on v5e-class parts and double that on the
#: larger v4/v5p parts; the "cpu" (interpret-mode) entry carries the
#: TIGHTEST real budget so configs tuned on CPU CI transfer to any TPU.
VMEM_BYTES: "Dict[str, int]" = {
    "tpu v5 lite": 16 * 2**20,
    "tpu v5e": 16 * 2**20,
    "tpu": 32 * 2**20,
    "cpu": 16 * 2**20,
}

#: footprint-modelled operator families (dispatch families map 1:1)
FAMILIES = ("gemm", "gemm_quant", "wgrad", "quantize", "act_quant")


def vmem_budget(device_kind: str) -> int:
    """VMEM budget for a device kind, longest-prefix matched (mirrors
    ``plan.device_spec``'s matching so ``"TPU v5 lite"`` hits the v5e
    entry)."""
    kind = device_kind.lower()
    best = None
    for prefix, budget in VMEM_BYTES.items():
        if kind.startswith(prefix) and (best is None or len(prefix) > best[0]):
            best = (len(prefix), budget)
    return best[1] if best is not None else VMEM_BYTES["cpu"]


# ---------------------------------------------------------------------------
# Tile arithmetic
# ---------------------------------------------------------------------------

def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _round_up(x: int, mult: int) -> int:
    return _ceil_div(x, mult) * mult


def sublane(itemsize: int) -> int:
    """Second-to-last-dim granularity of a VMEM tile: 8 sublanes of
    32-bit lanes — 8 rows for f32, 16 for bf16, 32 for fp8/int8."""
    return max(8 * (4 // max(itemsize, 1)), 8)


def tile_bytes(rows: int, cols: int, itemsize: int) -> int:
    """Bytes one ``(rows, cols)`` block occupies in VMEM at its physical
    tiling (cols padded to the 128-lane width, rows to the dtype's
    sublane granularity)."""
    return (_round_up(max(rows, 1), sublane(itemsize))
            * _round_up(max(cols, 1), LANE) * itemsize)


def config_blocks(config: Any) -> "Tuple[int, int, int]":
    """``(block_m, block_n, block_k)`` from a KernelConfig-like object or
    a plain dict (fixtures use dicts — a misaligned geometry cannot even
    construct a KernelConfig)."""
    if isinstance(config, dict):
        return (int(config["block_m"]), int(config.get("block_n", 128)),
                int(config.get("block_k", 128)))
    return (int(config.block_m), int(config.block_n), int(config.block_k))


def config_spans(config: Any) -> "Tuple[int, int]":
    """``(n_span, k_span)`` multi-tile wgrad spans from a KernelConfig-like
    object or a plain dict; absent fields mean the single-tile schedule."""
    if isinstance(config, dict):
        return (int(config.get("n_span", 1)), int(config.get("k_span", 1)))
    return (int(getattr(config, "n_span", 1)),
            int(getattr(config, "k_span", 1)))


def _totals(pipelined: "Dict[str, int]",
            scratch: "Dict[str, int]") -> "Dict[str, Any]":
    buffers = {**{name: b * PIPELINE_BUFFERS for name, b in pipelined.items()},
               **scratch}
    single = sum(pipelined.values()) + sum(scratch.values())
    return {"buffers": buffers,
            "total": sum(buffers.values()),
            "total_single": single}


# ---------------------------------------------------------------------------
# Per-family footprints (bytes resident per kernel program)
# ---------------------------------------------------------------------------

def gemm_footprint(block_m: int, block_n: int, block_k: int, *,
                   k: int, n: int, out_itemsize: int = 2,
                   quant_output: bool = False,
                   precision: str = "fp8") -> "Dict[str, Any]":
    """Grouped-GEMM per-program VMEM residency under the kernel's actual
    BlockSpecs.  The S_A/S_B scale fetches are *whole rows/blocks* per
    M-tile (shape-dependent: ``ceil(K/128)`` columns), so the footprint
    grows with K even at fixed tile geometry.  ``quant_output`` models
    the fused quantizing epilogue: the wide output tile is replaced by
    the fp8 payload + its ``(bm, bn/128)`` f32 scale tile.
    ``precision="bf16"`` models the true-bf16 kernel (``gmm_pallas_bf16``):
    2-byte operand tiles and no scale buffers at all."""
    kb = _ceil_div(k, QUANT_BLOCK)
    nb = _ceil_div(n, QUANT_BLOCK)
    if precision == "bf16":
        pipelined = {
            "a_tile": tile_bytes(block_m, block_k, 2),
            "b_tile": tile_bytes(block_k, block_n, 2),
        }
    else:
        pipelined = {
            "a_tile": tile_bytes(block_m, block_k, 1),
            "s_a_row": tile_bytes(block_m, kb, 4),
            "b_tile": tile_bytes(block_k, block_n, 1),
            "s_b_block": tile_bytes(kb, nb, 4),
        }
    if quant_output:
        pipelined["out_payload"] = tile_bytes(block_m, block_n, 1)
        pipelined["out_scales"] = tile_bytes(
            block_m, _ceil_div(block_n, QUANT_BLOCK), 4)
    else:
        pipelined["out_tile"] = tile_bytes(block_m, block_n, out_itemsize)
    scratch = {"acc_f32": tile_bytes(block_m, block_n, 4)}
    return _totals(pipelined, scratch)


def wgrad_footprint(block_m: int, block_n: int, block_k: int, *,
                    k: int, n: int, precision: str = "bf16",
                    n_span: int = 1, k_span: int = 1) -> "Dict[str, Any]":
    """Ragged-contraction (wgrad) per-program residency: x/dy operand
    tiles (bf16, or fp8 + their whole 1x128 scale rows), the f32 dw
    output block, and its accumulator scratch.  The multi-tile spans
    widen every block: one program owns a ``(k_span*bk, n_span*bn)``
    output super-tile and holds the matching ``(bm, k_span*bk)`` x and
    ``(bm, n_span*bn)`` dy operand tiles VMEM-resident across its
    sub-tiles — that residency is exactly what the wider footprint pays
    for the ``k_span``/``n_span``-fold fetch reduction."""
    fp8 = precision == "fp8"
    it = 1 if fp8 else 2
    wk = block_k * k_span
    wn = block_n * n_span
    pipelined = {
        "x_tile": tile_bytes(block_m, wk, it),
        "dy_tile": tile_bytes(block_m, wn, it),
        "dw_tile": tile_bytes(wk, wn, 4),
    }
    if fp8:
        pipelined["s_x_row"] = tile_bytes(block_m, _ceil_div(k, QUANT_BLOCK), 4)
        pipelined["s_dy_row"] = tile_bytes(block_m, _ceil_div(n, QUANT_BLOCK), 4)
    scratch = {"acc_f32": tile_bytes(wk, wn, 4)}
    return _totals(pipelined, scratch)


def quantize_footprint(block_m: int, *, k: int, m: Optional[int] = None,
                       fused: bool = False,
                       in_itemsize: Optional[int] = None) -> "Dict[str, Any]":
    """Tilewise-quantize / fused act_quant per-program residency: the
    kernels block over M only and keep whole-K rows resident.  ``fused``
    models the activation epilogue's EXTRA buffer — it reads the gate AND
    up producer outputs (two inputs) where the plain quantizer reads one.
    The kernel clamps its tile height to M (pass ``m``) exactly like
    ``act_quantize_pallas`` does."""
    if m is not None:
        block_m = min(block_m, max(8, m))
    kb = _ceil_div(k, QUANT_BLOCK)
    if in_itemsize is None:
        in_itemsize = 2 if fused else 4     # bf16 producer outputs / f32 in
    pipelined = {
        "in_rows": (2 if fused else 1) * tile_bytes(block_m, k, in_itemsize),
        "out_payload": tile_bytes(block_m, k, 1),
        "out_scales": tile_bytes(block_m, kb, 4),
    }
    return _totals(pipelined, {})


def footprint(family: str, config: Any, *, m: int, k: int, n: int,
              out_itemsize: int = 2,
              wgrad_precision: Optional[str] = None,
              gemm_precision: Optional[str] = None) -> "Dict[str, Any]":
    """Per-program VMEM footprint of ``family`` under ``config`` at shape
    ``(m, k, n)``.  ``config`` is a KernelConfig-like object or a plain
    ``{"block_m": ..}`` dict.  Returns ``{"buffers", "total",
    "total_single"}`` — ``total`` is double-buffered (the pipelined
    steady state), ``total_single`` the unpipelined floor.
    ``gemm_precision="bf16"`` selects the true-bf16 kernel's operand
    buffers; the wgrad family reads the config's multi-tile spans."""
    bm, bn, bk = config_blocks(config)
    if family in ("gemm", "gemm_quant"):
        return gemm_footprint(bm, bn, bk, k=k, n=n,
                              out_itemsize=out_itemsize,
                              quant_output=family == "gemm_quant",
                              precision=gemm_precision or "fp8")
    if family == "wgrad":
        prec = wgrad_precision
        if prec is None:
            prec = (config.get("wgrad_precision", "bf16")
                    if isinstance(config, dict)
                    else getattr(config, "wgrad_precision", "bf16"))
        ns, ks = config_spans(config)
        return wgrad_footprint(bm, bn, bk, k=k, n=n, precision=prec,
                               n_span=ns, k_span=ks)
    if family in ("quantize", "act_quant"):
        return quantize_footprint(bm, k=k, m=m, fused=family == "act_quant")
    raise ValueError(f"no footprint model for operator family {family!r}; "
                     f"modelled families: {FAMILIES}")


# ---------------------------------------------------------------------------
# Static feasibility checks (shared by the lint and the autotune pruner)
# ---------------------------------------------------------------------------

def alignment_issues(config: Any) -> "List[Tuple[str, str]]":
    """``(code, message)`` pairs for the paper's 16B/128B-analogue static
    alignment rules: sublane (block_m % 8), lane (block_n % 128), and
    scale-tile integrality (block_k % QUANT_BLOCK — a tile must cover a
    whole number of 1x128 scale columns)."""
    bm, bn, bk = config_blocks(config)
    out = []
    if bm % 8:
        out.append(("sublane", f"block_m={bm} is not a multiple of 8 "
                               f"(sublane granularity)"))
    if bn % LANE:
        out.append(("lane", f"block_n={bn} is not a multiple of {LANE} "
                            f"(lane width / fp8 payload row alignment)"))
    if bk % QUANT_BLOCK:
        out.append(("quant", f"block_k={bk} is not a multiple of "
                             f"QUANT_BLOCK={QUANT_BLOCK} — the tile would "
                             f"cover a fractional 1x128 scale column"))
    return out


def degeneracy_issues(config: Any, *, m: int, k: int, n: int,
                      elementwise: bool = False,
                      n_span: int = 1, k_span: int = 1) -> "List[str]":
    """Grid-degeneracy hazards at a concrete shape: a tile wider than the
    operand it walks (zero or fractional grid steps), or an M tile so
    tall the grid degenerates to one mostly-empty visit (``block_m >=
    2*M`` — the half-size tile covers the same rows in the same number of
    visits at half the fetch).  Elementwise kernels clamp their tile
    height to M, so only the GEMM-shaped families carry the M hazard.
    The wgrad caller passes its multi-tile spans: the grid steps by whole
    ``(k_span*bk, n_span*bn)`` super-tiles, so a span that outgrows the
    operand is degenerate even when the base tile fits."""
    bm, bn, bk = config_blocks(config)
    bn, bk = bn * n_span, bk * k_span
    span_n = f" * n_span={n_span}" if n_span > 1 else ""
    span_k = f" * k_span={k_span}" if k_span > 1 else ""
    out = []
    if elementwise:
        return out
    if n and bn > n:
        out.append(f"block_n{span_n}={bn} is wider than the operand "
                   f"(N={n}): the N grid has zero full steps")
    if k and bk > k:
        out.append(f"block_k{span_k}={bk} is wider than the operand "
                   f"(K={k}): the K grid has zero full steps")
    if m and bm >= 2 * m and bm > 8:
        out.append(f"block_m={bm} is degenerate for M={m}: one visit "
                   f"covers every row with >=50% of the fetched A rows "
                   f"(and the C flush) wasted")
    return out


def infeasible_reason(family: str, config: Any, m: int, k: int, n: int, *,
                      vmem_bytes: float,
                      wgrad_precision: Optional[str] = None,
                      gemm_precision: Optional[str] = None
                      ) -> "Optional[str]":
    """One-line reason this ``(family, config, shape)`` triple can never
    run well (or at all) on a device with ``vmem_bytes`` of VMEM, or
    ``None`` when statically feasible.  This is the pruning predicate
    ``plan.autotune`` applies before ranking/measuring candidates."""
    for code, msg in alignment_issues(config):
        return f"misaligned ({code}): {msg}"
    elementwise = family in ("quantize", "act_quant")
    ns, ks = config_spans(config) if family == "wgrad" else (1, 1)
    for msg in degeneracy_issues(config, m=m, k=k, n=n,
                                 elementwise=elementwise,
                                 n_span=ns, k_span=ks):
        return f"degenerate grid: {msg}"
    fp = footprint(family, config, m=m, k=k, n=n,
                   wgrad_precision=wgrad_precision,
                   gemm_precision=gemm_precision)
    if fp["total"] > vmem_bytes:
        return (f"VMEM footprint {fp['total']} B (double-buffered) exceeds "
                f"the {int(vmem_bytes)} B budget")
    return None
