"""Pallas TPU kernel for 1x128 per-tile fp8 activation quantization.

This is the producer of the grouped-GEMM kernel's ``(a_fp8, s_a)`` operands.
It replaces the baseline's *padding kernel* (the Triton pad-to-128 kernel the
paper benchmarks against at ~2000 GB/s): in the padding-free pipeline the
quantizer writes the exact ``M`` rows, no more.

Per-row scale layout contract (shared by every consumer): the scales are
``[M, ceil(last_dim/128)]`` f32, one scale per 1x128 tile of the row,
travelling on the SAME global M-tiles as the payload.  The layout is
orientation-agnostic on purpose — the x side of the forward GEMM
(scales over K), the dy side of the dgrad (scales over N), and BOTH
operands of the fp8 wgrad (``gmm_pallas_wgrad_fp8`` dequantizes x over K
and dy over N per visit) consume the one output format of this kernel, so
the backward's single ``quantize_tilewise(dy)`` serves the dgrad and the
wgrad without a dy-specific quantizer.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

QUANT_BLOCK = 128
FP8_MAX = 448.0


def _quant_kernel(x_ref, q_ref, s_ref, *, kb):
    x = x_ref[...].astype(jnp.float32)                       # (bm, K)
    bm, k = x.shape
    tiles = x.reshape(bm, kb, QUANT_BLOCK)
    amax = jnp.max(jnp.abs(tiles), axis=-1)                  # (bm, kb)
    scale = jnp.where(amax > 0, amax / FP8_MAX, 1.0)
    q = tiles / scale[..., None]
    q_ref[...] = q.reshape(bm, k).astype(q_ref.dtype)
    s_ref[...] = scale.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def quantize_tilewise_pallas(x: jax.Array, *, block_m: int = 256,
                             interpret: bool = False):
    """x: [M, K] (f32/bf16), K % 128 == 0 -> (q[M,K] fp8e4m3, s[M,K/128] f32)."""
    m, k = x.shape
    if k % QUANT_BLOCK != 0:
        raise ValueError(f"K={k} must be a multiple of {QUANT_BLOCK}")
    kb = k // QUANT_BLOCK
    block_m = min(block_m, max(8, m))
    grid = ((m + block_m - 1) // block_m,)
    return pl.pallas_call(
        functools.partial(_quant_kernel, kb=kb),
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_m, k), lambda i: (i, 0)),
            pl.BlockSpec((block_m, kb), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.float8_e4m3fn),
            jax.ShapeDtypeStruct((m, kb), jnp.float32),
        ],
        interpret=interpret,
    )(x)
