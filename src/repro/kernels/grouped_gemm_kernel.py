"""TMA-Adaptive FP8 Grouped GEMM — Pallas TPU kernel.

This is the TPU-native re-derivation of the paper's padding-free grouped
GEMM (see DESIGN.md §2 for the Hopper→TPU mapping).  The paper's problem:

  * groups have dynamic row counts ``M^g`` (MoE routing), but the bulk-copy
    engine (Hopper TMA there, Pallas ``BlockSpec`` pipelining here) only
    moves statically-shaped, aligned blocks;
  * padding every group to ``block_m`` wastes memory + bandwidth + flops.

The paper's fix is a pool of ``log2(block_m)`` TMA descriptors plus a
two-phase *overlapping, idempotent* store for each residual block.  The TPU
equivalent implemented here:

  * the grid walks **globally block-aligned tiles of the unpadded,
    concatenated token buffer** — every HBM→VMEM copy is aligned by
    construction (the analogue of TMA's static-descriptor compliance);
  * a tile that straddles a group boundary is *visited once per group that
    intersects it* (scalar-prefetched ``group_ids``/``m_tile_ids`` schedule);
  * each visit computes the full tile against its group's ``B^g`` and
    performs a **masked read-modify-write** of the output tile in VMEM —
    rows owned by other groups are preserved.  Same-tile visits are adjacent
    in the grid, so Pallas keeps the output block resident in VMEM between
    them and flushes it to HBM exactly once (the "safe overlapping write"
    of paper §2.2, with the identical cost profile: ≤2 visits per boundary
    tile, independent of the residual size).

Alignment bookkeeping (paper §2.3) maps to:
  * ``block_n % 128 == 0``  (lane width / MXU tile; paper: ``block_N % 64``)
  * ``K % block_k == 0`` and ``block_k % 128 == 0`` (quant-tile alignment)
  * scale rows ``S_A`` travel on the same global M-tiles as ``A`` — the
    whole per-row scale vector is over-fetched once per tile (padded to the
    128-lane VMEM tile), the analogue of the paper's ``[block_M+16, ...]``
    over-fetch descriptor.

Quantization: A is fp8 e4m3 with 1x128 per-tile scales, B is fp8 e4m3 with
128x128 per-block scales (DeepSeek-V3 recipe, as in the paper).  The MXU on
v5e consumes bf16, so operands are upconverted in VREGs; the memory-side
wins — which are what the paper measures — are dtype-native.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels.quant_kernel import FP8_MAX
from repro.kernels.plan import (  # noqa: F401  (metadata lives in plan.py;
    QUANT_BLOCK,                   # re-exported here for pre-plan callers)
    KernelConfig,
    TilePlan,
    make_group_metadata,
    make_tile_plan,
)


def validate_kernel_config(m, k, n, block_m, block_n, block_k):
    """TPU-adapted alignment constraints (analogue of paper's block_N % 64).

    Folded into :class:`repro.kernels.plan.KernelConfig`: construction
    checks the static block constraints, :meth:`KernelConfig.validate`
    the shape-dependent ones.  M is deliberately unconstrained — handling
    arbitrary (ragged) M without padding is the point of the paper.
    """
    KernelConfig(block_m=block_m, block_n=block_n,
                 block_k=block_k).validate(m, k, n)


def _accumulate_visit(a_ref, sa_ref, b_ref, sb_ref, acc_ref, *,
                      n_i, k_i, block_m, block_n, block_k):
    """One visit's MXU work: the fine-grained-rescaled partial products of
    this (m_tile, n_i, k_i) step accumulated into the f32 scratch.  Shared
    by the plain and the quantizing-epilogue kernels — the visit machinery
    is identical, only the store phase differs."""
    # MXU work on the full, always-aligned tile (rows of a neighbouring
    # group compute garbage that the masked store below discards — the
    # cost-equivalent of the paper's redundant overlapping TMA write).
    a = a_ref[...].astype(jnp.float32)                 # (bm, bk)
    b = b_ref[0].astype(jnp.float32)                   # (bk, bn)

    # --- fine-grained rescale (DeepSeek 1x128 x 128x128 recipe) ---------
    # sa_ref: (bm, KB) over-fetched whole scale rows; columns for this k step
    kq = block_k // QUANT_BLOCK                        # quant tiles per k step
    nq = block_n // QUANT_BLOCK                        # quant blocks per n step
    sa = jax.lax.dynamic_slice(sa_ref[...], (0, k_i * kq), (block_m, kq))
    sb = jax.lax.dynamic_slice(sb_ref[0], (k_i * kq, n_i * nq), (kq, nq))
    # one MXU dot per 128-wide quant sub-tile so per-tile scales stay exact
    for j in range(kq):
        aj = a[:, j * QUANT_BLOCK:(j + 1) * QUANT_BLOCK]
        bj = b[j * QUANT_BLOCK:(j + 1) * QUANT_BLOCK]
        pj = jax.lax.dot(aj, bj, preferred_element_type=jnp.float32)
        col_scale = jnp.repeat(sb[j], QUANT_BLOCK, axis=0)     # (bn,)
        acc_ref[...] += pj * sa[:, j][:, None] * col_scale[None, :]


def _gmm_kernel(group_offsets_ref, group_ids_ref, m_tile_ids_ref,  # prefetch
                a_ref, sa_ref, b_ref, sb_ref,                      # VMEM in
                out_ref,                                           # VMEM out
                acc_ref,                                           # scratch
                *, block_m, block_n, block_k, k_steps, num_groups,
                out_dtype):
    n_i = pl.program_id(0)
    t = pl.program_id(1)
    k_i = pl.program_id(2)

    g = group_ids_ref[t]
    m_tile = m_tile_ids_ref[t]

    @pl.when(k_i == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _accumulate_visit(a_ref, sa_ref, b_ref, sb_ref, acc_ref,
                      n_i=n_i, k_i=k_i, block_m=block_m, block_n=block_n,
                      block_k=block_k)

    @pl.when(k_i == k_steps - 1)
    def _store():
        # Masked RMW — the two-phase overlapping-store analogue.  Rows of
        # this tile owned by group g are [start, end); rows owned by *no*
        # group (>= sum(group_sizes) — the capacity-buffer tail) are
        # zero-filled so the output is fully defined (the fp8 backward's
        # dx feeds a scatter-add; garbage tails would corrupt real token
        # gradients); everything else is preserved from the previous
        # (adjacent) visit's contents.  Padding visits in the schedule
        # sweep the tail tiles precisely so this zero-fill reaches every
        # unowned row (see make_group_metadata).
        start = group_offsets_ref[g]
        end = group_offsets_ref[g + 1]
        total = group_offsets_ref[num_groups]
        rows = m_tile * block_m + jax.lax.broadcasted_iota(
            jnp.int32, (block_m, block_n), 0)
        owned = (rows >= start) & (rows < end)
        unowned = rows >= total
        prev = out_ref[...]
        out_ref[...] = jnp.where(
            owned, acc_ref[...].astype(out_dtype),
            jnp.where(unowned, jnp.zeros_like(prev), prev))


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype",
                     "interpret", "num_groups"))
def gmm_pallas(a_fp8: jax.Array, s_a: jax.Array, b_fp8: jax.Array,
               s_b: jax.Array, group_sizes: jax.Array, *,
               num_groups: int | None = None,
               block_m: int = 128, block_n: int = 128, block_k: int = 128,
               out_dtype: Any = jnp.bfloat16, interpret: bool = False,
               plan: TilePlan | None = None):
    """Padding-free fp8 grouped GEMM.

    a_fp8:  [M, K]   fp8 e4m3 — concatenated groups, arbitrary (ragged) M^g
    s_a:    [M, KB]  f32      — 1x128 tile scales (KB = ceil(K/128))
    b_fp8:  [G, K, N] fp8
    s_b:    [G, KB, NB] f32   — 128x128 block scales
    group_sizes: [G] int32, sum <= M.  Rows in ``[sum(group_sizes), M)``
            (the unowned tail of a capacity buffer) come back as DEFINED
            zeros — the schedule's padding visits sweep the tail tiles and
            the masked store zero-fills every row no group owns, so
            downstream consumers (the fp8 backward's take-VJP scatter-add)
            never see uninitialized memory.
    plan:   optional precomputed :class:`TilePlan` for this
            ``(group_sizes, M, block_m)`` — pass it to amortize the
            schedule across the several GEMMs of one routing decision
            (built here when absent).  The plan MUST have been built from
            these ``group_sizes``: its schedule replaces them wholesale,
            and only the static (m, block_m, num_groups) triple is
            checkable — a plan from a different routing decision gives
            silently wrong output (see :class:`TilePlan`)
    returns [M, N] out_dtype
    """
    m, k = a_fp8.shape
    g, k2, n = b_fp8.shape
    if k != k2:
        raise ValueError(
            f"A and B disagree on K: a_fp8 is [M={m}, K={k}] but b_fp8 is "
            f"[G={g}, K={k2}, N={n}]")
    num_groups = num_groups or g
    validate_kernel_config(m, k, n, block_m, block_n, block_k)
    kb = s_a.shape[1]
    expected_kb = (k + QUANT_BLOCK - 1) // QUANT_BLOCK
    if kb != expected_kb:
        raise ValueError(
            f"s_a has {kb} scale columns but K={k} needs "
            f"ceil(K/{QUANT_BLOCK}) = {expected_kb} (s_a shape "
            f"{s_a.shape}, a_fp8 shape {a_fp8.shape})")

    if m == 0:
        return jnp.zeros((0, n), out_dtype)

    if plan is None:
        plan = make_tile_plan(group_sizes, m, block_m=block_m,
                              num_groups=num_groups)
    else:
        plan.check_against(m, block_m, num_groups)
    k_steps = k // block_k

    grid = (n // block_n, plan.max_visits, k_steps)

    kernel = functools.partial(
        _gmm_kernel, block_m=block_m, block_n=block_n, block_k=block_k,
        k_steps=k_steps, num_groups=num_groups, out_dtype=out_dtype)

    def _run_kernel(group_offsets, group_ids, m_tile_ids):
        return pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=3,
                grid=grid,
                in_specs=[
                    # A tile: globally block-aligned HBM->VMEM copy
                    pl.BlockSpec((block_m, block_k),
                                 lambda n_i, t, k_i, go, gi, mi: (mi[t], k_i)),
                    # S_A: over-fetch the whole scale row per tile (padded to
                    # the 128-lane VMEM tile) — paper §2.3 analogue
                    pl.BlockSpec((block_m, kb),
                                 lambda n_i, t, k_i, go, gi, mi: (mi[t], 0)),
                    # B^g tile, selected by the visit's group id
                    pl.BlockSpec((1, block_k, block_n),
                                 lambda n_i, t, k_i, go, gi, mi: (gi[t], k_i, n_i)),
                    # S_B^g: whole per-group scale block (tiny)
                    pl.BlockSpec((1, kb, s_b.shape[2]),
                                 lambda n_i, t, k_i, go, gi, mi: (gi[t], 0, 0)),
                ],
                out_specs=pl.BlockSpec(
                    (block_m, block_n),
                    lambda n_i, t, k_i, go, gi, mi: (mi[t], n_i)),
                scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
            ),
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            compiler_params=compat.tpu_compiler_params(
                dimension_semantics=("parallel", "arbitrary", "arbitrary"),
            ),
            interpret=interpret,
        )(group_offsets, group_ids, m_tile_ids, a_fp8, s_a, b_fp8, s_b)

    # all-empty schedule (every group size 0): the zero-visit plan owns no
    # rows, so short-circuit to defined zeros instead of launching visits
    # that leave the whole buffer uninitialized
    return jax.lax.cond(
        plan.total_rows() > 0,
        lambda go, gi, mi: _run_kernel(go, gi, mi),
        lambda go, gi, mi: jnp.zeros((m, n), out_dtype),
        plan.group_offsets, plan.group_ids, plan.m_tile_ids)


def _gmm_bf16_kernel(group_offsets_ref, group_ids_ref, m_tile_ids_ref,
                     a_ref, b_ref,                                  # VMEM in
                     out_ref,                                       # VMEM out
                     acc_ref,                                       # scratch
                     *, block_m, block_n, block_k, k_steps, num_groups,
                     out_dtype):
    """True-bf16 twin of :func:`_gmm_kernel`: identical grid walk, visit
    schedule, and masked-RMW store — no scale operands and no rescale
    (the numerics-baseline orientation, so every fp8-vs-bf16 comparison
    measures OUR schedule on both sides, not XLA's).  Accumulation stays
    one f32 MXU dot per 128-wide K sub-tile, the same reduction order as
    the fp8 kernel (and the ``gmm_bf16_xla_exact`` oracle)."""
    n_i = pl.program_id(0)
    t = pl.program_id(1)
    k_i = pl.program_id(2)

    g = group_ids_ref[t]
    m_tile = m_tile_ids_ref[t]

    @pl.when(k_i == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)                 # (bm, bk)
    b = b_ref[0].astype(jnp.float32)                   # (bk, bn)
    for j in range(block_k // QUANT_BLOCK):
        aj = a[:, j * QUANT_BLOCK:(j + 1) * QUANT_BLOCK]
        bj = b[j * QUANT_BLOCK:(j + 1) * QUANT_BLOCK]
        acc_ref[...] += jax.lax.dot(aj, bj,
                                    preferred_element_type=jnp.float32)

    @pl.when(k_i == k_steps - 1)
    def _store():
        # same masked RMW as the fp8 kernel: owned rows store, unowned
        # tail rows zero-fill, everything else preserves the adjacent
        # visit's contents
        start = group_offsets_ref[g]
        end = group_offsets_ref[g + 1]
        total = group_offsets_ref[num_groups]
        rows = m_tile * block_m + jax.lax.broadcasted_iota(
            jnp.int32, (block_m, block_n), 0)
        owned = (rows >= start) & (rows < end)
        unowned = rows >= total
        prev = out_ref[...]
        out_ref[...] = jnp.where(
            owned, acc_ref[...].astype(out_dtype),
            jnp.where(unowned, jnp.zeros_like(prev), prev))


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype",
                     "interpret", "num_groups"))
def gmm_pallas_bf16(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
                    num_groups: int | None = None,
                    block_m: int = 128, block_n: int = 128,
                    block_k: int = 128,
                    out_dtype: Any = jnp.bfloat16, interpret: bool = False,
                    plan: TilePlan | None = None):
    """Padding-free bf16 grouped GEMM — the true-Pallas ``(gemm, bf16)``
    registry entry.

    x:  [M, K] float — concatenated groups (cast to bf16 operands, like
        the ``ragged_dot`` baseline this kernel replaces)
    w:  [G, K, N] float — per-group weights (cast to bf16)
    group_sizes: [G] int32, sum <= M; tail rows come back as DEFINED
        zeros (same masked-store contract as :func:`gmm_pallas`)
    plan: optional precomputed :class:`TilePlan` — the same plan-reuse
        contract as every other kernel of a routing decision.
    returns [M, N] out_dtype with f32 accumulation of bf16 products.
    """
    m, k = x.shape
    g, k2, n = w.shape
    if k != k2:
        raise ValueError(
            f"x and w disagree on K: x is [M={m}, K={k}] but w is "
            f"[G={g}, K={k2}, N={n}]")
    num_groups = num_groups or g
    validate_kernel_config(m, k, n, block_m, block_n, block_k)

    if m == 0:
        return jnp.zeros((0, n), out_dtype)
    x16 = x.astype(jnp.bfloat16)
    w16 = w.astype(jnp.bfloat16)

    if plan is None:
        plan = make_tile_plan(group_sizes, m, block_m=block_m,
                              num_groups=num_groups)
    else:
        plan.check_against(m, block_m, num_groups)
    k_steps = k // block_k

    grid = (n // block_n, plan.max_visits, k_steps)

    kernel = functools.partial(
        _gmm_bf16_kernel, block_m=block_m, block_n=block_n, block_k=block_k,
        k_steps=k_steps, num_groups=num_groups, out_dtype=out_dtype)

    def _run_kernel(group_offsets, group_ids, m_tile_ids):
        return pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=3,
                grid=grid,
                in_specs=[
                    # A tile: globally block-aligned HBM->VMEM copy
                    pl.BlockSpec((block_m, block_k),
                                 lambda n_i, t, k_i, go, gi, mi: (mi[t], k_i)),
                    # B^g tile, selected by the visit's group id
                    pl.BlockSpec((1, block_k, block_n),
                                 lambda n_i, t, k_i, go, gi, mi: (gi[t], k_i, n_i)),
                ],
                out_specs=pl.BlockSpec(
                    (block_m, block_n),
                    lambda n_i, t, k_i, go, gi, mi: (mi[t], n_i)),
                scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
            ),
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            compiler_params=compat.tpu_compiler_params(
                dimension_semantics=("parallel", "arbitrary", "arbitrary"),
            ),
            interpret=interpret,
        )(group_offsets, group_ids, m_tile_ids, x16, w16)

    # all-empty schedule: short-circuit to defined zeros (same contract
    # as the fp8 kernel)
    return jax.lax.cond(
        plan.total_rows() > 0,
        lambda go, gi, mi: _run_kernel(go, gi, mi),
        lambda go, gi, mi: jnp.zeros((m, n), out_dtype),
        plan.group_offsets, plan.group_ids, plan.m_tile_ids)


def _gmm_quant_kernel(group_offsets_ref, group_ids_ref, m_tile_ids_ref,
                      a_ref, sa_ref, b_ref, sb_ref,                # VMEM in
                      q_ref, s_ref,                                # VMEM out
                      acc_ref,                                     # scratch
                      *, block_m, block_n, block_k, k_steps, num_groups,
                      out_dtype):
    """Quantizing-epilogue twin of :func:`_gmm_kernel`.

    Identical visit machinery; the store phase rounds the accumulator
    through ``out_dtype`` (so the payload is bitwise what the unfused
    GEMM -> quantize_tilewise composition produces), computes the per-row
    amax over each 128-wide N quant tile, and emits the fp8 payload plus
    the 1x128 scales directly — the bf16 output never exists.  The masked
    RMW extends to both outputs: unowned tail rows get payload 0 and
    scale 1, exactly what quantizing a zero-filled row yields, so the
    zero-fill contract survives fusion.
    """
    n_i = pl.program_id(0)
    t = pl.program_id(1)
    k_i = pl.program_id(2)

    g = group_ids_ref[t]
    m_tile = m_tile_ids_ref[t]

    @pl.when(k_i == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _accumulate_visit(a_ref, sa_ref, b_ref, sb_ref, acc_ref,
                      n_i=n_i, k_i=k_i, block_m=block_m, block_n=block_n,
                      block_k=block_k)

    @pl.when(k_i == k_steps - 1)
    def _store():
        start = group_offsets_ref[g]
        end = group_offsets_ref[g + 1]
        total = group_offsets_ref[num_groups]
        # per-ROW masks (bm, 1): the amax reduction is along N, so row
        # ownership decides both the payload columns and the scale columns
        rows = m_tile * block_m + jax.lax.broadcasted_iota(
            jnp.int32, (block_m, 1), 0)
        owned = (rows >= start) & (rows < end)
        unowned = rows >= total
        # round through out_dtype first: the unfused composition stores the
        # GEMM output in out_dtype and quantizes its f32 upcast — matching
        # that rounding point is what makes fused-vs-unfused bitwise
        h = acc_ref[...].astype(out_dtype).astype(jnp.float32)
        nq = block_n // QUANT_BLOCK
        tiles = h.reshape(block_m, nq, QUANT_BLOCK)
        amax = jnp.max(jnp.abs(tiles), axis=-1)                  # (bm, nq)
        scale = jnp.where(amax > 0, amax / FP8_MAX, 1.0)
        qv = (tiles / scale[..., None]).reshape(block_m, block_n)
        # payload select in f32, then one cast: fp8->f32->fp8 on the
        # preserved columns is lossless, and the owned columns round
        # exactly once (same as the standalone quantize kernel)
        prev_q = q_ref[...].astype(jnp.float32)
        q_ref[...] = jnp.where(
            owned, qv,
            jnp.where(unowned, jnp.zeros_like(qv), prev_q)).astype(q_ref.dtype)
        prev_s = s_ref[...]
        s_ref[...] = jnp.where(
            owned, scale, jnp.where(unowned, jnp.ones_like(scale), prev_s))


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype",
                     "interpret", "num_groups"))
def gmm_pallas_quant(a_fp8: jax.Array, s_a: jax.Array, b_fp8: jax.Array,
                     s_b: jax.Array, group_sizes: jax.Array, *,
                     num_groups: int | None = None,
                     block_m: int = 128, block_n: int = 128,
                     block_k: int = 128,
                     out_dtype: Any = jnp.bfloat16, interpret: bool = False,
                     plan: TilePlan | None = None):
    """Padding-free fp8 grouped GEMM with a fused 1x128 quantizing epilogue.

    Same contract as :func:`gmm_pallas`, but instead of materializing the
    ``[M, N] out_dtype`` product it emits the DeepSeek-recipe quantized
    form directly from the epilogue:

    returns ``(q, s)``:
      q: [M, N]      fp8 e4m3 — ``out_dtype``-rounded product / scale
      s: [M, N/128]  f32      — per-row 1x128 tile scales

    ``out_dtype`` is the *intermediate rounding* dtype: the accumulator is
    rounded through it before the amax/scale computation, so the result is
    bitwise identical to ``quantize_tilewise(gmm_pallas(...).astype(f32))``.
    Tail rows in ``[sum(group_sizes), M)`` come back as payload 0 /
    scale 1 — what quantizing the unfused path's zero-filled tail yields —
    preserving the zero-fill contract for downstream consumers.
    """
    m, k = a_fp8.shape
    g, k2, n = b_fp8.shape
    if k != k2:
        raise ValueError(
            f"A and B disagree on K: a_fp8 is [M={m}, K={k}] but b_fp8 is "
            f"[G={g}, K={k2}, N={n}]")
    num_groups = num_groups or g
    validate_kernel_config(m, k, n, block_m, block_n, block_k)
    kb = s_a.shape[1]
    expected_kb = (k + QUANT_BLOCK - 1) // QUANT_BLOCK
    if kb != expected_kb:
        raise ValueError(
            f"s_a has {kb} scale columns but K={k} needs "
            f"ceil(K/{QUANT_BLOCK}) = {expected_kb} (s_a shape "
            f"{s_a.shape}, a_fp8 shape {a_fp8.shape})")
    nb = n // QUANT_BLOCK
    q_dtype = a_fp8.dtype

    if m == 0:
        return (jnp.zeros((0, n), q_dtype), jnp.ones((0, nb), jnp.float32))

    if plan is None:
        plan = make_tile_plan(group_sizes, m, block_m=block_m,
                              num_groups=num_groups)
    else:
        plan.check_against(m, block_m, num_groups)
    k_steps = k // block_k
    nq = block_n // QUANT_BLOCK

    grid = (n // block_n, plan.max_visits, k_steps)

    kernel = functools.partial(
        _gmm_quant_kernel, block_m=block_m, block_n=block_n, block_k=block_k,
        k_steps=k_steps, num_groups=num_groups, out_dtype=out_dtype)

    def _run_kernel(group_offsets, group_ids, m_tile_ids):
        return pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=3,
                grid=grid,
                in_specs=[
                    pl.BlockSpec((block_m, block_k),
                                 lambda n_i, t, k_i, go, gi, mi: (mi[t], k_i)),
                    pl.BlockSpec((block_m, kb),
                                 lambda n_i, t, k_i, go, gi, mi: (mi[t], 0)),
                    pl.BlockSpec((1, block_k, block_n),
                                 lambda n_i, t, k_i, go, gi, mi: (gi[t], k_i, n_i)),
                    pl.BlockSpec((1, kb, s_b.shape[2]),
                                 lambda n_i, t, k_i, go, gi, mi: (gi[t], 0, 0)),
                ],
                out_specs=[
                    # fp8 payload tile — same walk as the plain kernel's out
                    pl.BlockSpec((block_m, block_n),
                                 lambda n_i, t, k_i, go, gi, mi: (mi[t], n_i)),
                    # 1x128 scales: nq columns per N step, same M-tile walk
                    pl.BlockSpec((block_m, nq),
                                 lambda n_i, t, k_i, go, gi, mi: (mi[t], n_i)),
                ],
                scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
            ),
            out_shape=[
                jax.ShapeDtypeStruct((m, n), q_dtype),
                jax.ShapeDtypeStruct((m, nb), jnp.float32),
            ],
            compiler_params=compat.tpu_compiler_params(
                dimension_semantics=("parallel", "arbitrary", "arbitrary"),
            ),
            interpret=interpret,
        )(group_offsets, group_ids, m_tile_ids, a_fp8, s_a, b_fp8, s_b)

    # all-empty schedule: payload 0 / scale 1 everywhere — bitwise what
    # quantizing the unfused path's all-zero output produces
    return jax.lax.cond(
        plan.total_rows() > 0,
        lambda go, gi, mi: _run_kernel(go, gi, mi),
        lambda go, gi, mi: (jnp.zeros((m, n), q_dtype),
                            jnp.ones((m, nb), jnp.float32)),
        plan.group_offsets, plan.group_ids, plan.m_tile_ids)
