"""Pure-jnp oracles for every kernel in this package.

These are the ground truth the Pallas kernels and the XLA fast paths are
validated against (``tests/test_kernels_grouped_gemm.py`` sweeps shapes and
dtypes and asserts allclose).

Quantization scheme follows the paper (= DeepSeek-V3):
  * ``A``  — fp8 e4m3, one scale per 1x128 tile:   S_A[m, ceil(K/128)]  (f32)
  * ``B``  — fp8 e4m3, one scale per 128x128 block: S_B[g, ceil(K/128), ceil(N/128)]
  * ``C``  — bf16, accumulated in f32 with per-K-block rescale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

QUANT_BLOCK = 128  # the paper's 1x128 / 128x128 quantization granularity
FP8_MAX = 448.0    # float8_e4m3fn max normal


# ---------------------------------------------------------------------------
# Quantization oracles
# ---------------------------------------------------------------------------

def quantize_tilewise_ref(a: jax.Array, block: int = QUANT_BLOCK):
    """1 x `block` per-tile symmetric fp8 quantization of a 2-D activation.

    Returns ``(a_fp8[m, k], s_a[m, ceil(k/block)])`` with
    ``a ≈ a_fp8 * repeat(s_a, block, axis=1)``.
    """
    m, k = a.shape
    kb = (k + block - 1) // block
    pad = kb * block - k
    ap = jnp.pad(a.astype(jnp.float32), ((0, 0), (0, pad)))
    tiles = ap.reshape(m, kb, block)
    amax = jnp.max(jnp.abs(tiles), axis=-1)
    scale = jnp.where(amax > 0, amax / FP8_MAX, 1.0)
    q = (tiles / scale[..., None]).reshape(m, kb * block)[:, :k]
    return q.astype(jnp.float8_e4m3fn), scale.astype(jnp.float32)


def act_quantize_ref(g: jax.Array, u: jax.Array | None = None,
                     act: str = "silu_mul", block: int = QUANT_BLOCK, *,
                     s_g: jax.Array | None = None,
                     s_u: jax.Array | None = None):
    """Unfused oracle for the fused activation->quantize epilogue.

    Computes the activation in f32 (``silu(g) * u`` or unary ``gelu(g)``)
    and feeds it through :func:`quantize_tilewise_ref`.  The fused Pallas
    kernel performs the identical elementwise f32 ops, so interpret-mode
    comparisons against this oracle can demand bitwise equality.

    With ``s_g`` (and ``s_u``) present the operands are fp8 payloads from
    the fused-producer GEMM; they dequantize tilewise first, mirroring the
    kernel's in-register dequant-on-load.
    """
    from repro.kernels.epilogue_kernel import _act_f32
    if s_g is not None:
        g = dequantize_tilewise_ref(g, s_g, block)
    if s_u is not None:
        u = dequantize_tilewise_ref(u, s_u, block)
    return quantize_tilewise_ref(_act_f32(g, u, act), block)


def quantize_blockwise_ref(b: jax.Array, block: int = QUANT_BLOCK):
    """`block` x `block` per-block symmetric fp8 quantization of a 2-D weight.

    Returns ``(b_fp8[k, n], s_b[ceil(k/block), ceil(n/block)])``.
    """
    k, n = b.shape
    kb = (k + block - 1) // block
    nb = (n + block - 1) // block
    bp = jnp.pad(b.astype(jnp.float32), ((0, kb * block - k), (0, nb * block - n)))
    blocks = bp.reshape(kb, block, nb, block).transpose(0, 2, 1, 3)
    amax = jnp.max(jnp.abs(blocks), axis=(-1, -2))
    scale = jnp.where(amax > 0, amax / FP8_MAX, 1.0)
    q = (blocks / scale[..., None, None]).transpose(0, 2, 1, 3).reshape(
        kb * block, nb * block)[:k, :n]
    return q.astype(jnp.float8_e4m3fn), scale.astype(jnp.float32)


def dequantize_tilewise_ref(a_fp8, s_a, block: int = QUANT_BLOCK):
    m, k = a_fp8.shape
    kb = s_a.shape[1]
    scales = jnp.repeat(s_a, block, axis=1)[:, :k]
    return a_fp8.astype(jnp.float32) * scales


def dequantize_blockwise_ref(b_fp8, s_b, block: int = QUANT_BLOCK):
    k, n = b_fp8.shape
    scales = jnp.repeat(jnp.repeat(s_b, block, axis=0), block, axis=1)[:k, :n]
    return b_fp8.astype(jnp.float32) * scales


# ---------------------------------------------------------------------------
# Grouped GEMM oracle (loop over groups, dequantize then fp32 matmul)
# ---------------------------------------------------------------------------

def grouped_gemm_ref(a_fp8, s_a, b_fp8, s_b, group_sizes,
                     block: int = QUANT_BLOCK, out_dtype=jnp.bfloat16):
    """Oracle: dequantize then per-group fp32 matmul.

    a_fp8:  [M, K]  fp8   (concatenated groups, NO padding — the paper's input)
    s_a:    [M, KB] f32
    b_fp8:  [G, K, N] fp8
    s_b:    [G, KB, NB] f32
    group_sizes: [G] int32, sum == M
    returns [M, N] out_dtype
    """
    group_sizes = np.asarray(group_sizes)
    a = dequantize_tilewise_ref(a_fp8, s_a, block)
    outs = []
    off = 0
    for g, sz in enumerate(group_sizes):
        bg = dequantize_blockwise_ref(b_fp8[g], s_b[g], block)
        outs.append(jnp.dot(a[off:off + sz], bg,
                            preferred_element_type=jnp.float32))
        off += int(sz)
    return jnp.concatenate(outs, axis=0).astype(out_dtype)


def grouped_gemm_blockscaled_ref(a_fp8, s_a, b_fp8, s_b, group_sizes,
                                 block: int = QUANT_BLOCK,
                                 out_dtype=jnp.bfloat16):
    """Second oracle matching the *kernel's* exact math: per-K-block partial
    products rescaled by ``s_a[:, kb] * s_b[g, kb, nb]`` and accumulated in
    f32.  This is the arithmetic both the Pallas kernel and the XLA path
    implement, so comparisons against it can demand much tighter tolerances
    (the paper's "bitwise identical" claim is w.r.t. like-for-like math).
    """
    group_sizes = np.asarray(group_sizes)
    m, k = a_fp8.shape
    g_, _, n = b_fp8.shape
    kb = (k + block - 1) // block
    nb = (n + block - 1) // block
    out = []
    off = 0
    for g, sz in enumerate(group_sizes):
        acc = jnp.zeros((int(sz), n), jnp.float32)
        ag = a_fp8[off:off + int(sz)]
        sag = s_a[off:off + int(sz)]
        for ki in range(kb):
            k0, k1 = ki * block, min((ki + 1) * block, k)
            part = jnp.dot(ag[:, k0:k1].astype(jnp.float32),
                           b_fp8[g, k0:k1].astype(jnp.float32),
                           preferred_element_type=jnp.float32)
            # per-(1xK-tile) activation scale x per-(KxN block) weight scale
            col_scale = jnp.repeat(s_b[g, ki, :nb], block)[:n]
            acc = acc + part * sag[:, ki:ki + 1] * col_scale[None, :]
        out.append(acc)
        off += int(sz)
    return jnp.concatenate(out, axis=0).astype(out_dtype)


# ---------------------------------------------------------------------------
# Padded baseline oracle (what the paper compares against: pad + dense GEMM)
# ---------------------------------------------------------------------------

def pad_groups_ref(a_fp8, s_a, group_sizes, block_m: int = 128):
    """The baseline's explicit padding op: each group's rows padded up to a
    multiple of ``block_m``.  Returns (a_padded, s_a_padded,
    padded_group_sizes).  This is the memory/bandwidth overhead the paper
    eliminates."""
    group_sizes = np.asarray(group_sizes)
    padded_sizes = ((group_sizes + block_m - 1) // block_m) * block_m
    a_out, s_out = [], []
    off = 0
    for sz, psz in zip(group_sizes, padded_sizes):
        a_out.append(a_fp8[off:off + int(sz)])
        a_out.append(jnp.zeros((int(psz - sz), a_fp8.shape[1]), a_fp8.dtype))
        s_out.append(s_a[off:off + int(sz)])
        s_out.append(jnp.ones((int(psz - sz), s_a.shape[1]), s_a.dtype))
        off += int(sz)
    return (jnp.concatenate(a_out, axis=0), jnp.concatenate(s_out, axis=0),
            padded_sizes)


def unpad_groups_ref(c_padded, group_sizes, block_m: int = 128):
    group_sizes = np.asarray(group_sizes)
    padded_sizes = ((group_sizes + block_m - 1) // block_m) * block_m
    outs, off = [], 0
    for sz, psz in zip(group_sizes, padded_sizes):
        outs.append(c_padded[off:off + int(sz)])
        off += int(psz)
    return jnp.concatenate(outs, axis=0)
