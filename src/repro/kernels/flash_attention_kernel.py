"""Fused causal GQA flash-attention — Pallas TPU kernel.

§Perf follow-up: the roofline analysis showed the XLA online-softmax
formulation pays ~30% of the training memory term in f32 score-chunk and
accumulator-rescale HBM traffic.  In this kernel the (m, l, acc) state
lives in VMEM scratch across the k loop — scores never touch HBM — and
fully-masked causal blocks are skipped with ``pl.when`` (the same
block-skipping the XLA path got via ``lax.cond``, §Perf I4).

GQA is handled in the BlockSpec index maps: q-head ``h`` reads kv-head
``h // group``, so KV are never materialized at q-head count.

Layout: q [B, Hq, S, D], k/v [B, Hkv, S, D] -> out [B, Hq, S, D].
Constraints (validator): D % 8 == 0 (ideally 128), S % block == 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q, block_k, num_kb, sm_scale, causal):
    i = pl.program_id(2)   # q block
    j = pl.program_id(3)   # k block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal block skip: live iff last q row >= first k row
    live = ((i + 1) * block_q - 1 >= j * block_k) if causal else True

    @pl.when(live)
    def _attend():
        q = q_ref[0].astype(jnp.float32)                 # [bq, D]
        k = k_ref[0].astype(jnp.float32)                 # [bk, D]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                 # [bq, bk]
        if causal:
            rows = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                           # masked -> exp->0
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == num_kb - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                              "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool = False):
    """q: [B, Hq, S, D]; k, v: [B, Hkv, S, D] -> [B, Hq, S, D]."""
    b, hq, s, d = q.shape
    _, hkv, sk, _ = k.shape
    if hq % hkv != 0:
        raise ValueError(f"Hq={hq} must be a multiple of Hkv={hkv} "
                         f"(GQA group count must be integral); got "
                         f"q {q.shape}, k {k.shape}")
    g = hq // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, sk)
    if s % block_q or sk % block_k:
        raise ValueError(f"S={s}/{sk} must divide blocks {block_q}/{block_k}")
    nq, nk = s // block_q, sk // block_k
    sm_scale = d ** -0.5

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, num_kb=nk,
        sm_scale=sm_scale, causal=causal)

    bh = b * hq
    qr = q.reshape(bh, s, d)
    kr = k.reshape(b * hkv, sk, d)
    vr = v.reshape(b * hkv, sk, d)

    def kv_index(bh_i, _, __, j):
        # q flat index (b*Hq + h) -> kv flat index (b*Hkv + h // g)
        return (bh_i // hq) * hkv + (bh_i % hq) // g, j, 0

    out = pl.pallas_call(
        kernel,
        grid=(bh, 1, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d),
                         lambda bh_i, _, i, j: (bh_i, i, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh_i, _, i, j: kv_index(bh_i, _, i, j)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh_i, _, i, j: kv_index(bh_i, _, i, j)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh_i, _, i, j: (bh_i, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),       # running max
            pltpu.VMEM((block_q, 1), jnp.float32),       # running denom
            pltpu.VMEM((block_q, d), jnp.float32),       # accumulator
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, s, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention_trainable(q, k, v, causal: bool = True,
                              interpret: bool = False):
    """Differentiable wrapper: fused Pallas forward, reference backward.

    The backward pass recomputes attention through the XLA online-softmax
    formulation and takes its VJP (flash-attention-style recompute-in-bwd;
    a dedicated Pallas backward kernel is the logical next step and slots
    in behind this same interface)."""
    return flash_attention(q, k, v, causal=causal, interpret=interpret)


def _flash_fwd(q, k, v, causal, interpret):
    return flash_attention(q, k, v, causal=causal, interpret=interpret), \
        (q, k, v)


def _flash_bwd(causal, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: flash_attention_ref(
        q_, k_, v_, causal=causal), q, k, v)
    return vjp(g)


flash_attention_trainable.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """Pure-jnp oracle."""
    b, hq, s, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    kx = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vx = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    s_ = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kx) * d ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((s, sk), bool))
        s_ = jnp.where(mask, s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx).astype(q.dtype)
