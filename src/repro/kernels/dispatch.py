"""Unified grouped-GEMM backend dispatch registry.

Every grouped-GEMM call site in the repo (``core/grouped_gemm.py``,
``core/moe.py``, ``core/padding_baseline.py``, models, benchmarks,
examples) routes through this module.  A backend is a named entry in the
registry with

  * an ``available()`` probe returning ``(ok, reason)`` — built on
    :mod:`repro.compat` capability probes so selection is testable by
    monkeypatching, and refusal is an explicit
    :class:`BackendUnavailableError` instead of a deep ``AttributeError``;
  * a ``run()`` implementing the quantized grouped GEMM
    ``(a_fp8, s_a, b_fp8, s_b, group_sizes) -> [M, N]`` under a
    :class:`repro.kernels.plan.KernelConfig` (tile shapes + out dtype),
    optionally consuming a precomputed :class:`~repro.kernels.plan.TilePlan`
    (the plan-once/run-many schedule shared by every GEMM of one routing
    decision).

Built-in backends:

  ===================  =====================================================
  ``pallas``           compiled Pallas TPU kernel (requires a TPU)
  ``pallas_interpret`` same kernel body, interpreted — runs anywhere (CPU
                       regression gate; bit-identical to ``pallas``)
  ``xla_ragged``       ``jax.lax.ragged_dot`` on bf16-dequantized operands
                       (portable, GSPMD-partitionable; ~fp8-rounding-level
                       deviation from the kernel)
  ``xla_exact``        per-K-block f32 math with the kernel's accumulation
                       order — cross-check oracle
  ``padded_baseline``  the paper's baseline: pad every group to block_m,
                       aligned grouped GEMM, unpad (through the Pallas
                       kernel so equivalence checks are bitwise)
  ===================  =====================================================

``backend="auto"`` resolves to the first available of
``pallas`` > ``xla_ragged`` > ``pallas_interpret``.  ``"xla"`` is kept as
an alias of ``"xla_ragged"`` for pre-registry callers.

The module hosts a SECOND operation family: the ragged-contraction
(wgrad) grouped GEMM ``dw[g] = x_g^T @ dy_g`` (``grouped_gemm_wgrad``,
``register_wgrad_backend``), with ``pallas`` / ``pallas_interpret``
(``repro.kernels.wgrad_kernel``), ``xla_ragged``
(``compat.ragged_wgrad``) and a dense f32 ``xla_exact`` oracle.  Backend
names are shared across families so one ``KernelConfig.backend`` rides a
whole training step: forward and dgrad through the gemm family, wgrad
through this one, the same :class:`~repro.kernels.plan.TilePlan` through
all of them.

Operand precision is a THIRD dimension of the wgrad family: every
bf16-operand entry has an fp8-operand twin under the ``<name>_fp8``
registry name (``pallas_fp8`` / ``pallas_interpret_fp8`` run
``gmm_pallas_wgrad_fp8`` — per-visit dequantization of the forward's
``(a8, s_a)`` residual and the dgrad's ``(dy8, s_dy)``; the
``xla_*_fp8`` entries dequantize up front and reuse the bf16/f32 math).
Callers keep naming the family-neutral backend
(``KernelConfig(backend="pallas", wgrad_precision="fp8")`` or
``grouped_linear(wgrad_precision="fp8")``);
``resolve_wgrad_backend(..., precision="fp8")`` derives the twin.  The
bf16 path stays the default (the DeepSeek recipe); fp8 is the opt-in
all-fp8 step of arXiv 2505.20524.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels import ref as _ref
from repro.kernels.grouped_gemm_kernel import QUANT_BLOCK, gmm_pallas
from repro.kernels.plan import (KernelConfig, TilePlan,  # noqa: F401
                                make_tile_plan, resolve_config)
from repro.kernels.quant_kernel import quantize_tilewise_pallas
from repro.kernels.wgrad_kernel import gmm_pallas_wgrad, gmm_pallas_wgrad_fp8

# auto-resolution preference, best first (shared by both op families)
AUTO_ORDER = ("pallas", "xla_ragged", "pallas_interpret")

_ALIASES = {"xla": "xla_ragged"}

# suffix distinguishing the fp8-operand twins in the wgrad registry
_FP8_SUFFIX = "_fp8"

# backends that walk the TilePlan schedule (and honour tile shapes); the
# XLA paths let the compiler tile and ignore both
PLAN_BACKENDS = frozenset({"pallas", "pallas_interpret",
                           "pallas_fp8", "pallas_interpret_fp8"})
TILE_FREE_BACKENDS = frozenset({"xla_ragged", "xla_exact",
                                "xla_ragged_fp8", "xla_exact_fp8"})


class BackendUnavailableError(RuntimeError):
    """Requested backend cannot run here; ``.reason`` says why."""

    def __init__(self, name: str, reason: str):
        super().__init__(f"grouped-GEMM backend {name!r} unavailable: "
                         f"{reason}")
        self.backend = name
        self.reason = reason


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    name: str
    description: str
    available: Callable[[], "tuple[bool, str]"]   # (ok, reason-if-not)
    run: Callable[..., jax.Array]


_REGISTRY: dict[str, BackendSpec] = {}
_default_backend_override: Optional[str] = None


def register_backend(name: str, *, description: str,
                     available: Callable[[], "tuple[bool, str]"],
                     run: Callable[..., jax.Array]) -> None:
    """Later PRs (autotuned variants, new hardware paths) plug in here."""
    _REGISTRY[name] = BackendSpec(name, description, available, run)


def backend_names() -> "tuple[str, ...]":
    return tuple(_REGISTRY)


def availability(name: str) -> "tuple[bool, str]":
    name = _ALIASES.get(name, name)
    if name not in _REGISTRY:
        raise ValueError(f"unknown backend {name!r}; "
                         f"choose from {backend_names()}")
    return _REGISTRY[name].available()


def backend_matrix() -> "dict[str, dict[str, Any]]":
    """{name: {available, reason, description}} — docs / CLI surface."""
    out = {}
    for name, spec in _REGISTRY.items():
        ok, reason = spec.available()
        out[name] = {"available": ok, "reason": reason,
                     "description": spec.description}
    return out


def set_default_backend(name: Optional[str]) -> None:
    """Override what ``backend=None`` / ``"auto"`` resolves to."""
    global _default_backend_override
    if name is not None:
        name = _ALIASES.get(name, name)
        if name not in _REGISTRY:
            raise ValueError(f"unknown backend {name!r}; "
                             f"choose from {backend_names()}")
    _default_backend_override = name


def default_backend() -> str:
    return resolve_backend("auto")


def resolve_backend(backend: Optional[str] = "auto") -> str:
    """Map a requested backend (or ``"auto"``/``None``) to a concrete,
    *available* registry entry, or raise with the probe's reason."""
    if backend in (None, "auto"):
        if _default_backend_override is not None:
            backend = _default_backend_override
        else:
            for name in AUTO_ORDER:
                ok, _ = _REGISTRY[name].available()
                if ok:
                    return name
            raise BackendUnavailableError(
                "auto", "no grouped-GEMM backend is available "
                        f"(tried {AUTO_ORDER})")
    backend = _ALIASES.get(backend, backend)
    if backend not in _REGISTRY:
        raise ValueError(f"unknown backend {backend!r}; "
                         f"choose from {backend_names()}")
    ok, reason = _REGISTRY[backend].available()
    if not ok:
        raise BackendUnavailableError(backend, reason)
    return backend


def backend_uses_plan(backend: Optional[str] = "auto") -> bool:
    """Whether the (resolved) backend consumes a precomputed TilePlan —
    callers skip plan construction for the XLA paths."""
    return resolve_backend(backend) in PLAN_BACKENDS


def backend_ignores_tiles(backend: Optional[str] = "auto") -> bool:
    """Whether tile shapes are a no-op for the (resolved) backend — the
    autotuner skips measurement there (cost-model selection only)."""
    return resolve_backend(backend) in TILE_FREE_BACKENDS


# ---------------------------------------------------------------------------
# Second operation family: ragged-contraction (wgrad) grouped GEMM
# ---------------------------------------------------------------------------

_WGRAD_REGISTRY: dict[str, BackendSpec] = {}


def register_wgrad_backend(name: str, *, description: str,
                           available: Callable[[], "tuple[bool, str]"],
                           run: Callable[..., jax.Array]) -> None:
    """Register a backend for ``grouped_gemm_wgrad`` (the ragged-
    contraction family).  Names are shared with the gemm family so one
    ``KernelConfig.backend`` covers a whole training step."""
    _WGRAD_REGISTRY[name] = BackendSpec(name, description, available, run)


def wgrad_backend_names() -> "tuple[str, ...]":
    return tuple(_WGRAD_REGISTRY)


def wgrad_availability(name: str) -> "tuple[bool, str]":
    name = _ALIASES.get(name, name)
    if name not in _WGRAD_REGISTRY:
        raise ValueError(f"unknown wgrad backend {name!r}; "
                         f"choose from {wgrad_backend_names()}")
    return _WGRAD_REGISTRY[name].available()


def _wgrad_twin(name: str, precision: str) -> str:
    """Family-neutral backend name -> this precision's registry entry
    (``pallas`` <-> ``pallas_fp8``; already-suffixed names normalize)."""
    if name.endswith(_FP8_SUFFIX):
        name = name[: -len(_FP8_SUFFIX)]
    return name + (_FP8_SUFFIX if precision == "fp8" else "")


def resolve_wgrad_backend(backend: Optional[str] = "auto", *,
                          precision: str = "bf16") -> str:
    """Map a requested backend to a concrete, *available* wgrad-family
    entry of the requested operand ``precision`` ("bf16" | "fp8").

    Backend names are family-neutral: ``"pallas"`` with
    ``precision="fp8"`` resolves to the ``pallas_fp8`` entry (and an
    explicitly suffixed ``"pallas_fp8"`` normalizes to whichever twin the
    precision asks for — the operands at the call site, not the name,
    decide the arithmetic).

    Gemm-family names with no wgrad counterpart (``padded_baseline``)
    fall back to auto-resolution instead of raising: a training config
    pins ONE backend string for the whole step, and a forward-only choice
    must not strand the backward.  A name that exists in this family but
    is unavailable still raises — the caller asked for that kernel.
    """
    if precision not in ("bf16", "fp8"):
        raise ValueError(f"unknown wgrad precision {precision!r}; "
                         "use 'bf16' or 'fp8'")
    if backend not in (None, "auto"):
        backend = _ALIASES.get(backend, backend)
        cand = _wgrad_twin(backend, precision)
        if cand in _WGRAD_REGISTRY:
            ok, reason = _WGRAD_REGISTRY[cand].available()
            if not ok:
                raise BackendUnavailableError(cand, reason)
            return cand
        base = _wgrad_twin(backend, "bf16")
        if base not in _REGISTRY:
            raise ValueError(f"unknown backend {backend!r}; wgrad family "
                             f"has {wgrad_backend_names()}")
        # gemm-only backend: fall through to auto
    if _default_backend_override is not None:
        cand = _wgrad_twin(_default_backend_override, precision)
        if cand in _WGRAD_REGISTRY:
            ok, _ = _WGRAD_REGISTRY[cand].available()
            if ok:
                return cand
    for name in AUTO_ORDER:
        cand = _wgrad_twin(name, precision)
        if cand in _WGRAD_REGISTRY:
            ok, _ = _WGRAD_REGISTRY[cand].available()
            if ok:
                return cand
    raise BackendUnavailableError(
        "auto", f"no {precision} wgrad backend is available "
                f"(tried {AUTO_ORDER})")


# ---------------------------------------------------------------------------
# XLA implementations
# ---------------------------------------------------------------------------

def _dequant_a(a_fp8, s_a, dtype):
    m, k = a_fp8.shape
    scales = jnp.repeat(s_a, QUANT_BLOCK, axis=1)[:, :k]
    return (a_fp8.astype(jnp.float32) * scales).astype(dtype)


def _dequant_b(b_fp8, s_b, dtype):
    g, k, n = b_fp8.shape
    scales = jnp.repeat(jnp.repeat(s_b, QUANT_BLOCK, axis=1), QUANT_BLOCK,
                        axis=2)[:, :k, :n]
    return (b_fp8.astype(jnp.float32) * scales).astype(dtype)


def gmm_xla(a_fp8, s_a, b_fp8, s_b, group_sizes, *, out_dtype=jnp.bfloat16,
            compute_dtype=jnp.bfloat16):
    """ragged_dot on dequantized operands (GSPMD-partitionable)."""
    a = _dequant_a(a_fp8, s_a, compute_dtype)
    b = _dequant_b(b_fp8, s_b, compute_dtype)
    out = compat.ragged_dot(a, b, group_sizes.astype(jnp.int32),
                            preferred_element_type=jnp.float32)
    return out.astype(out_dtype)


def gmm_xla_exact(a_fp8, s_a, b_fp8, s_b, group_sizes, *,
                  out_dtype=jnp.bfloat16):
    """Per-K-block f32 math — bit-identical accumulation order to the
    Pallas kernel (ragged_dot per K block, rescale, accumulate in f32)."""
    m, k = a_fp8.shape
    g, _, n = b_fp8.shape
    kb = k // QUANT_BLOCK
    gs = group_sizes.astype(jnp.int32)
    acc = jnp.zeros((m, n), jnp.float32)
    # row scale for token i and k-block j applied post-dot; column scale is
    # constant within a 128-wide n block.
    for j in range(kb):
        aj = a_fp8[:, j * QUANT_BLOCK:(j + 1) * QUANT_BLOCK].astype(jnp.float32)
        bj = b_fp8[:, j * QUANT_BLOCK:(j + 1) * QUANT_BLOCK, :].astype(jnp.float32)
        part = compat.ragged_dot(aj, bj, gs,
                                 preferred_element_type=jnp.float32)
        # gather this token's group column-scales: expand s_b rows per group
        seg = jnp.repeat(jnp.arange(g), gs, total_repeat_length=m)
        col = jnp.repeat(s_b[:, j, :], QUANT_BLOCK, axis=1)[:, :n]   # (g, n)
        acc = acc + part * s_a[:, j][:, None] * col[seg]
    return acc.astype(out_dtype)


def wgrad_xla_ragged(x, dy, group_sizes, *, num_groups,
                     out_dtype=jnp.float32):
    """``compat.ragged_wgrad``: ``ragged_dot_general`` where available,
    transpose-of-``ragged_dot`` otherwise — the historical wgrad path,
    now the portable fallback of this family."""
    return compat.ragged_wgrad(x, dy, group_sizes,
                               num_groups=num_groups).astype(out_dtype)


def wgrad_xla_exact(x, dy, group_sizes, *, num_groups,
                    out_dtype=jnp.float32):
    """Dense f32 oracle: one-hot group membership contracted in a single
    einsum.  O(M*G) membership mask — test-scale only, but every term is
    an exact f32 product, and rows beyond ``sum(group_sizes)`` have an
    all-zero membership row (excluded by construction, not by masking
    garbage after the fact)."""
    m = x.shape[0]
    gs = group_sizes.astype(jnp.int32)
    ends = jnp.cumsum(gs)
    starts = ends - gs
    r = jnp.arange(m, dtype=jnp.int32)
    member = ((r[:, None] >= starts[None, :])
              & (r[:, None] < ends[None, :])).astype(jnp.float32)  # [M, G]
    dw = jnp.einsum("mg,mk,mn->gkn", member, x.astype(jnp.float32),
                    dy.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    return dw.astype(out_dtype)


def wgrad_fp8_xla_ragged(x_fp8, s_x, dy_fp8, s_dy, group_sizes, *,
                         num_groups, out_dtype=jnp.float32):
    """fp8-operand twin of :func:`wgrad_xla_ragged`: dequantize both
    operands up front (``_dequant_a`` — the 1x128 row-tile layout is the
    same on the x and dy sides) and reuse the bf16 ragged contraction."""
    x = _dequant_a(x_fp8, s_x, jnp.bfloat16)
    dy = _dequant_a(dy_fp8, s_dy, jnp.bfloat16)
    return wgrad_xla_ragged(x, dy, group_sizes, num_groups=num_groups,
                            out_dtype=out_dtype)


def wgrad_fp8_xla_exact(x_fp8, s_x, dy_fp8, s_dy, group_sizes, *,
                        num_groups, out_dtype=jnp.float32):
    """fp8-operand oracle: exact f32 dequantization then the dense
    one-hot f32 contraction — the ground truth the fp8 wgrad kernel's
    per-visit dequantization is validated against."""
    x = _dequant_a(x_fp8, s_x, jnp.float32)
    dy = _dequant_a(dy_fp8, s_dy, jnp.float32)
    return wgrad_xla_exact(x, dy, group_sizes, num_groups=num_groups,
                           out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# Built-in backend registrations
# ---------------------------------------------------------------------------

def _avail_always():
    return True, ""


def _avail_tpu():
    if compat.has_tpu():
        return True, ""
    return False, ("requires a TPU (jax.default_backend() == 'tpu'); "
                   "use 'pallas_interpret' for CPU-verifiable runs")


def _avail_ragged_dot():
    if compat.has_ragged_dot():
        return True, ""
    return False, (f"jax {jax.__version__} has no jax.lax.ragged_dot")


def _run_pallas(a8, sa, b8, sb, gs, *, num_groups, config, plan, interpret):
    return gmm_pallas(a8, sa, b8, sb, gs, num_groups=num_groups,
                      block_m=config.block_m, block_n=config.block_n,
                      block_k=config.block_k, out_dtype=config.out_dtype,
                      interpret=interpret, plan=plan)


def _run_xla_ragged(a8, sa, b8, sb, gs, *, config, **_):
    return gmm_xla(a8, sa, b8, sb, gs, out_dtype=config.out_dtype)


def _run_xla_exact(a8, sa, b8, sb, gs, *, config, **_):
    return gmm_xla_exact(a8, sa, b8, sb, gs, out_dtype=config.out_dtype)


def _run_padded_baseline(a8, sa, b8, sb, gs, *, config, **_):
    # deferred import: padding_baseline routes its aligned GEMM back
    # through this registry.  A caller's TilePlan never applies here —
    # padding changes the group offsets, so the baseline re-plans.
    from repro.core import padding_baseline as pb
    inner = "pallas" if compat.has_tpu() else "pallas_interpret"
    return pb.grouped_gemm_fp8_padded(a8, sa, b8, sb, gs,
                                      config=config.with_(backend=inner))


register_backend(
    "pallas",
    description="compiled Pallas TPU kernel (padding-free, paper §2)",
    available=_avail_tpu,
    run=lambda *a, **kw: _run_pallas(*a, interpret=False, **kw))
register_backend(
    "pallas_interpret",
    description="Pallas kernel in interpret mode — CPU-verifiable, "
                "bit-identical to 'pallas'",
    available=_avail_always,
    run=lambda *a, **kw: _run_pallas(*a, interpret=True, **kw))
register_backend(
    "xla_ragged",
    description="jax.lax.ragged_dot on bf16-dequantized operands "
                "(portable / GSPMD)",
    available=_avail_ragged_dot,
    run=_run_xla_ragged)
register_backend(
    "xla_exact",
    description="per-K-block f32 oracle with the kernel's accumulation "
                "order",
    available=_avail_ragged_dot,
    run=_run_xla_exact)
register_backend(
    "padded_baseline",
    description="the paper's baseline: pad groups to block_m, aligned "
                "grouped GEMM, unpad",
    available=_avail_always,
    run=_run_padded_baseline)


def _run_pallas_wgrad(x, dy, gs, *, num_groups, config, plan, interpret):
    return gmm_pallas_wgrad(x, dy, gs, num_groups=num_groups,
                            block_m=config.block_m, block_n=config.block_n,
                            block_k=config.block_k,
                            out_dtype=config.out_dtype, interpret=interpret,
                            plan=plan)


def _run_wgrad_xla_ragged(x, dy, gs, *, num_groups, config, **_):
    return wgrad_xla_ragged(x, dy, gs, num_groups=num_groups,
                            out_dtype=config.out_dtype)


def _run_wgrad_xla_exact(x, dy, gs, *, num_groups, config, **_):
    return wgrad_xla_exact(x, dy, gs, num_groups=num_groups,
                           out_dtype=config.out_dtype)


def _avail_ragged_wgrad():
    if compat.has_ragged_dot_general() or compat.has_ragged_dot():
        return True, ""
    return False, (f"jax {jax.__version__} has neither "
                   "jax.lax.ragged_dot_general nor jax.lax.ragged_dot")


register_wgrad_backend(
    "pallas",
    description="compiled Pallas TPU kernel: ragged-M contraction with "
                "per-visit masked accumulation (padding-free wgrad)",
    available=_avail_tpu,
    run=lambda *a, **kw: _run_pallas_wgrad(*a, interpret=False, **kw))
register_wgrad_backend(
    "pallas_interpret",
    description="wgrad kernel in interpret mode — CPU-verifiable, "
                "bit-identical to 'pallas'",
    available=_avail_always,
    run=lambda *a, **kw: _run_pallas_wgrad(*a, interpret=True, **kw))
register_wgrad_backend(
    "xla_ragged",
    description="compat.ragged_wgrad (ragged_dot_general or transposed "
                "ragged_dot) — portable fallback",
    available=_avail_ragged_wgrad,
    run=_run_wgrad_xla_ragged)
register_wgrad_backend(
    "xla_exact",
    description="dense one-hot f32 oracle for the ragged contraction",
    available=_avail_always,
    run=_run_wgrad_xla_exact)


def _run_pallas_wgrad_fp8(x8, sx, dy8, sdy, gs, *, num_groups, config, plan,
                          interpret):
    return gmm_pallas_wgrad_fp8(x8, sx, dy8, sdy, gs, num_groups=num_groups,
                                block_m=config.block_m,
                                block_n=config.block_n,
                                block_k=config.block_k,
                                out_dtype=config.out_dtype,
                                interpret=interpret, plan=plan)


def _run_wgrad_fp8_xla_ragged(x8, sx, dy8, sdy, gs, *, num_groups, config,
                              **_):
    return wgrad_fp8_xla_ragged(x8, sx, dy8, sdy, gs, num_groups=num_groups,
                                out_dtype=config.out_dtype)


def _run_wgrad_fp8_xla_exact(x8, sx, dy8, sdy, gs, *, num_groups, config,
                             **_):
    return wgrad_fp8_xla_exact(x8, sx, dy8, sdy, gs, num_groups=num_groups,
                               out_dtype=config.out_dtype)


# fp8-operand twins — the precision dimension of the wgrad registry
register_wgrad_backend(
    "pallas_fp8",
    description="compiled Pallas TPU kernel: ragged-M contraction on fp8 "
                "operands, per-visit dequant folded into the masked "
                "prologue (arXiv 2505.20524 all-fp8 step)",
    available=_avail_tpu,
    run=lambda *a, **kw: _run_pallas_wgrad_fp8(*a, interpret=False, **kw))
register_wgrad_backend(
    "pallas_interpret_fp8",
    description="fp8 wgrad kernel in interpret mode — CPU-verifiable, "
                "bit-identical to 'pallas_fp8'",
    available=_avail_always,
    run=lambda *a, **kw: _run_pallas_wgrad_fp8(*a, interpret=True, **kw))
register_wgrad_backend(
    "xla_ragged_fp8",
    description="up-front bf16 dequantization + compat.ragged_wgrad — "
                "portable fp8-operand fallback",
    available=_avail_ragged_wgrad,
    run=_run_wgrad_fp8_xla_ragged)
register_wgrad_backend(
    "xla_exact_fp8",
    description="f32 dequantization + dense one-hot f32 oracle for the "
                "fp8-operand ragged contraction",
    available=_avail_always,
    run=_run_wgrad_fp8_xla_exact)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def grouped_gemm_fp8(a_fp8, s_a, b_fp8, s_b, group_sizes, *,
                     backend: Optional[str] = None,
                     num_groups: Optional[int] = None,
                     config: Optional[KernelConfig] = None,
                     out_dtype=None,
                     plan: Optional[TilePlan] = None):
    """Quantized grouped GEMM through the registry (the low-level entry —
    operands already fp8 with DeepSeek-style tile/block scales).

    Tile shapes travel in ``config`` (a :class:`KernelConfig`; defaults to
    the installed/per-device default); ``backend=``/``out_dtype=`` are
    per-call overrides of the config's fields.  ``plan`` is an optional
    precomputed :class:`TilePlan` for plan-consuming backends.
    """
    cfg = resolve_config(config, backend=backend, out_dtype=out_dtype)
    if cfg.out_dtype is None:
        cfg = cfg.with_(out_dtype=jnp.bfloat16)
    name = resolve_backend(cfg.backend)
    return _REGISTRY[name].run(
        a_fp8, s_a, b_fp8, s_b, group_sizes, num_groups=num_groups,
        config=cfg, plan=plan)


def grouped_gemm(x, w, group_sizes, *, backend: Optional[str] = None,
                 out_dtype=None, config: Optional[KernelConfig] = None,
                 plan: Optional[TilePlan] = None):
    """Unified high-level grouped GEMM: ``y[rows of g] = x[rows of g] @
    w[g]`` with the paper's fp8 recipe (1x128 activation tiles, 128x128
    weight blocks) applied before dispatch.

    x: [M, K] float; w: [G, K, N] float; group_sizes: [G] int.
    Not differentiable — training goes through
    :func:`repro.core.grouped_gemm.grouped_linear`, which wraps the same
    registry in a custom VJP.
    """
    a8, sa = _ref.quantize_tilewise_ref(x.astype(jnp.float32))
    b8, sb = jax.vmap(_ref.quantize_blockwise_ref)(w.astype(jnp.float32))
    # explicit out_dtype > config's pinned out_dtype > x.dtype
    cfg = resolve_config(config, backend=backend, out_dtype=out_dtype)
    if cfg.out_dtype is None:
        cfg = cfg.with_(out_dtype=x.dtype)
    return grouped_gemm_fp8(a8, sa, b8, sb, group_sizes,
                            num_groups=w.shape[0], config=cfg, plan=plan)


def _wgrad_tile_fallback(name: str, cfg: KernelConfig, m: int, k: int,
                         n: int, precision: str) -> str:
    """Shared tile-incompatibility policy for both wgrad precisions: an
    *explicitly requested* plan backend whose tile shapes don't divide
    (K, N) raises via ``validate``; an auto-resolved one falls back to the
    first available tile-free entry of the same precision."""
    explicit = cfg.backend not in (None, "auto") \
        and _wgrad_twin(_ALIASES.get(cfg.backend, cfg.backend),
                        precision) in _WGRAD_REGISTRY
    if explicit:
        cfg.validate(m, k, n)            # raises with the shape message
    for fallback in (_wgrad_twin("xla_ragged", precision),
                     _wgrad_twin("xla_exact", precision)):
        ok, _ = _WGRAD_REGISTRY[fallback].available()
        if ok:
            return fallback
    raise BackendUnavailableError(
        name, f"tile shapes (block_k={cfg.block_k}, "
              f"block_n={cfg.block_n}) do not divide (K={k}, N={n})"
              f" and no tile-free {precision} wgrad backend is available")


def grouped_gemm_wgrad(x, dy, group_sizes, *,
                       num_groups: Optional[int] = None,
                       backend: Optional[str] = None,
                       config: Optional[KernelConfig] = None,
                       out_dtype=None,
                       plan: Optional[TilePlan] = None):
    """Ragged-contraction grouped GEMM ``dw[g] = x_g^T @ dy_g`` through
    the wgrad registry.

    x: [M, K] float; dy: [M, N] float; group_sizes: [G] int,
    ``sum <= M`` (tail rows are excluded from the contraction).  Returns
    [G, K, N] (default f32 — wgrad is the highest-precision GEMM of the
    step).  ``plan`` is the routing decision's :class:`TilePlan` — the
    same object the forward/dgrad GEMMs consumed; the schedule is
    orientation-agnostic, so nothing is rebuilt here.

    An *auto-resolved* plan backend whose tile shapes don't divide
    (K, N) falls back to the first tile-free backend (the bf16 path calls
    in with arbitrary model dims); an explicitly requested one raises.
    """
    cfg = resolve_config(config, backend=backend, out_dtype=out_dtype)
    if cfg.out_dtype is None:
        cfg = cfg.with_(out_dtype=jnp.float32)
    num_groups = num_groups if num_groups is not None \
        else group_sizes.shape[0]
    name = resolve_wgrad_backend(cfg.backend)
    k, n = x.shape[1], dy.shape[1]
    if name in PLAN_BACKENDS and not cfg.compatible(k, n):
        name = _wgrad_tile_fallback(name, cfg, x.shape[0], k, n, "bf16")
    return _WGRAD_REGISTRY[name].run(
        x, dy, group_sizes, num_groups=num_groups, config=cfg, plan=plan)


def grouped_gemm_wgrad_fp8(x_fp8, s_x, dy_fp8, s_dy, group_sizes, *,
                           num_groups: Optional[int] = None,
                           backend: Optional[str] = None,
                           config: Optional[KernelConfig] = None,
                           out_dtype=None,
                           plan: Optional[TilePlan] = None):
    """fp8-operand ragged-contraction grouped GEMM
    ``dw[g] = dequant(x)_g^T @ dequant(dy)_g`` through the wgrad
    registry's fp8 twins (arXiv 2505.20524's all-fp8 training step).

    x_fp8/s_x: [M, K] fp8 + [M, ceil(K/128)] f32 — the forward's quantized
    activation and its 1x128 tile scales (the VJP residual, NOT
    re-quantized here); dy_fp8/s_dy: [M, N] fp8 + [M, ceil(N/128)] f32 —
    the upstream gradient as the dgrad already quantized it.
    ``backend`` names the family-neutral engine (``"pallas"``,
    ``"pallas_interpret"``, ...); resolution appends the precision twin.
    Same fallback semantics as :func:`grouped_gemm_wgrad`: auto-resolved
    tile shapes that don't divide (K, N) fall back to a tile-free fp8
    entry, explicit requests raise.
    """
    cfg = resolve_config(config, backend=backend, out_dtype=out_dtype)
    if cfg.out_dtype is None:
        cfg = cfg.with_(out_dtype=jnp.float32)
    num_groups = num_groups if num_groups is not None \
        else group_sizes.shape[0]
    name = resolve_wgrad_backend(cfg.backend, precision="fp8")
    k, n = x_fp8.shape[1], dy_fp8.shape[1]
    if name in PLAN_BACKENDS and not cfg.compatible(k, n):
        name = _wgrad_tile_fallback(name, cfg, x_fp8.shape[0], k, n, "fp8")
    return _WGRAD_REGISTRY[name].run(
        x_fp8, s_x, dy_fp8, s_dy, group_sizes, num_groups=num_groups,
        config=cfg, plan=plan)


def quantize_tilewise(x, *, backend: Optional[str] = None):
    """1x128 per-tile fp8 activation quantization through the registry.

    A pure-quantization call never *needs* a kernel backend — when
    *auto*-resolution fails (e.g. an installed default naming an
    unavailable backend), fall back to the XLA reference implementation
    instead of refusing work the ref path can always serve.  An
    explicitly requested unavailable backend still raises: the caller
    asked for that kernel, not a silent stand-in.
    """
    explicit = backend not in (None, "auto")
    try:
        backend = resolve_backend(backend)
    except BackendUnavailableError:
        if explicit:
            raise
        return _ref.quantize_tilewise_ref(x)
    if backend == "pallas":
        return quantize_tilewise_pallas(x, interpret=False)
    if backend == "pallas_interpret":
        return quantize_tilewise_pallas(x, interpret=True)
    return _ref.quantize_tilewise_ref(x)


def quantize_blockwise(w, *, backend: Optional[str] = None):
    """128x128 weight quantization through the registry seam.

    No kernel backend implements this yet (weights are quantized once per
    step outside the hot loop, so XLA ref math is fine everywhere), but
    resolution runs here so a future quant kernel plugs in at ONE place
    and the batched path below inherits it.  Same refusal semantics as
    :func:`quantize_tilewise`: auto-resolution failures fall back to ref,
    an explicitly requested unavailable backend raises.
    """
    explicit = backend not in (None, "auto")
    try:
        resolve_backend(backend)
    except BackendUnavailableError:
        if explicit:
            raise
    return _ref.quantize_blockwise_ref(w)


def quantize_blockwise_batched(w, *, backend: Optional[str] = None):
    """[G, K, N] -> (fp8[G, K, N], f32[G, KB, NB]) — vmap of the
    registry-routed :func:`quantize_blockwise`, so a future quant kernel
    covers the batched (per-expert) path automatically."""
    return jax.vmap(
        lambda wg: quantize_blockwise(wg, backend=backend))(w)
