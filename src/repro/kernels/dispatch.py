"""Unified operator registry for every grouped-GEMM-shaped kernel seam.

The paper's core idea is ONE dispatch seam that adapts to variable group
dimensions at runtime instead of padding.  This module is that seam for
the whole repo: a single registry keyed by :class:`OpKey` ``(family,
precision)`` —

  =============  ===========  ==============================================
  family         precision    operation
  =============  ===========  ==============================================
  ``gemm``       ``fp8``      quantized grouped GEMM ``y[rows of g] =
                              a_g @ b[g]`` (ragged M output rows; the
                              paper's forward/dgrad orientation)
  ``gemm``       ``bf16``     the same orientation on bf16 operands — a
                              true Pallas kernel sharing the fp8 twin's
                              visit schedule (so fp8-vs-bf16 comparisons
                              measure OUR schedule on both sides), with
                              ``jax.lax.ragged_dot`` as the portable /
                              GSPMD fallback
  ``wgrad``      ``bf16``     ragged-contraction ``dw[g] = x_g^T @ dy_g``
                              (M contracted; DeepSeek recipe operands)
  ``wgrad``      ``fp8``      the same contraction on fp8 operands with
                              1x128 tile scales, dequantized per visit
                              (arXiv 2505.20524's all-fp8 step)
  ``gemm_quant`` ``fp8``      grouped GEMM with a fused quantizing
                              epilogue: the producer emits the fp8 payload
                              + 1x128 tile scales directly (the bf16
                              output never exists; kernel entries fuse,
                              XLA entries compose GEMM + quantize so the
                              matrix stays total)
  ``quantize``   ``fp8``      1x128 per-tile fp8 activation quantization
                              (the producer of the gemm family's operands)
  ``act_quant``  ``fp8``      fused activation -> 1x128 fp8 quantization
                              (``silu(g)*u`` / ``gelu(g)`` epilogue; the
                              bf16 intermediate never touches HBM; fp8
                              inputs with scales dequantize on load)
  =============  ===========  ==============================================

Backend *names* are family-neutral and shared across the table: one
``KernelConfig.backend`` string ("pallas", "xla_ragged", ...) rides a
whole training step — forward and dgrad through ``(gemm, fp8)``, wgrad
through ``(wgrad, <precision>)``, activation quantization through
``(quantize, fp8)`` — and the same :class:`~repro.kernels.plan.TilePlan`
through all of them.  Each entry is a :class:`BackendSpec` with

  * an ``available()`` probe returning ``(ok, reason)`` — built on
    :mod:`repro.compat` capability probes so selection is testable by
    monkeypatching, and refusal is an explicit
    :class:`BackendUnavailableError` instead of a deep ``AttributeError``;
  * a ``run()`` implementing the family's operation under a
    :class:`repro.kernels.plan.KernelConfig`, optionally consuming a
    precomputed :class:`~repro.kernels.plan.TilePlan`;
  * ``uses_plan`` / ``uses_tiles`` flags — plan/tile-free membership is a
    property of the registry entry, not a parallel frozenset to maintain.

All resolution goes through ONE function, :func:`resolve`, which owns

  * precision-twin derivation (``resolve(("wgrad", "fp8"), "pallas")``
    lands on the fp8 wgrad kernel; the historical ``<name>_fp8`` public
    spelling normalizes to the same entry),
  * availability checks (explicit requests raise with the probe's
    reason),
  * explicit-vs-auto fallback semantics (a *gemm-only* name like
    ``padded_baseline`` auto-resolves in the wgrad family instead of
    stranding a training config's backward; an explicitly requested but
    unavailable entry always raises),
  * tile-compatibility fallback (an *auto-resolved* plan backend whose
    tile shapes don't divide the problem falls back to the first
    tile-free entry of the same op; an explicit request raises via
    ``KernelConfig.validate``).

``backend="auto"`` resolves to the first available of
``pallas`` > ``xla_ragged`` > ``pallas_interpret``.  ``"xla"`` is kept as
an alias of ``"xla_ragged"`` for pre-registry callers.

Every pre-unification public entry point (``grouped_gemm``,
``grouped_gemm_fp8``, ``grouped_gemm_wgrad``, ``grouped_gemm_wgrad_fp8``,
``quantize_tilewise``, ``register_backend``, ``resolve_backend``,
``resolve_wgrad_backend``, ...) survives as a thin alias over the unified
seam — new backends, precisions, and op families plug in via
:func:`register_operator` without growing another registry copy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.analysis import events as _events
from repro.kernels import ref as _ref
from repro.kernels.grouped_gemm_kernel import (QUANT_BLOCK, gmm_pallas,
                                               gmm_pallas_bf16,
                                               gmm_pallas_quant)
from repro.kernels.plan import (KernelConfig, TilePlan,  # noqa: F401
                                make_tile_plan, resolve_config)
from repro.kernels.epilogue_kernel import act_quantize_pallas
from repro.kernels.quant_kernel import quantize_tilewise_pallas
from repro.kernels.wgrad_kernel import gmm_pallas_wgrad, gmm_pallas_wgrad_fp8

# auto-resolution preference, best first (shared by every op family)
AUTO_ORDER = ("pallas", "xla_ragged", "pallas_interpret")

_ALIASES = {"xla": "xla_ragged"}

# suffix of the wgrad family's historical fp8-twin public names
# ("pallas_fp8" etc.); resolution normalizes it away — the OpKey precision,
# not the name, selects the arithmetic
_FP8_SUFFIX = "_fp8"

FAMILIES = ("gemm", "gemm_quant", "wgrad", "quantize", "act_quant")
PRECISIONS = ("bf16", "fp8")


@dataclasses.dataclass(frozen=True)
class OpKey:
    """One operator of the registry: an operation family at an operand
    precision.  Hashable; accepted anywhere as a plain ``(family,
    precision)`` tuple."""
    family: str      # "gemm" | "gemm_quant" | "wgrad" | "quantize" | "act_quant"
    precision: str   # "bf16" | "fp8"

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown op family {self.family!r}; "
                             f"choose from {FAMILIES}")
        if self.precision not in PRECISIONS:
            raise ValueError(f"unknown operand precision "
                             f"{self.precision!r}; choose from {PRECISIONS}")


def _op_key(op_key) -> OpKey:
    if isinstance(op_key, OpKey):
        return op_key
    return OpKey(*op_key)


class BackendUnavailableError(RuntimeError):
    """Requested backend cannot run here; ``.reason`` says why."""

    def __init__(self, name: str, reason: str):
        super().__init__(f"grouped-GEMM backend {name!r} unavailable: "
                         f"{reason}")
        self.backend = name
        self.reason = reason


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    name: str
    description: str
    available: Callable[[], "tuple[bool, str]"]   # (ok, reason-if-not)
    run: Callable[..., Any]
    uses_plan: bool = False     # walks the TilePlan visitation schedule
    uses_tiles: bool = False    # honours KernelConfig tile shapes at all


# THE registry: every (family, precision) operator's backend table lives
# in this one dict — there is no per-family registry copy to keep in sync.
_OPERATORS: "dict[OpKey, dict[str, BackendSpec]]" = {}

_default_backend_override: Optional[str] = None


def register_operator(op_key, name: str, *, description: str,
                      available: Callable[[], "tuple[bool, str]"],
                      run: Callable[..., Any],
                      uses_plan: bool = False,
                      uses_tiles: bool = False) -> None:
    """Register a backend for one ``(family, precision)`` operator.
    Later PRs (autotuned variants, new hardware paths, new precisions)
    plug in here — this is the ONLY write path into the registry."""
    key = _op_key(op_key)
    _OPERATORS.setdefault(key, {})[name] = BackendSpec(
        name, description, available, run,
        uses_plan=uses_plan, uses_tiles=uses_tiles)


def op_keys() -> "tuple[OpKey, ...]":
    return tuple(_OPERATORS)


def _table(op_key) -> "dict[str, BackendSpec]":
    key = _op_key(op_key)
    if key not in _OPERATORS:
        raise ValueError(f"no operator registered for {key}; "
                         f"registered: {op_keys()}")
    return _OPERATORS[key]


def _canonical(op_key: OpKey, name: str) -> str:
    """Public spelling -> registry name: aliases ("xla"), and — in the
    wgrad family only — the historical ``<name>_fp8`` twin suffix."""
    name = _ALIASES.get(name, name)
    if op_key.family == "wgrad" and name.endswith(_FP8_SUFFIX):
        name = name[: -len(_FP8_SUFFIX)]
    return name


def _display(op_key: OpKey, name: str) -> str:
    """Registry name -> the public spelling pre-unification callers know
    (the wgrad family's fp8 twins carried a ``_fp8`` suffix)."""
    if op_key.family == "wgrad" and op_key.precision == "fp8":
        return name + _FP8_SUFFIX
    return name


def resolve(op_key, backend: Optional[str] = None, *,
            tile: "Optional[tuple]" = None) -> str:
    """THE resolution path: map a requested backend (or ``"auto"`` /
    ``None``) to a concrete, *available* entry of ``op_key``'s table.

    ``tile``, when given, is ``(config, m, k, n)`` and enables the
    tile-compatibility policy for plan-consuming entries: an explicitly
    requested backend whose tile shapes don't divide ``(k, n)`` raises
    via ``config.validate``; an auto-resolved one falls back to the first
    available tile-free entry of the same operator.

    Fallback semantics (one place, every family):

      * explicit name in this op's table but unavailable -> raise
        :class:`BackendUnavailableError` with the probe's reason;
      * explicit name known only to the gemm family (``padded_baseline``
        in the wgrad family) -> auto-resolve instead of stranding a
        training config's backward;
      * name known nowhere -> ``ValueError``;
      * ``auto``/``None`` -> the installed default override if usable
        (the gemm/quantize families treat an unavailable override as an
        explicit request and raise — callers like ``quantize_tilewise``
        turn that into a ref fallback; the wgrad family skips it), then
        the first available of :data:`AUTO_ORDER`.
    """
    key = _op_key(op_key)
    table = _table(key)
    explicit = backend not in (None, "auto")

    if explicit:
        name = _canonical(key, backend)
        if name in table:
            ok, reason = table[name].available()
            if not ok:
                raise BackendUnavailableError(_display(key, name), reason)
            return _tile_policy(key, name, tile, explicit=True)
        if name not in _OPERATORS[OpKey("gemm", "fp8")]:
            known = tuple(_display(key, n) for n in table)
            raise ValueError(f"unknown backend {backend!r}; "
                             f"{key.family}/{key.precision} has {known}")
        # a gemm-only backend name: auto-resolve from here on — a
        # training config pins ONE backend string for the whole step, and
        # a forward-only choice must not strand the other families
        explicit = False

    if _default_backend_override is not None:
        name = _canonical(key, _default_backend_override)
        if key.family == "wgrad":
            # the wgrad family tries the override, then falls back: the
            # override seam predates the family and a gemm-centric pin
            # must not strand the backward
            if name in table and table[name].available()[0]:
                return _tile_policy(key, name, tile, explicit=False)
        elif name in table:
            # the gemm/quantize families treat an unavailable override as
            # an explicit request (historical semantics — quantize's ref
            # fallback depends on the raise); an override the operator
            # never registered (e.g. a kernel name against the bf16
            # baseline table) auto-resolves instead
            ok, reason = table[name].available()
            if not ok:
                raise BackendUnavailableError(_display(key, name), reason)
            return _tile_policy(key, name, tile, explicit=False)

    for cand in AUTO_ORDER:
        if cand in table and table[cand].available()[0]:
            return _tile_policy(key, cand, tile, explicit=False)
    raise BackendUnavailableError(
        "auto", f"no {key.precision} {key.family} backend is available "
                f"(tried {AUTO_ORDER})")


def _tile_policy(key: OpKey, name: str, tile, *, explicit: bool) -> str:
    """Shared tile-incompatibility policy: see :func:`resolve`."""
    if tile is None:
        return name
    table = _OPERATORS[key]
    if not table[name].uses_plan:
        return name
    cfg, m, k, n = tile
    if cfg.compatible(k, n, family=key.family):
        return name
    if explicit:
        # raises with the shape message (or the computed VMEM footprint)
        cfg.validate(m, k, n, family=key.family)
    for fb in ("xla_ragged", "xla_exact"):
        if fb in table and table[fb].available()[0]:
            return fb
    eff_k, eff_n = cfg.effective_blocks(key.family)
    raise BackendUnavailableError(
        _display(key, name),
        f"tile shapes (block_k={eff_k}, block_n={eff_n}, spans included) "
        f"do not divide (K={k}, N={n}) and no tile-free {key.precision} "
        f"{key.family} backend is available")


def op_backend_names(op_key) -> "tuple[str, ...]":
    return tuple(_table(op_key))


def op_availability(op_key, name: str) -> "tuple[bool, str]":
    key = _op_key(op_key)
    table = _table(key)
    name = _canonical(key, name)
    if name not in table:
        raise ValueError(
            f"unknown backend {name!r} for {key.family}/{key.precision}; "
            f"choose from {tuple(_display(key, n) for n in table)}")
    return table[name].available()


def op_uses_plan(op_key, backend: Optional[str] = "auto") -> bool:
    key = _op_key(op_key)
    return _table(key)[resolve(key, backend)].uses_plan


def op_ignores_tiles(op_key, backend: Optional[str] = "auto") -> bool:
    key = _op_key(op_key)
    return not _table(key)[resolve(key, backend)].uses_tiles


def backend_matrix(op_key=None) -> "dict[str, Any]":
    """Availability/description rows for docs and CLIs.

    ``op_key=None`` keeps the historical shape — the ``(gemm, fp8)``
    table keyed by backend name.  ``op_key="all"`` returns every
    operator: ``{"family/precision": {name: row}}`` (the source of the
    README's family x precision x backend table); a concrete
    ``OpKey``/tuple returns that operator's rows.
    """
    if op_key == "all":
        return {f"{k.family}/{k.precision}": backend_matrix(k)
                for k in sorted(_OPERATORS,
                                key=lambda k: (FAMILIES.index(k.family),
                                               k.precision))}
    key = _op_key(op_key) if op_key is not None else OpKey("gemm", "fp8")
    out = {}
    for name, spec in _table(key).items():
        ok, reason = spec.available()
        out[name] = {"available": ok, "reason": reason,
                     "description": spec.description,
                     "uses_plan": spec.uses_plan,
                     "uses_tiles": spec.uses_tiles}
    return out


def format_backend_matrix() -> str:
    """The README's backend table, generated (``python -m
    repro.kernels.dispatch`` prints it)."""
    lines = ["| family | precision | backend | needs | description |",
             "| --- | --- | --- | --- | --- |"]
    for label, rows in backend_matrix("all").items():
        family, precision = label.split("/")
        for name, row in rows.items():
            disp = _display(OpKey(family, precision), name)
            needs = "—" if row["available"] else row["reason"].split(";")[0]
            if name == "pallas":
                needs = "TPU"
            lines.append(f"| `{family}` | `{precision}` | `{disp}` | "
                         f"{needs} | {row['description']} |")
    return "\n".join(lines)


def set_default_backend(name: Optional[str]) -> None:
    """Override what ``backend=None`` / ``"auto"`` resolves to."""
    global _default_backend_override
    if name is not None:
        name = _ALIASES.get(name, name)
        if name not in _table(OpKey("gemm", "fp8")):
            raise ValueError(f"unknown backend {name!r}; "
                             f"choose from {backend_names()}")
    _default_backend_override = name


def default_backend() -> str:
    return resolve_backend("auto")


# ---------------------------------------------------------------------------
# Pre-unification aliases (the public surface of PRs 1-4, unchanged)
# ---------------------------------------------------------------------------

def register_backend(name: str, *, description: str,
                     available: Callable[[], "tuple[bool, str]"],
                     run: Callable[..., jax.Array],
                     uses_plan: bool = False,
                     uses_tiles: bool = False) -> None:
    """Alias: register a ``(gemm, fp8)`` backend."""
    register_operator(OpKey("gemm", "fp8"), name, description=description,
                      available=available, run=run, uses_plan=uses_plan,
                      uses_tiles=uses_tiles)


def register_wgrad_backend(name: str, *, description: str,
                           available: Callable[[], "tuple[bool, str]"],
                           run: Callable[..., jax.Array],
                           uses_plan: bool = False,
                           uses_tiles: bool = False) -> None:
    """Alias: register a wgrad-family backend.  A ``<name>_fp8`` spelling
    registers the fp8-precision twin (the OpKey carries the precision;
    the suffix is only the historical public naming)."""
    precision = "fp8" if name.endswith(_FP8_SUFFIX) else "bf16"
    base = name[: -len(_FP8_SUFFIX)] if precision == "fp8" else name
    register_operator(OpKey("wgrad", precision), base,
                      description=description, available=available, run=run,
                      uses_plan=uses_plan, uses_tiles=uses_tiles)


def backend_names() -> "tuple[str, ...]":
    return op_backend_names(OpKey("gemm", "fp8"))


def wgrad_backend_names() -> "tuple[str, ...]":
    key16, key8 = OpKey("wgrad", "bf16"), OpKey("wgrad", "fp8")
    return (tuple(_table(key16))
            + tuple(_display(key8, n) for n in _table(key8)))


def availability(name: str) -> "tuple[bool, str]":
    name = _ALIASES.get(name, name)
    if name not in _table(OpKey("gemm", "fp8")):
        raise ValueError(f"unknown backend {name!r}; "
                         f"choose from {backend_names()}")
    return op_availability(OpKey("gemm", "fp8"), name)


def wgrad_availability(name: str) -> "tuple[bool, str]":
    precision = "fp8" if _ALIASES.get(name, name).endswith(_FP8_SUFFIX) \
        else "bf16"
    key = OpKey("wgrad", precision)
    base = _canonical(key, name)
    if base not in _table(key):
        raise ValueError(f"unknown wgrad backend {name!r}; "
                         f"choose from {wgrad_backend_names()}")
    return op_availability(key, base)


def resolve_backend(backend: Optional[str] = "auto") -> str:
    """Alias: resolve in the ``(gemm, fp8)`` table."""
    return resolve(OpKey("gemm", "fp8"), backend)


def resolve_wgrad_backend(backend: Optional[str] = "auto", *,
                          precision: str = "bf16") -> str:
    """Alias: resolve in the wgrad table of the requested operand
    ``precision`` ("bf16" | "fp8"); returns the historical public
    spelling (fp8 entries carry the ``_fp8`` suffix).

    Backend names are family-neutral: ``"pallas"`` with
    ``precision="fp8"`` resolves to the fp8 wgrad kernel (and an
    explicitly suffixed ``"pallas_fp8"`` normalizes to whichever twin the
    precision asks for — the operands at the call site, not the name,
    decide the arithmetic)."""
    if precision not in PRECISIONS:
        raise ValueError(f"unknown wgrad precision {precision!r}; "
                         "use 'bf16' or 'fp8'")
    key = OpKey("wgrad", precision)
    return _display(key, resolve(key, backend))


def backend_uses_plan(backend: Optional[str] = "auto") -> bool:
    """Whether the (resolved) gemm backend consumes a precomputed
    TilePlan — callers skip plan construction for the XLA paths."""
    return op_uses_plan(OpKey("gemm", "fp8"), backend)


def backend_ignores_tiles(backend: Optional[str] = "auto") -> bool:
    """Whether tile shapes are a no-op for the (resolved) gemm backend —
    the autotuner skips measurement there (cost-model selection only)."""
    return op_ignores_tiles(OpKey("gemm", "fp8"), backend)


def _plan_tile_frozenset(uses_plan: bool) -> "frozenset[str]":
    # the tile-free view keeps its historical GEMM/wgrad contents — the
    # quantize-flavoured families (whose ref entries are trivially
    # tile-free) stay out of the back-compat frozenset
    names = set()
    for key, table in _OPERATORS.items():
        for name, spec in table.items():
            if (spec.uses_plan if uses_plan
                    else (not spec.uses_tiles
                          and key.family not in ("gemm_quant", "quantize",
                                                 "act_quant"))):
                names.add(_display(key, name))
    return frozenset(names)


# ---------------------------------------------------------------------------
# XLA implementations
# ---------------------------------------------------------------------------

def _dequant_a(a_fp8, s_a, dtype):
    m, k = a_fp8.shape
    scales = jnp.repeat(s_a, QUANT_BLOCK, axis=1)[:, :k]
    return (a_fp8.astype(jnp.float32) * scales).astype(dtype)


def _dequant_b(b_fp8, s_b, dtype):
    g, k, n = b_fp8.shape
    scales = jnp.repeat(jnp.repeat(s_b, QUANT_BLOCK, axis=1), QUANT_BLOCK,
                        axis=2)[:, :k, :n]
    return (b_fp8.astype(jnp.float32) * scales).astype(dtype)


def gmm_xla(a_fp8, s_a, b_fp8, s_b, group_sizes, *, out_dtype=jnp.bfloat16,
            compute_dtype=jnp.bfloat16):
    """ragged_dot on dequantized operands (GSPMD-partitionable)."""
    a = _dequant_a(a_fp8, s_a, compute_dtype)
    b = _dequant_b(b_fp8, s_b, compute_dtype)
    out = compat.ragged_dot(a, b, group_sizes.astype(jnp.int32),
                            preferred_element_type=jnp.float32)
    return out.astype(out_dtype)


def gmm_xla_exact(a_fp8, s_a, b_fp8, s_b, group_sizes, *,
                  out_dtype=jnp.bfloat16):
    """Per-K-block f32 math — bit-identical accumulation order to the
    Pallas kernel (ragged_dot per K block, rescale, accumulate in f32)."""
    m, k = a_fp8.shape
    g, _, n = b_fp8.shape
    kb = k // QUANT_BLOCK
    gs = group_sizes.astype(jnp.int32)
    acc = jnp.zeros((m, n), jnp.float32)
    # row scale for token i and k-block j applied post-dot; column scale is
    # constant within a 128-wide n block.
    for j in range(kb):
        aj = a_fp8[:, j * QUANT_BLOCK:(j + 1) * QUANT_BLOCK].astype(jnp.float32)
        bj = b_fp8[:, j * QUANT_BLOCK:(j + 1) * QUANT_BLOCK, :].astype(jnp.float32)
        part = compat.ragged_dot(aj, bj, gs,
                                 preferred_element_type=jnp.float32)
        # gather this token's group column-scales: expand s_b rows per group
        seg = jnp.repeat(jnp.arange(g), gs, total_repeat_length=m)
        col = jnp.repeat(s_b[:, j, :], QUANT_BLOCK, axis=1)[:, :n]   # (g, n)
        acc = acc + part * s_a[:, j][:, None] * col[seg]
    return acc.astype(out_dtype)


def gmm_bf16_xla_exact(x, w, group_sizes, *, out_dtype=jnp.bfloat16):
    """bf16-operand oracle with :func:`~repro.kernels.grouped_gemm_kernel
    .gmm_pallas_bf16`'s exact reduction order: one dense f32 ``dot`` per
    (group, 128-wide K block) on f32-upcast bf16 operands, row-selected
    by group membership and accumulated in f32 across K blocks.  Dense
    ``dot`` (not ``ragged_dot``) is load-bearing for bitwise parity: XLA
    splits the contraction differently per output row inside a
    ``ragged_dot``, while M-tiling a dense dot is bitwise-stable — and
    the kernel's per-visit dots are exactly M tiles of these.  Tail rows
    beyond ``sum(group_sizes)`` stay exactly zero (the kernel's
    zero-fill contract).  O(G·M·N·K) — test-scale only."""
    x16 = x.astype(jnp.bfloat16)
    w16 = w.astype(jnp.bfloat16)
    m, k = x16.shape
    g, _, n = w16.shape
    gs = group_sizes.astype(jnp.int32)
    ends = jnp.cumsum(gs)
    starts = ends - gs
    r = jnp.arange(m, dtype=jnp.int32)
    acc = jnp.zeros((m, n), jnp.float32)
    for j in range(k // QUANT_BLOCK):
        aj = x16[:, j * QUANT_BLOCK:(j + 1) * QUANT_BLOCK].astype(jnp.float32)
        part = jnp.zeros((m, n), jnp.float32)
        for gi in range(g):
            bj = w16[gi, j * QUANT_BLOCK:(j + 1) * QUANT_BLOCK, :].astype(
                jnp.float32)
            pg = jax.lax.dot(aj, bj, preferred_element_type=jnp.float32)
            own = (r >= starts[gi]) & (r < ends[gi])
            part = jnp.where(own[:, None], pg, part)
        acc = acc + part
    return acc.astype(out_dtype)


def wgrad_xla_ragged(x, dy, group_sizes, *, num_groups,
                     out_dtype=jnp.float32):
    """``compat.ragged_wgrad``: ``ragged_dot_general`` where available,
    transpose-of-``ragged_dot`` otherwise — the historical wgrad path,
    now the portable fallback of this family."""
    return compat.ragged_wgrad(x, dy, group_sizes,
                               num_groups=num_groups).astype(out_dtype)


def wgrad_xla_exact(x, dy, group_sizes, *, num_groups,
                    out_dtype=jnp.float32):
    """Dense f32 oracle: one-hot group membership contracted in a single
    einsum.  O(M*G) membership mask — test-scale only, but every term is
    an exact f32 product, and rows beyond ``sum(group_sizes)`` have an
    all-zero membership row (excluded by construction, not by masking
    garbage after the fact)."""
    m = x.shape[0]
    gs = group_sizes.astype(jnp.int32)
    ends = jnp.cumsum(gs)
    starts = ends - gs
    r = jnp.arange(m, dtype=jnp.int32)
    member = ((r[:, None] >= starts[None, :])
              & (r[:, None] < ends[None, :])).astype(jnp.float32)  # [M, G]
    dw = jnp.einsum("mg,mk,mn->gkn", member, x.astype(jnp.float32),
                    dy.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    return dw.astype(out_dtype)


def wgrad_fp8_xla_ragged(x_fp8, s_x, dy_fp8, s_dy, group_sizes, *,
                         num_groups, out_dtype=jnp.float32):
    """fp8-operand twin of :func:`wgrad_xla_ragged`: dequantize both
    operands up front (``_dequant_a`` — the 1x128 row-tile layout is the
    same on the x and dy sides) and reuse the bf16 ragged contraction."""
    x = _dequant_a(x_fp8, s_x, jnp.bfloat16)
    dy = _dequant_a(dy_fp8, s_dy, jnp.bfloat16)
    return wgrad_xla_ragged(x, dy, group_sizes, num_groups=num_groups,
                            out_dtype=out_dtype)


def wgrad_fp8_xla_exact(x_fp8, s_x, dy_fp8, s_dy, group_sizes, *,
                        num_groups, out_dtype=jnp.float32):
    """fp8-operand oracle: exact f32 dequantization then the dense
    one-hot f32 contraction — the ground truth the fp8 wgrad kernel's
    per-visit dequantization is validated against."""
    x = _dequant_a(x_fp8, s_x, jnp.float32)
    dy = _dequant_a(dy_fp8, s_dy, jnp.float32)
    return wgrad_xla_exact(x, dy, group_sizes, num_groups=num_groups,
                           out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# Built-in registrations
# ---------------------------------------------------------------------------

def _avail_always():
    return True, ""


def _avail_tpu():
    if compat.has_tpu():
        return True, ""
    return False, ("requires a TPU (jax.default_backend() == 'tpu'); "
                   "use 'pallas_interpret' for CPU-verifiable runs")


def _avail_ragged_dot():
    if compat.has_ragged_dot():
        return True, ""
    return False, (f"jax {jax.__version__} has no jax.lax.ragged_dot")


def _avail_ragged_wgrad():
    if compat.has_ragged_dot_general() or compat.has_ragged_dot():
        return True, ""
    return False, (f"jax {jax.__version__} has neither "
                   "jax.lax.ragged_dot_general nor jax.lax.ragged_dot")


# ---- (gemm, fp8): the paper's forward/dgrad orientation -------------------

def _run_pallas(a8, sa, b8, sb, gs, *, num_groups, config, plan, interpret):
    return gmm_pallas(a8, sa, b8, sb, gs, num_groups=num_groups,
                      block_m=config.block_m, block_n=config.block_n,
                      block_k=config.block_k, out_dtype=config.out_dtype,
                      interpret=interpret, plan=plan)


def _run_xla_ragged(a8, sa, b8, sb, gs, *, config, **_):
    return gmm_xla(a8, sa, b8, sb, gs, out_dtype=config.out_dtype)


def _run_xla_exact(a8, sa, b8, sb, gs, *, config, **_):
    return gmm_xla_exact(a8, sa, b8, sb, gs, out_dtype=config.out_dtype)


def _run_padded_baseline(a8, sa, b8, sb, gs, *, config, **_):
    # deferred import: padding_baseline routes its aligned GEMM back
    # through this registry.  A caller's TilePlan never applies here —
    # padding changes the group offsets, so the baseline plans over the
    # padded sizes (once per static shape, via the PlanCache).
    from repro.core import padding_baseline as pb
    inner = "pallas" if compat.has_tpu() else "pallas_interpret"
    return pb.grouped_gemm_fp8_padded(a8, sa, b8, sb, gs,
                                      config=config.with_(backend=inner))


register_operator(
    ("gemm", "fp8"), "pallas",
    description="compiled Pallas TPU kernel (padding-free, paper §2)",
    available=_avail_tpu,
    run=lambda *a, **kw: _run_pallas(*a, interpret=False, **kw),
    uses_plan=True, uses_tiles=True)
register_operator(
    ("gemm", "fp8"), "pallas_interpret",
    description="Pallas kernel in interpret mode — CPU-verifiable, "
                "bit-identical to 'pallas'",
    available=_avail_always,
    run=lambda *a, **kw: _run_pallas(*a, interpret=True, **kw),
    uses_plan=True, uses_tiles=True)
register_operator(
    ("gemm", "fp8"), "xla_ragged",
    description="jax.lax.ragged_dot on bf16-dequantized operands "
                "(portable / GSPMD)",
    available=_avail_ragged_dot,
    run=_run_xla_ragged)
register_operator(
    ("gemm", "fp8"), "xla_exact",
    description="per-K-block f32 oracle with the kernel's accumulation "
                "order",
    available=_avail_ragged_dot,
    run=_run_xla_exact)
register_operator(
    ("gemm", "fp8"), "padded_baseline",
    description="the paper's baseline: pad groups to block_m, aligned "
                "grouped GEMM, unpad",
    available=_avail_always,
    run=_run_padded_baseline,
    uses_tiles=True)       # block_m drives the padding; no plan consumed


# ---- (gemm, bf16): the numerics-baseline orientation ----------------------

def _run_pallas_bf16(x, w, gs, *, num_groups, config, plan, interpret):
    return gmm_pallas_bf16(x, w, gs, num_groups=num_groups,
                           block_m=config.block_m, block_n=config.block_n,
                           block_k=config.block_k,
                           out_dtype=config.out_dtype,
                           interpret=interpret, plan=plan)


def _run_bf16_ragged(x, w, gs, *, config, **_):
    out = compat.ragged_dot(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                            gs.astype(jnp.int32),
                            preferred_element_type=jnp.float32)
    return out.astype(config.out_dtype)


def _run_bf16_xla_exact(x, w, gs, *, config, **_):
    return gmm_bf16_xla_exact(x, w, gs, out_dtype=config.out_dtype)


register_operator(
    ("gemm", "bf16"), "pallas",
    description="compiled Pallas TPU kernel on bf16 operands — the fp8 "
                "kernel's visit schedule without the quantize machinery",
    available=_avail_tpu,
    run=lambda *a, **kw: _run_pallas_bf16(*a, interpret=False, **kw),
    uses_plan=True, uses_tiles=True)
register_operator(
    ("gemm", "bf16"), "pallas_interpret",
    description="bf16 Pallas kernel in interpret mode — CPU-verifiable, "
                "bit-identical to 'pallas'",
    available=_avail_always,
    run=lambda *a, **kw: _run_pallas_bf16(*a, interpret=True, **kw),
    uses_plan=True, uses_tiles=True)
register_operator(
    ("gemm", "bf16"), "xla_ragged",
    description="jax.lax.ragged_dot on bf16 operands (numerics baseline; "
                "dense fallback where the primitive is missing)",
    available=_avail_always,       # compat.ragged_dot always has a fallback
    run=_run_bf16_ragged)
register_operator(
    ("gemm", "bf16"), "xla_exact",
    description="per-(group, 128-K-block) dense f32 oracle with the bf16 "
                "kernel's accumulation order",
    available=_avail_always,
    run=_run_bf16_xla_exact)


# ---- (gemm_quant, fp8): the quantizing-epilogue producer ------------------

def _run_gemm_quant_pallas(a8, sa, b8, sb, gs, *, num_groups, config, plan,
                           interpret):
    return gmm_pallas_quant(a8, sa, b8, sb, gs, num_groups=num_groups,
                            block_m=config.block_m, block_n=config.block_n,
                            block_k=config.block_k,
                            out_dtype=config.out_dtype,
                            interpret=interpret, plan=plan)


def _compose_gemm_quant(gemm_name):
    """Unfused composition: run the same-named ``(gemm, fp8)`` entry, then
    the reference tilewise quantizer on its f32 upcast.  Keeps the
    backend matrix total — every backend that can GEMM can gemm_quant —
    and defines the rounding point the fused kernel matches bitwise."""
    def run(a8, sa, b8, sb, gs, *, num_groups=None, config=None, plan=None,
            **_):
        y = _OPERATORS[OpKey("gemm", "fp8")][gemm_name].run(
            a8, sa, b8, sb, gs, num_groups=num_groups, config=config,
            plan=plan)
        return _ref.quantize_tilewise_ref(y.astype(jnp.float32))
    return run


def _run_gemm_quant_ref(a8, sa, b8, sb, gs, *, config, **_):
    y = gmm_xla(a8, sa, b8, sb, gs, out_dtype=config.out_dtype)
    return _ref.quantize_tilewise_ref(y.astype(jnp.float32))


register_operator(
    ("gemm_quant", "fp8"), "pallas",
    description="compiled Pallas TPU kernel: grouped GEMM + fused 1x128 "
                "quantizing epilogue (fp8 payload + scales emitted "
                "directly; no bf16 output write)",
    available=_avail_tpu,
    run=lambda *a, **kw: _run_gemm_quant_pallas(*a, interpret=False, **kw),
    uses_plan=True, uses_tiles=True)
register_operator(
    ("gemm_quant", "fp8"), "pallas_interpret",
    description="quantizing-epilogue kernel in interpret mode — "
                "CPU-verifiable, bit-identical to 'pallas'",
    available=_avail_always,
    run=lambda *a, **kw: _run_gemm_quant_pallas(*a, interpret=True, **kw),
    uses_plan=True, uses_tiles=True)
register_operator(
    ("gemm_quant", "fp8"), "xla_ragged",
    description="unfused composition: xla_ragged GEMM then reference "
                "tilewise quantize",
    available=_avail_ragged_dot,
    run=_compose_gemm_quant("xla_ragged"))
register_operator(
    ("gemm_quant", "fp8"), "xla_exact",
    description="unfused composition: xla_exact GEMM then reference "
                "tilewise quantize",
    available=_avail_ragged_dot,
    run=_compose_gemm_quant("xla_exact"))
register_operator(
    ("gemm_quant", "fp8"), "padded_baseline",
    description="unfused composition: padded-baseline GEMM then reference "
                "tilewise quantize (the baseline fuses nothing)",
    available=_avail_always,
    run=_compose_gemm_quant("padded_baseline"),
    uses_tiles=True)       # block_m drives the inner padding
register_operator(
    ("gemm_quant", "fp8"), "ref",
    description="unfused dequantize-GEMM + reference quantize — always "
                "available",
    available=_avail_always,
    run=_run_gemm_quant_ref)


# ---- (wgrad, bf16): the ragged-contraction orientation --------------------

def _run_pallas_wgrad(x, dy, gs, *, num_groups, config, plan, interpret):
    return gmm_pallas_wgrad(x, dy, gs, num_groups=num_groups,
                            block_m=config.block_m, block_n=config.block_n,
                            block_k=config.block_k,
                            n_span=config.n_span, k_span=config.k_span,
                            out_dtype=config.out_dtype, interpret=interpret,
                            plan=plan)


def _run_wgrad_xla_ragged(x, dy, gs, *, num_groups, config, **_):
    return wgrad_xla_ragged(x, dy, gs, num_groups=num_groups,
                            out_dtype=config.out_dtype)


def _run_wgrad_xla_exact(x, dy, gs, *, num_groups, config, **_):
    return wgrad_xla_exact(x, dy, gs, num_groups=num_groups,
                           out_dtype=config.out_dtype)


register_operator(
    ("wgrad", "bf16"), "pallas",
    description="compiled Pallas TPU kernel: ragged-M contraction with "
                "per-visit masked accumulation (padding-free wgrad)",
    available=_avail_tpu,
    run=lambda *a, **kw: _run_pallas_wgrad(*a, interpret=False, **kw),
    uses_plan=True, uses_tiles=True)
register_operator(
    ("wgrad", "bf16"), "pallas_interpret",
    description="wgrad kernel in interpret mode — CPU-verifiable, "
                "bit-identical to 'pallas'",
    available=_avail_always,
    run=lambda *a, **kw: _run_pallas_wgrad(*a, interpret=True, **kw),
    uses_plan=True, uses_tiles=True)
register_operator(
    ("wgrad", "bf16"), "xla_ragged",
    description="compat.ragged_wgrad (ragged_dot_general or transposed "
                "ragged_dot) — portable fallback",
    available=_avail_ragged_wgrad,
    run=_run_wgrad_xla_ragged)
register_operator(
    ("wgrad", "bf16"), "xla_exact",
    description="dense one-hot f32 oracle for the ragged contraction",
    available=_avail_always,
    run=_run_wgrad_xla_exact)


# ---- (wgrad, fp8): the all-fp8 step's contraction -------------------------

def _run_pallas_wgrad_fp8(x8, sx, dy8, sdy, gs, *, num_groups, config, plan,
                          interpret):
    return gmm_pallas_wgrad_fp8(x8, sx, dy8, sdy, gs, num_groups=num_groups,
                                block_m=config.block_m,
                                block_n=config.block_n,
                                block_k=config.block_k,
                                n_span=config.n_span, k_span=config.k_span,
                                out_dtype=config.out_dtype,
                                interpret=interpret, plan=plan)


def _run_wgrad_fp8_xla_ragged(x8, sx, dy8, sdy, gs, *, num_groups, config,
                              **_):
    return wgrad_fp8_xla_ragged(x8, sx, dy8, sdy, gs, num_groups=num_groups,
                                out_dtype=config.out_dtype)


def _run_wgrad_fp8_xla_exact(x8, sx, dy8, sdy, gs, *, num_groups, config,
                             **_):
    return wgrad_fp8_xla_exact(x8, sx, dy8, sdy, gs, num_groups=num_groups,
                               out_dtype=config.out_dtype)


register_operator(
    ("wgrad", "fp8"), "pallas",
    description="compiled Pallas TPU kernel: ragged-M contraction on fp8 "
                "operands, per-visit dequant folded into the masked "
                "prologue (arXiv 2505.20524 all-fp8 step)",
    available=_avail_tpu,
    run=lambda *a, **kw: _run_pallas_wgrad_fp8(*a, interpret=False, **kw),
    uses_plan=True, uses_tiles=True)
register_operator(
    ("wgrad", "fp8"), "pallas_interpret",
    description="fp8 wgrad kernel in interpret mode — CPU-verifiable, "
                "bit-identical to 'pallas_fp8'",
    available=_avail_always,
    run=lambda *a, **kw: _run_pallas_wgrad_fp8(*a, interpret=True, **kw),
    uses_plan=True, uses_tiles=True)
register_operator(
    ("wgrad", "fp8"), "xla_ragged",
    description="up-front bf16 dequantization + compat.ragged_wgrad — "
                "portable fp8-operand fallback",
    available=_avail_ragged_wgrad,
    run=_run_wgrad_fp8_xla_ragged)
register_operator(
    ("wgrad", "fp8"), "xla_exact",
    description="f32 dequantization + dense one-hot f32 oracle for the "
                "fp8-operand ragged contraction",
    available=_avail_always,
    run=_run_wgrad_fp8_xla_exact)


# ---- (quantize, fp8): the operand producer --------------------------------

def _run_quant_pallas(x, *, config, interpret, **_):
    kw = {} if config is None else {"block_m": config.block_m}
    return quantize_tilewise_pallas(x, interpret=interpret, **kw)


def _run_quant_ref(x, **_):
    return _ref.quantize_tilewise_ref(x)


register_operator(
    ("quantize", "fp8"), "pallas",
    description="Pallas 1x128 per-tile fp8 quantizer (tile height "
                "autotunable via op='quantize')",
    available=_avail_tpu,
    run=lambda *a, **kw: _run_quant_pallas(*a, interpret=False, **kw),
    uses_tiles=True)
register_operator(
    ("quantize", "fp8"), "pallas_interpret",
    description="quantizer kernel in interpret mode — CPU-verifiable, "
                "bit-identical to 'pallas'",
    available=_avail_always,
    run=lambda *a, **kw: _run_quant_pallas(*a, interpret=True, **kw),
    uses_tiles=True)
register_operator(
    ("quantize", "fp8"), "xla_ragged",
    description="XLA reference quantizer (tile shapes are a no-op)",
    available=_avail_ragged_dot,
    run=_run_quant_ref)
register_operator(
    ("quantize", "fp8"), "xla_exact",
    description="XLA reference quantizer (tile shapes are a no-op)",
    available=_avail_ragged_dot,
    run=_run_quant_ref)
register_operator(
    ("quantize", "fp8"), "padded_baseline",
    description="XLA reference quantizer (the baseline quantizes like "
                "everyone else)",
    available=_avail_always,
    run=_run_quant_ref)
register_operator(
    ("quantize", "fp8"), "ref",
    description="XLA reference quantizer — always available",
    available=_avail_always,
    run=_run_quant_ref)


# ---- (act_quant, fp8): the fused activation epilogue ----------------------

def _run_act_quant_pallas(g, u=None, *, act, config, interpret,
                          s_g=None, s_u=None, **_):
    kw = {} if config is None else {"block_m": config.block_m}
    return act_quantize_pallas(g, u, s_g=s_g, s_u=s_u, act=act,
                               interpret=interpret, **kw)


def _run_act_quant_ref(g, u=None, *, act, s_g=None, s_u=None, **_):
    return _ref.act_quantize_ref(g, u, act, s_g=s_g, s_u=s_u)


register_operator(
    ("act_quant", "fp8"), "pallas",
    description="fused Pallas epilogue: silu(g)*u / gelu(g) + 1x128 fp8 "
                "quantization in one grid pass (tile height autotunable "
                "via op='act_quant')",
    available=_avail_tpu,
    run=lambda *a, **kw: _run_act_quant_pallas(*a, interpret=False, **kw),
    uses_tiles=True)
register_operator(
    ("act_quant", "fp8"), "pallas_interpret",
    description="fused epilogue kernel in interpret mode — CPU-verifiable, "
                "bit-identical to 'pallas'",
    available=_avail_always,
    run=lambda *a, **kw: _run_act_quant_pallas(*a, interpret=True, **kw),
    uses_tiles=True)
register_operator(
    ("act_quant", "fp8"), "xla_ragged",
    description="unfused XLA reference: activation then tilewise quantize "
                "(tile shapes are a no-op)",
    available=_avail_ragged_dot,
    run=_run_act_quant_ref)
register_operator(
    ("act_quant", "fp8"), "xla_exact",
    description="unfused XLA reference: activation then tilewise quantize "
                "(tile shapes are a no-op)",
    available=_avail_ragged_dot,
    run=_run_act_quant_ref)
register_operator(
    ("act_quant", "fp8"), "padded_baseline",
    description="unfused XLA reference (the baseline has no fused "
                "epilogue either)",
    available=_avail_always,
    run=_run_act_quant_ref)
register_operator(
    ("act_quant", "fp8"), "ref",
    description="unfused silu·mul/gelu + quantize_tilewise reference — "
                "always available",
    available=_avail_always,
    run=_run_act_quant_ref)


# back-compat membership views (derived from the registry flags; prefer
# op_uses_plan / op_ignores_tiles)
PLAN_BACKENDS = _plan_tile_frozenset(uses_plan=True)
TILE_FREE_BACKENDS = _plan_tile_frozenset(uses_plan=False)


# ---------------------------------------------------------------------------
# Operator contract facts (repro.analysis layer 2, rule REPRO-R07)
# ---------------------------------------------------------------------------

# OpKey -> declarative facts the contract checker validates: which public
# dispatch function fronts the operator, whether its hot path is
# padding-free, and how many STANDALONE tilewise quantizations the
# operator itself performs (fused epilogues quantize in-kernel: zero).
_OP_CONTRACT_FACTS: "dict[OpKey, dict]" = {}


def register_operator_contract(op_key, *, entry_point: str,
                               padding_free: bool,
                               standalone_quantizes: int = 0) -> None:
    """Declare contract facts for one operator — registered next to its
    ``register_operator`` block so a new family cannot land without
    naming its invariants (REPRO-R07 fails the lint otherwise)."""
    _OP_CONTRACT_FACTS[_op_key(op_key)] = {
        "entry_point": entry_point,
        "padding_free": padding_free,
        "standalone_quantizes": standalone_quantizes,
    }


def op_contract_facts() -> "dict[OpKey, dict]":
    return dict(_OP_CONTRACT_FACTS)


register_operator_contract(("gemm", "fp8"),
                           entry_point="grouped_gemm_fp8",
                           padding_free=True)
register_operator_contract(("gemm", "bf16"),
                           entry_point="grouped_gemm_bf16",
                           padding_free=True)
register_operator_contract(("gemm_quant", "fp8"),
                           entry_point="grouped_gemm_quant",
                           padding_free=True)
register_operator_contract(("wgrad", "bf16"),
                           entry_point="grouped_gemm_wgrad",
                           padding_free=True)
register_operator_contract(("wgrad", "fp8"),
                           entry_point="grouped_gemm_wgrad_fp8",
                           padding_free=True)
register_operator_contract(("quantize", "fp8"),
                           entry_point="quantize_tilewise",
                           padding_free=True, standalone_quantizes=1)
register_operator_contract(("act_quant", "fp8"),
                           entry_point="act_quantize",
                           padding_free=True)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def grouped_gemm_fp8(a_fp8, s_a, b_fp8, s_b, group_sizes, *,
                     backend: Optional[str] = None,
                     num_groups: Optional[int] = None,
                     config: Optional[KernelConfig] = None,
                     out_dtype=None,
                     plan: Optional[TilePlan] = None):
    """Quantized grouped GEMM through the ``(gemm, fp8)`` operator (the
    low-level entry — operands already fp8 with DeepSeek-style tile/block
    scales).

    Tile shapes travel in ``config`` (a :class:`KernelConfig`; defaults to
    the installed/per-device default); ``backend=``/``out_dtype=`` are
    per-call overrides of the config's fields.  ``plan`` is an optional
    precomputed :class:`TilePlan` for plan-consuming backends.
    """
    cfg = resolve_config(config, backend=backend, out_dtype=out_dtype)
    if cfg.out_dtype is None:
        cfg = cfg.with_(out_dtype=jnp.bfloat16)
    key = OpKey("gemm", "fp8")
    name = resolve(key, cfg.backend)
    return _OPERATORS[key][name].run(
        a_fp8, s_a, b_fp8, s_b, group_sizes, num_groups=num_groups,
        config=cfg, plan=plan)


def grouped_gemm_quant(a_fp8, s_a, b_fp8, s_b, group_sizes, *,
                       backend: Optional[str] = None,
                       num_groups: Optional[int] = None,
                       config: Optional[KernelConfig] = None,
                       out_dtype=None,
                       plan: Optional[TilePlan] = None):
    """Grouped GEMM with a fused 1x128 quantizing epilogue through the
    ``(gemm_quant, fp8)`` operator: returns ``(q[M, N] fp8e4m3,
    s[M, N/128] f32)`` instead of the materialized product — the
    producer's output is already the next GEMM's operand.

    ``out_dtype`` (default bf16) is the *intermediate rounding* dtype:
    the accumulator is rounded through it before the amax/scale step, so
    the result is bitwise what ``quantize_tilewise(grouped_gemm_fp8(...)
    .astype(f32))`` produces — fusion changes traffic, not values.  Tail
    rows beyond ``sum(group_sizes)`` come back as payload 0 / scale 1
    (the quantized image of the zero-fill contract).

    Same tile-fallback semantics as :func:`grouped_gemm_fp8`'s plan
    consumers: an auto-resolved kernel whose tile shapes don't divide
    (K, N) falls back to the unfused composition entries; an explicit
    request raises.
    """
    cfg = resolve_config(config, backend=backend, out_dtype=out_dtype)
    if cfg.out_dtype is None:
        cfg = cfg.with_(out_dtype=jnp.bfloat16)
    num_groups = num_groups if num_groups is not None else b_fp8.shape[0]
    # one event per producer-GEMM dispatch — the producer-fusion
    # contracts (REPRO-C05) pin the gate/up routing count
    _events.emit("gemm_quant", m=a_fp8.shape[0], n=b_fp8.shape[2])
    key = OpKey("gemm_quant", "fp8")
    name = resolve(key, cfg.backend,
                   tile=(cfg, a_fp8.shape[0], a_fp8.shape[1],
                         b_fp8.shape[2]))
    return _OPERATORS[key][name].run(
        a_fp8, s_a, b_fp8, s_b, group_sizes, num_groups=num_groups,
        config=cfg, plan=plan)


def grouped_gemm_bf16(x, w, group_sizes, *, backend: Optional[str] = None,
                      num_groups: Optional[int] = None,
                      config: Optional[KernelConfig] = None,
                      out_dtype=None,
                      plan: Optional[TilePlan] = None):
    """bf16-operand grouped GEMM through the ``(gemm, bf16)`` operator —
    the numerics-baseline orientation ``grouped_linear(precision="bf16")``
    builds on.  A true Pallas kernel (the fp8 twin's visit schedule, bf16
    operands, f32 accumulate) leads the auto order on TPU;
    ``jax.lax.ragged_dot`` (with a dense fallback) keeps the family
    available on every JAX.  Same tile-fallback semantics as every other
    plan consumer: an auto-resolved kernel whose tile shapes don't divide
    (K, N) falls back to the tile-free entries, an explicit request
    raises.  Not differentiable — training goes through
    :func:`repro.core.grouped_gemm.grouped_linear`."""
    cfg = resolve_config(config, backend=backend, out_dtype=out_dtype)
    if cfg.out_dtype is None:
        cfg = cfg.with_(out_dtype=x.dtype)
    num_groups = num_groups if num_groups is not None else w.shape[0]
    key = OpKey("gemm", "bf16")
    name = resolve(key, cfg.backend,
                   tile=(cfg, x.shape[0], x.shape[1], w.shape[2]))
    return _OPERATORS[key][name].run(
        x, w, group_sizes, num_groups=num_groups, config=cfg, plan=plan)


def grouped_gemm(x, w, group_sizes, *, backend: Optional[str] = None,
                 out_dtype=None, config: Optional[KernelConfig] = None,
                 plan: Optional[TilePlan] = None):
    """Unified high-level grouped GEMM: ``y[rows of g] = x[rows of g] @
    w[g]`` with the paper's fp8 recipe (1x128 activation tiles, 128x128
    weight blocks) applied before dispatch.

    x: [M, K] float; w: [G, K, N] float; group_sizes: [G] int.
    Not differentiable — training goes through
    :func:`repro.core.grouped_gemm.grouped_linear`, which wraps the same
    registry in a custom VJP.
    """
    a8, sa = _ref.quantize_tilewise_ref(x.astype(jnp.float32))
    b8, sb = jax.vmap(_ref.quantize_blockwise_ref)(w.astype(jnp.float32))
    # explicit out_dtype > config's pinned out_dtype > x.dtype
    cfg = resolve_config(config, backend=backend, out_dtype=out_dtype)
    if cfg.out_dtype is None:
        cfg = cfg.with_(out_dtype=x.dtype)
    return grouped_gemm_fp8(a8, sa, b8, sb, group_sizes,
                            num_groups=w.shape[0], config=cfg, plan=plan)


def grouped_gemm_wgrad(x, dy, group_sizes, *,
                       num_groups: Optional[int] = None,
                       backend: Optional[str] = None,
                       config: Optional[KernelConfig] = None,
                       out_dtype=None,
                       plan: Optional[TilePlan] = None):
    """Ragged-contraction grouped GEMM ``dw[g] = x_g^T @ dy_g`` through
    the ``(wgrad, bf16)`` operator.

    x: [M, K] float; dy: [M, N] float; group_sizes: [G] int,
    ``sum <= M`` (tail rows are excluded from the contraction).  Returns
    [G, K, N] (default f32 — wgrad is the highest-precision GEMM of the
    step).  ``plan`` is the routing decision's :class:`TilePlan` — the
    same object the forward/dgrad GEMMs consumed; the schedule is
    orientation-agnostic, so nothing is rebuilt here.

    An *auto-resolved* plan backend whose tile shapes don't divide
    (K, N) falls back to the first tile-free backend (the bf16 path calls
    in with arbitrary model dims); an explicitly requested one raises.
    """
    cfg = resolve_config(config, backend=backend, out_dtype=out_dtype)
    if cfg.out_dtype is None:
        cfg = cfg.with_(out_dtype=jnp.float32)
    num_groups = num_groups if num_groups is not None \
        else group_sizes.shape[0]
    key = OpKey("wgrad", "bf16")
    name = resolve(key, cfg.backend,
                   tile=(cfg, x.shape[0], x.shape[1], dy.shape[1]))
    return _OPERATORS[key][name].run(
        x, dy, group_sizes, num_groups=num_groups, config=cfg, plan=plan)


def grouped_gemm_wgrad_fp8(x_fp8, s_x, dy_fp8, s_dy, group_sizes, *,
                           num_groups: Optional[int] = None,
                           backend: Optional[str] = None,
                           config: Optional[KernelConfig] = None,
                           out_dtype=None,
                           plan: Optional[TilePlan] = None):
    """fp8-operand ragged-contraction grouped GEMM
    ``dw[g] = dequant(x)_g^T @ dequant(dy)_g`` through the
    ``(wgrad, fp8)`` operator (arXiv 2505.20524's all-fp8 training step).

    x_fp8/s_x: [M, K] fp8 + [M, ceil(K/128)] f32 — the forward's quantized
    activation and its 1x128 tile scales (the VJP residual, NOT
    re-quantized here); dy_fp8/s_dy: [M, N] fp8 + [M, ceil(N/128)] f32 —
    the upstream gradient as the dgrad already quantized it.
    ``backend`` names the family-neutral engine (``"pallas"``,
    ``"pallas_interpret"``, ...); the OpKey precision selects the twin.
    Same fallback semantics as :func:`grouped_gemm_wgrad`: auto-resolved
    tile shapes that don't divide (K, N) fall back to a tile-free fp8
    entry, explicit requests raise.
    """
    cfg = resolve_config(config, backend=backend, out_dtype=out_dtype)
    if cfg.out_dtype is None:
        cfg = cfg.with_(out_dtype=jnp.float32)
    num_groups = num_groups if num_groups is not None \
        else group_sizes.shape[0]
    key = OpKey("wgrad", "fp8")
    name = resolve(key, cfg.backend,
                   tile=(cfg, x_fp8.shape[0], x_fp8.shape[1],
                         dy_fp8.shape[1]))
    return _OPERATORS[key][name].run(
        x_fp8, s_x, dy_fp8, s_dy, group_sizes, num_groups=num_groups,
        config=cfg, plan=plan)


def quantize_tilewise(x, *, backend: Optional[str] = None,
                      config: Optional[KernelConfig] = None):
    """1x128 per-tile fp8 activation quantization through the
    ``(quantize, fp8)`` operator.

    ``config`` (optional) routes an autotuned tile height
    (``op="quantize"`` in :func:`repro.kernels.plan.autotune`) into the
    kernel's ``block_m``; without one the kernel keeps its default.  The
    OUTPUT is tile-height-independent — per-row 1x128 scales don't care
    how rows are batched — so tuning only moves wall time.

    A pure-quantization call never *needs* a kernel backend — when
    *auto*-resolution fails (e.g. an installed default naming an
    unavailable backend), fall back to the XLA reference implementation
    instead of refusing work the ref path can always serve.  An
    explicitly requested unavailable backend still raises: the caller
    asked for that kernel, not a silent stand-in.
    """
    explicit = backend not in (None, "auto")
    key = OpKey("quantize", "fp8")
    try:
        name = resolve(key, backend)
    except BackendUnavailableError:
        if explicit:
            raise
        return _ref.quantize_tilewise_ref(x)
    return _OPERATORS[key][name].run(x, config=config)


def act_quantize(g, u=None, *, act: str = "silu_mul",
                 backend: Optional[str] = None,
                 config: Optional[KernelConfig] = None,
                 s_g=None, s_u=None):
    """Fused activation -> 1x128 fp8 quantization through the
    ``(act_quant, fp8)`` operator.

    ``act="silu_mul"`` computes ``silu(g) * u`` (the SwiGLU expert
    epilogue; ``u`` required); ``act="gelu"`` is unary (``u`` must be
    None).  Returns ``(q[M, K] fp8e4m3, s[M, K/128] f32)`` — the exact
    :func:`quantize_tilewise` output contract applied to the activation,
    so every existing GEMM consumer accepts it unchanged.

    With ``s_g`` (and ``s_u``) the operands are fp8 payloads + 1x128
    scales from the quantizing-epilogue producer
    (:func:`grouped_gemm_quant`): they dequantize on load inside the
    kernel, closing the fp8 hot path with no bf16 intermediate on either
    side of the activation.

    ``config`` routes an autotuned tile height (``op="act_quant"``) into
    the kernel's ``block_m``; the output is tile-height-independent.
    Same fallback semantics as :func:`quantize_tilewise`: auto-resolution
    failures fall back to the unfused reference (activation then
    ``quantize_tilewise_ref``), an explicitly requested unavailable
    backend raises.
    """
    explicit = backend not in (None, "auto")
    key = OpKey("act_quant", "fp8")
    try:
        name = resolve(key, backend)
    except BackendUnavailableError:
        if explicit:
            raise
        return _ref.act_quantize_ref(g, u, act, s_g=s_g, s_u=s_u)
    return _OPERATORS[key][name].run(g, u, act=act, config=config,
                                     s_g=s_g, s_u=s_u)


def quantize_blockwise(w, *, backend: Optional[str] = None):
    """128x128 weight quantization through the registry seam.

    No kernel backend implements this yet (weights are quantized once per
    step outside the hot loop, so XLA ref math is fine everywhere), but
    resolution runs here so a future quant kernel plugs in at ONE place
    and the batched path below inherits it.  Same refusal semantics as
    :func:`quantize_tilewise`: auto-resolution failures fall back to ref,
    an explicitly requested unavailable backend raises.
    """
    explicit = backend not in (None, "auto")
    try:
        resolve(OpKey("quantize", "fp8"), backend)
    except BackendUnavailableError:
        if explicit:
            raise
    return _ref.quantize_blockwise_ref(w)


def quantize_blockwise_batched(w, *, backend: Optional[str] = None):
    """[G, K, N] -> (fp8[G, K, N], f32[G, KB, NB]) — vmap of the
    registry-routed :func:`quantize_blockwise`, so a future quant kernel
    covers the batched (per-expert) path automatically."""
    return jax.vmap(
        lambda wg: quantize_blockwise(wg, backend=backend))(w)


if __name__ == "__main__":
    print(format_backend_matrix())
