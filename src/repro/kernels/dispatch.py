"""Unified grouped-GEMM backend dispatch registry.

Every grouped-GEMM call site in the repo (``core/grouped_gemm.py``,
``core/moe.py``, ``core/padding_baseline.py``, models, benchmarks,
examples) routes through this module.  A backend is a named entry in the
registry with

  * an ``available()`` probe returning ``(ok, reason)`` — built on
    :mod:`repro.compat` capability probes so selection is testable by
    monkeypatching, and refusal is an explicit
    :class:`BackendUnavailableError` instead of a deep ``AttributeError``;
  * a ``run()`` implementing the quantized grouped GEMM
    ``(a_fp8, s_a, b_fp8, s_b, group_sizes) -> [M, N]`` under a
    :class:`repro.kernels.plan.KernelConfig` (tile shapes + out dtype),
    optionally consuming a precomputed :class:`~repro.kernels.plan.TilePlan`
    (the plan-once/run-many schedule shared by every GEMM of one routing
    decision).

Built-in backends:

  ===================  =====================================================
  ``pallas``           compiled Pallas TPU kernel (requires a TPU)
  ``pallas_interpret`` same kernel body, interpreted — runs anywhere (CPU
                       regression gate; bit-identical to ``pallas``)
  ``xla_ragged``       ``jax.lax.ragged_dot`` on bf16-dequantized operands
                       (portable, GSPMD-partitionable; ~fp8-rounding-level
                       deviation from the kernel)
  ``xla_exact``        per-K-block f32 math with the kernel's accumulation
                       order — cross-check oracle
  ``padded_baseline``  the paper's baseline: pad every group to block_m,
                       aligned grouped GEMM, unpad (through the Pallas
                       kernel so equivalence checks are bitwise)
  ===================  =====================================================

``backend="auto"`` resolves to the first available of
``pallas`` > ``xla_ragged`` > ``pallas_interpret``.  ``"xla"`` is kept as
an alias of ``"xla_ragged"`` for pre-registry callers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels import ref as _ref
from repro.kernels.grouped_gemm_kernel import QUANT_BLOCK, gmm_pallas
from repro.kernels.plan import (KernelConfig, TilePlan,  # noqa: F401
                                make_tile_plan, resolve_config)
from repro.kernels.quant_kernel import quantize_tilewise_pallas

# auto-resolution preference, best first
AUTO_ORDER = ("pallas", "xla_ragged", "pallas_interpret")

_ALIASES = {"xla": "xla_ragged"}

# backends that walk the TilePlan schedule (and honour tile shapes); the
# XLA paths let the compiler tile and ignore both
PLAN_BACKENDS = frozenset({"pallas", "pallas_interpret"})
TILE_FREE_BACKENDS = frozenset({"xla_ragged", "xla_exact"})


class BackendUnavailableError(RuntimeError):
    """Requested backend cannot run here; ``.reason`` says why."""

    def __init__(self, name: str, reason: str):
        super().__init__(f"grouped-GEMM backend {name!r} unavailable: "
                         f"{reason}")
        self.backend = name
        self.reason = reason


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    name: str
    description: str
    available: Callable[[], "tuple[bool, str]"]   # (ok, reason-if-not)
    run: Callable[..., jax.Array]


_REGISTRY: dict[str, BackendSpec] = {}
_default_backend_override: Optional[str] = None


def register_backend(name: str, *, description: str,
                     available: Callable[[], "tuple[bool, str]"],
                     run: Callable[..., jax.Array]) -> None:
    """Later PRs (autotuned variants, new hardware paths) plug in here."""
    _REGISTRY[name] = BackendSpec(name, description, available, run)


def backend_names() -> "tuple[str, ...]":
    return tuple(_REGISTRY)


def availability(name: str) -> "tuple[bool, str]":
    name = _ALIASES.get(name, name)
    if name not in _REGISTRY:
        raise ValueError(f"unknown backend {name!r}; "
                         f"choose from {backend_names()}")
    return _REGISTRY[name].available()


def backend_matrix() -> "dict[str, dict[str, Any]]":
    """{name: {available, reason, description}} — docs / CLI surface."""
    out = {}
    for name, spec in _REGISTRY.items():
        ok, reason = spec.available()
        out[name] = {"available": ok, "reason": reason,
                     "description": spec.description}
    return out


def set_default_backend(name: Optional[str]) -> None:
    """Override what ``backend=None`` / ``"auto"`` resolves to."""
    global _default_backend_override
    if name is not None:
        name = _ALIASES.get(name, name)
        if name not in _REGISTRY:
            raise ValueError(f"unknown backend {name!r}; "
                             f"choose from {backend_names()}")
    _default_backend_override = name


def default_backend() -> str:
    return resolve_backend("auto")


def resolve_backend(backend: Optional[str] = "auto") -> str:
    """Map a requested backend (or ``"auto"``/``None``) to a concrete,
    *available* registry entry, or raise with the probe's reason."""
    if backend in (None, "auto"):
        if _default_backend_override is not None:
            backend = _default_backend_override
        else:
            for name in AUTO_ORDER:
                ok, _ = _REGISTRY[name].available()
                if ok:
                    return name
            raise BackendUnavailableError(
                "auto", "no grouped-GEMM backend is available "
                        f"(tried {AUTO_ORDER})")
    backend = _ALIASES.get(backend, backend)
    if backend not in _REGISTRY:
        raise ValueError(f"unknown backend {backend!r}; "
                         f"choose from {backend_names()}")
    ok, reason = _REGISTRY[backend].available()
    if not ok:
        raise BackendUnavailableError(backend, reason)
    return backend


def backend_uses_plan(backend: Optional[str] = "auto") -> bool:
    """Whether the (resolved) backend consumes a precomputed TilePlan —
    callers skip plan construction for the XLA paths."""
    return resolve_backend(backend) in PLAN_BACKENDS


def backend_ignores_tiles(backend: Optional[str] = "auto") -> bool:
    """Whether tile shapes are a no-op for the (resolved) backend — the
    autotuner skips measurement there (cost-model selection only)."""
    return resolve_backend(backend) in TILE_FREE_BACKENDS


# ---------------------------------------------------------------------------
# XLA implementations
# ---------------------------------------------------------------------------

def _dequant_a(a_fp8, s_a, dtype):
    m, k = a_fp8.shape
    scales = jnp.repeat(s_a, QUANT_BLOCK, axis=1)[:, :k]
    return (a_fp8.astype(jnp.float32) * scales).astype(dtype)


def _dequant_b(b_fp8, s_b, dtype):
    g, k, n = b_fp8.shape
    scales = jnp.repeat(jnp.repeat(s_b, QUANT_BLOCK, axis=1), QUANT_BLOCK,
                        axis=2)[:, :k, :n]
    return (b_fp8.astype(jnp.float32) * scales).astype(dtype)


def gmm_xla(a_fp8, s_a, b_fp8, s_b, group_sizes, *, out_dtype=jnp.bfloat16,
            compute_dtype=jnp.bfloat16):
    """ragged_dot on dequantized operands (GSPMD-partitionable)."""
    a = _dequant_a(a_fp8, s_a, compute_dtype)
    b = _dequant_b(b_fp8, s_b, compute_dtype)
    out = compat.ragged_dot(a, b, group_sizes.astype(jnp.int32),
                            preferred_element_type=jnp.float32)
    return out.astype(out_dtype)


def gmm_xla_exact(a_fp8, s_a, b_fp8, s_b, group_sizes, *,
                  out_dtype=jnp.bfloat16):
    """Per-K-block f32 math — bit-identical accumulation order to the
    Pallas kernel (ragged_dot per K block, rescale, accumulate in f32)."""
    m, k = a_fp8.shape
    g, _, n = b_fp8.shape
    kb = k // QUANT_BLOCK
    gs = group_sizes.astype(jnp.int32)
    acc = jnp.zeros((m, n), jnp.float32)
    # row scale for token i and k-block j applied post-dot; column scale is
    # constant within a 128-wide n block.
    for j in range(kb):
        aj = a_fp8[:, j * QUANT_BLOCK:(j + 1) * QUANT_BLOCK].astype(jnp.float32)
        bj = b_fp8[:, j * QUANT_BLOCK:(j + 1) * QUANT_BLOCK, :].astype(jnp.float32)
        part = compat.ragged_dot(aj, bj, gs,
                                 preferred_element_type=jnp.float32)
        # gather this token's group column-scales: expand s_b rows per group
        seg = jnp.repeat(jnp.arange(g), gs, total_repeat_length=m)
        col = jnp.repeat(s_b[:, j, :], QUANT_BLOCK, axis=1)[:, :n]   # (g, n)
        acc = acc + part * s_a[:, j][:, None] * col[seg]
    return acc.astype(out_dtype)


# ---------------------------------------------------------------------------
# Built-in backend registrations
# ---------------------------------------------------------------------------

def _avail_always():
    return True, ""


def _avail_tpu():
    if compat.has_tpu():
        return True, ""
    return False, ("requires a TPU (jax.default_backend() == 'tpu'); "
                   "use 'pallas_interpret' for CPU-verifiable runs")


def _avail_ragged_dot():
    if compat.has_ragged_dot():
        return True, ""
    return False, (f"jax {jax.__version__} has no jax.lax.ragged_dot")


def _run_pallas(a8, sa, b8, sb, gs, *, num_groups, config, plan, interpret):
    return gmm_pallas(a8, sa, b8, sb, gs, num_groups=num_groups,
                      block_m=config.block_m, block_n=config.block_n,
                      block_k=config.block_k, out_dtype=config.out_dtype,
                      interpret=interpret, plan=plan)


def _run_xla_ragged(a8, sa, b8, sb, gs, *, config, **_):
    return gmm_xla(a8, sa, b8, sb, gs, out_dtype=config.out_dtype)


def _run_xla_exact(a8, sa, b8, sb, gs, *, config, **_):
    return gmm_xla_exact(a8, sa, b8, sb, gs, out_dtype=config.out_dtype)


def _run_padded_baseline(a8, sa, b8, sb, gs, *, config, **_):
    # deferred import: padding_baseline routes its aligned GEMM back
    # through this registry.  A caller's TilePlan never applies here —
    # padding changes the group offsets, so the baseline re-plans.
    from repro.core import padding_baseline as pb
    inner = "pallas" if compat.has_tpu() else "pallas_interpret"
    return pb.grouped_gemm_fp8_padded(a8, sa, b8, sb, gs,
                                      config=config.with_(backend=inner))


register_backend(
    "pallas",
    description="compiled Pallas TPU kernel (padding-free, paper §2)",
    available=_avail_tpu,
    run=lambda *a, **kw: _run_pallas(*a, interpret=False, **kw))
register_backend(
    "pallas_interpret",
    description="Pallas kernel in interpret mode — CPU-verifiable, "
                "bit-identical to 'pallas'",
    available=_avail_always,
    run=lambda *a, **kw: _run_pallas(*a, interpret=True, **kw))
register_backend(
    "xla_ragged",
    description="jax.lax.ragged_dot on bf16-dequantized operands "
                "(portable / GSPMD)",
    available=_avail_ragged_dot,
    run=_run_xla_ragged)
register_backend(
    "xla_exact",
    description="per-K-block f32 oracle with the kernel's accumulation "
                "order",
    available=_avail_ragged_dot,
    run=_run_xla_exact)
register_backend(
    "padded_baseline",
    description="the paper's baseline: pad groups to block_m, aligned "
                "grouped GEMM, unpad",
    available=_avail_always,
    run=_run_padded_baseline)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def grouped_gemm_fp8(a_fp8, s_a, b_fp8, s_b, group_sizes, *,
                     backend: Optional[str] = None,
                     num_groups: Optional[int] = None,
                     config: Optional[KernelConfig] = None,
                     out_dtype=None,
                     plan: Optional[TilePlan] = None):
    """Quantized grouped GEMM through the registry (the low-level entry —
    operands already fp8 with DeepSeek-style tile/block scales).

    Tile shapes travel in ``config`` (a :class:`KernelConfig`; defaults to
    the installed/per-device default); ``backend=``/``out_dtype=`` are
    per-call overrides of the config's fields.  ``plan`` is an optional
    precomputed :class:`TilePlan` for plan-consuming backends.
    """
    cfg = resolve_config(config, backend=backend, out_dtype=out_dtype)
    if cfg.out_dtype is None:
        cfg = cfg.with_(out_dtype=jnp.bfloat16)
    name = resolve_backend(cfg.backend)
    return _REGISTRY[name].run(
        a_fp8, s_a, b_fp8, s_b, group_sizes, num_groups=num_groups,
        config=cfg, plan=plan)


def grouped_gemm(x, w, group_sizes, *, backend: Optional[str] = None,
                 out_dtype=None, config: Optional[KernelConfig] = None,
                 plan: Optional[TilePlan] = None):
    """Unified high-level grouped GEMM: ``y[rows of g] = x[rows of g] @
    w[g]`` with the paper's fp8 recipe (1x128 activation tiles, 128x128
    weight blocks) applied before dispatch.

    x: [M, K] float; w: [G, K, N] float; group_sizes: [G] int.
    Not differentiable — training goes through
    :func:`repro.core.grouped_gemm.grouped_linear`, which wraps the same
    registry in a custom VJP.
    """
    a8, sa = _ref.quantize_tilewise_ref(x.astype(jnp.float32))
    b8, sb = jax.vmap(_ref.quantize_blockwise_ref)(w.astype(jnp.float32))
    # explicit out_dtype > config's pinned out_dtype > x.dtype
    cfg = resolve_config(config, backend=backend, out_dtype=out_dtype)
    if cfg.out_dtype is None:
        cfg = cfg.with_(out_dtype=x.dtype)
    return grouped_gemm_fp8(a8, sa, b8, sb, group_sizes,
                            num_groups=w.shape[0], config=cfg, plan=plan)


def quantize_tilewise(x, *, backend: Optional[str] = None):
    """1x128 per-tile fp8 activation quantization through the registry.

    A pure-quantization call never *needs* a kernel backend — when
    *auto*-resolution fails (e.g. an installed default naming an
    unavailable backend), fall back to the XLA reference implementation
    instead of refusing work the ref path can always serve.  An
    explicitly requested unavailable backend still raises: the caller
    asked for that kernel, not a silent stand-in.
    """
    explicit = backend not in (None, "auto")
    try:
        backend = resolve_backend(backend)
    except BackendUnavailableError:
        if explicit:
            raise
        return _ref.quantize_tilewise_ref(x)
    if backend == "pallas":
        return quantize_tilewise_pallas(x, interpret=False)
    if backend == "pallas_interpret":
        return quantize_tilewise_pallas(x, interpret=True)
    return _ref.quantize_tilewise_ref(x)


def quantize_blockwise(w):
    """128x128 weight quantization (XLA everywhere — weights are quantized
    once per step outside the hot loop)."""
    return _ref.quantize_blockwise_ref(w)
