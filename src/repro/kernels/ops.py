"""Back-compat surface over :mod:`repro.kernels.dispatch`.

Historically this module owned the backend switch; the unified operator
registry in ``dispatch.py`` replaced it.  Pre-registry callers (and
tests) that import ``ops.grouped_gemm_fp8`` / ``ops.quantize_tilewise``
keep working — every call routes through the ``OpKey``-keyed registry,
including the ``"xla"`` alias for the ``"xla_ragged"`` backend.
"""
from __future__ import annotations

from repro.kernels.dispatch import (        # noqa: F401  (re-exports)
    QUANT_BLOCK,
    BackendUnavailableError,
    KernelConfig,
    OpKey,
    TilePlan,
    act_quantize,
    availability,
    backend_ignores_tiles,
    backend_matrix,
    backend_names,
    backend_uses_plan,
    default_backend,
    gmm_xla,
    gmm_xla_exact,
    grouped_gemm,
    grouped_gemm_bf16,
    grouped_gemm_fp8,
    grouped_gemm_quant,
    grouped_gemm_wgrad,
    grouped_gemm_wgrad_fp8,
    make_tile_plan,
    op_availability,
    op_backend_names,
    op_ignores_tiles,
    op_keys,
    op_uses_plan,
    quantize_blockwise,
    quantize_blockwise_batched,
    quantize_tilewise,
    register_backend,
    register_operator,
    register_wgrad_backend,
    resolve,
    resolve_backend,
    resolve_config,
    resolve_wgrad_backend,
    set_default_backend,
    wgrad_availability,
    wgrad_backend_names,
)
