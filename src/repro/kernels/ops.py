"""Backend dispatch for the grouped-GEMM and quantization ops.

Backends:
  * ``pallas``            — the TPU kernel (compiled; requires TPU)
  * ``pallas_interpret``  — same kernel body, interpreted on CPU (tests)
  * ``xla``               — ``jax.lax.ragged_dot`` on bf16-dequantized
                            operands.  Portable: this is what the multi-pod
                            dry-run lowers on CPU hosts, and what GSPMD
                            partitions.  On a real TPU fleet the ``pallas``
                            backend is selected by the launcher.
  * ``xla_exact``         — f32 per-K-block math identical to the kernel's;
                            used as a cross-check oracle in tests.

The default is chosen per-platform by :func:`default_backend`.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.grouped_gemm_kernel import gmm_pallas
from repro.kernels.quant_kernel import quantize_tilewise_pallas

QUANT_BLOCK = 128

_BACKENDS = ("pallas", "pallas_interpret", "xla", "xla_exact")
_default_backend_override: str | None = None


def set_default_backend(name: str | None) -> None:
    global _default_backend_override
    if name is not None and name not in _BACKENDS:
        raise ValueError(f"unknown backend {name!r}; choose from {_BACKENDS}")
    _default_backend_override = name


def default_backend() -> str:
    if _default_backend_override is not None:
        return _default_backend_override
    platform = jax.default_backend()
    return "pallas" if platform == "tpu" else "xla"


# ---------------------------------------------------------------------------
# XLA fast paths
# ---------------------------------------------------------------------------

def _dequant_a(a_fp8, s_a, dtype):
    m, k = a_fp8.shape
    scales = jnp.repeat(s_a, QUANT_BLOCK, axis=1)[:, :k]
    return (a_fp8.astype(jnp.float32) * scales).astype(dtype)


def _dequant_b(b_fp8, s_b, dtype):
    g, k, n = b_fp8.shape
    scales = jnp.repeat(jnp.repeat(s_b, QUANT_BLOCK, axis=1), QUANT_BLOCK,
                        axis=2)[:, :k, :n]
    return (b_fp8.astype(jnp.float32) * scales).astype(dtype)


def gmm_xla(a_fp8, s_a, b_fp8, s_b, group_sizes, *, out_dtype=jnp.bfloat16,
            compute_dtype=jnp.bfloat16):
    """ragged_dot on dequantized operands (GSPMD-partitionable)."""
    a = _dequant_a(a_fp8, s_a, compute_dtype)
    b = _dequant_b(b_fp8, s_b, compute_dtype)
    out = jax.lax.ragged_dot(a, b, group_sizes.astype(jnp.int32),
                             preferred_element_type=jnp.float32)
    return out.astype(out_dtype)


def gmm_xla_exact(a_fp8, s_a, b_fp8, s_b, group_sizes, *,
                  out_dtype=jnp.bfloat16):
    """Per-K-block f32 math — bit-identical accumulation order to the
    Pallas kernel (ragged_dot per K block, rescale, accumulate in f32)."""
    m, k = a_fp8.shape
    g, _, n = b_fp8.shape
    kb = k // QUANT_BLOCK
    gs = group_sizes.astype(jnp.int32)
    acc = jnp.zeros((m, n), jnp.float32)
    # row scale for token i and k-block j applied post-dot; column scale is
    # constant within a 128-wide n block.
    for j in range(kb):
        aj = a_fp8[:, j * QUANT_BLOCK:(j + 1) * QUANT_BLOCK].astype(jnp.float32)
        bj = b_fp8[:, j * QUANT_BLOCK:(j + 1) * QUANT_BLOCK, :].astype(jnp.float32)
        part = jax.lax.ragged_dot(aj, bj, gs,
                                  preferred_element_type=jnp.float32)
        # gather this token's group column-scales: expand s_b rows per group
        seg = jnp.repeat(jnp.arange(g), gs, total_repeat_length=m)
        col = jnp.repeat(s_b[:, j, :], QUANT_BLOCK, axis=1)[:, :n]   # (g, n)
        acc = acc + part * s_a[:, j][:, None] * col[seg]
    return acc.astype(out_dtype)


# ---------------------------------------------------------------------------
# Public dispatch
# ---------------------------------------------------------------------------

def grouped_gemm_fp8(a_fp8, s_a, b_fp8, s_b, group_sizes, *,
                     backend: str | None = None,
                     num_groups: int | None = None,
                     block_m: int = 128, block_n: int = 128,
                     block_k: int = 128, out_dtype=jnp.bfloat16):
    backend = backend or default_backend()
    if backend == "pallas":
        return gmm_pallas(a_fp8, s_a, b_fp8, s_b, group_sizes,
                          num_groups=num_groups, block_m=block_m,
                          block_n=block_n, block_k=block_k,
                          out_dtype=out_dtype, interpret=False)
    if backend == "pallas_interpret":
        return gmm_pallas(a_fp8, s_a, b_fp8, s_b, group_sizes,
                          num_groups=num_groups, block_m=block_m,
                          block_n=block_n, block_k=block_k,
                          out_dtype=out_dtype, interpret=True)
    if backend == "xla":
        return gmm_xla(a_fp8, s_a, b_fp8, s_b, group_sizes,
                       out_dtype=out_dtype)
    if backend == "xla_exact":
        return gmm_xla_exact(a_fp8, s_a, b_fp8, s_b, group_sizes,
                             out_dtype=out_dtype)
    raise ValueError(f"unknown backend {backend!r}")


def quantize_tilewise(x, *, backend: str | None = None, block_m: int = 256):
    backend = backend or default_backend()
    if backend == "pallas":
        return quantize_tilewise_pallas(x, block_m=block_m, interpret=False)
    if backend == "pallas_interpret":
        return quantize_tilewise_pallas(x, block_m=block_m, interpret=True)
    return _ref.quantize_tilewise_ref(x)


def quantize_blockwise(w):
    """128x128 weight quantization (XLA everywhere — weights are quantized
    once per step outside the hot loop)."""
    return _ref.quantize_blockwise_ref(w)
