"""Pallas TPU kernel: fused activation -> 1x128 per-tile fp8 quantization.

The fp8 MoE hot path used to materialize ``h = silu(g) * u`` in bf16, write
it to HBM, and read it straight back through ``quant_kernel`` — three HBM
passes over a tensor that exists only to feed the down GEMM.  This kernel
fuses the epilogue: one grid pass reads the gate/up GEMM outputs, computes
the activation per tile in f32, and emits the fp8 payload plus 1x128 scales
directly.  The intermediate never touches HBM.

The scale layout is byte-identical to ``quant_kernel``'s (``[M, K/128]``
f32, orientation-agnostic, travelling on the same global M-tiles as the
payload), so every existing consumer — forward GEMM x-side, dgrad dy-side,
both fp8 wgrad operands — accepts the fused output unchanged.

Supported activations:
  - ``silu_mul``: ``silu(g) * u`` (the SwiGLU expert FFN epilogue)
  - ``gelu``: unary ``gelu(g)`` (whisper's MLP; ``u`` must be None)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quant_kernel import FP8_MAX, QUANT_BLOCK

ACTIVATIONS = ("silu_mul", "gelu")


def _act_f32(g, u, act):
    """The activation in f32 — the single definition shared by the kernel,
    the ref oracle, and the backward's recompute (bitwise agreement)."""
    gf = g.astype(jnp.float32)
    if act == "silu_mul":
        return jax.nn.silu(gf) * u.astype(jnp.float32)
    if act == "gelu":
        return jax.nn.gelu(gf)
    raise ValueError(f"unknown activation {act!r}; expected {ACTIVATIONS}")


def _dequant_rows(x, s):
    """In-kernel 1x128 tilewise dequant: x [bm, k] fp8, s [bm, k/128] f32."""
    bm, k = x.shape
    kb = k // QUANT_BLOCK
    tiles = x.astype(jnp.float32).reshape(bm, kb, QUANT_BLOCK)
    return (tiles * s[..., None]).reshape(bm, k)


def _epilogue_kernel(*refs, kb, act, dequant):
    if dequant:
        # fused-producer inputs: the gate/up GEMMs emitted fp8 + 1x128
        # scales directly, so the operands dequantize on load — the bf16
        # g/u never existed anywhere
        if act == "silu_mul":
            g_ref, sg_ref, u_ref, su_ref, q_ref, s_ref = refs
            h = _act_f32(_dequant_rows(g_ref[...], sg_ref[...]),
                         _dequant_rows(u_ref[...], su_ref[...]), act)
        else:
            g_ref, sg_ref, q_ref, s_ref = refs
            h = _act_f32(_dequant_rows(g_ref[...], sg_ref[...]), None, act)
    elif act == "silu_mul":
        g_ref, u_ref, q_ref, s_ref = refs
        h = _act_f32(g_ref[...], u_ref[...], act)
    else:
        g_ref, q_ref, s_ref = refs
        h = _act_f32(g_ref[...], None, act)
    bm, k = h.shape
    tiles = h.reshape(bm, kb, QUANT_BLOCK)
    amax = jnp.max(jnp.abs(tiles), axis=-1)                  # (bm, kb)
    scale = jnp.where(amax > 0, amax / FP8_MAX, 1.0)
    q = tiles / scale[..., None]
    q_ref[...] = q.reshape(bm, k).astype(q_ref.dtype)
    s_ref[...] = scale.astype(jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("act", "block_m", "interpret"))
def act_quantize_pallas(g: jax.Array, u: jax.Array | None = None, *,
                        s_g: jax.Array | None = None,
                        s_u: jax.Array | None = None,
                        act: str = "silu_mul", block_m: int = 256,
                        interpret: bool = False):
    """g (and u for silu_mul): [M, K], K % 128 == 0.

    Two input modes:
      * bf16/f32 operands (``s_g``/``s_u`` absent) — the PR 6 contract.
      * fp8 operands with 1x128 scales (``s_g`` and, for silu_mul, ``s_u``
        each ``[M, K/128]`` f32) — the fused-producer hot path: operands
        dequantize on load inside the kernel, so the activation runs on
        exactly the values the producer GEMM's quantizing epilogue kept.

    Returns ``(q[M, K] fp8e4m3, s[M, K/128] f32)`` — the same contract as
    ``quantize_tilewise_pallas`` applied to the activation output.
    """
    if act not in ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}; expected {ACTIVATIONS}")
    if act == "silu_mul":
        if u is None:
            raise ValueError("act='silu_mul' needs both g and u")
        if u.shape != g.shape:
            raise ValueError(f"g {g.shape} and u {u.shape} must match")
    elif u is not None:
        raise ValueError(f"act={act!r} is unary; got a second operand")
    dequant = s_g is not None
    if dequant and u is not None and s_u is None:
        raise ValueError("fp8 inputs need scales for both operands "
                         "(got s_g but not s_u)")
    if not dequant and s_u is not None:
        raise ValueError("got s_u without s_g")
    m, k = g.shape
    if k % QUANT_BLOCK != 0:
        raise ValueError(f"K={k} must be a multiple of {QUANT_BLOCK}")
    kb = k // QUANT_BLOCK
    if dequant:
        for nm, sc in (("s_g", s_g), ("s_u", s_u)):
            if sc is not None and sc.shape != (m, kb):
                raise ValueError(
                    f"{nm} has shape {sc.shape}; fp8 operands of shape "
                    f"{(m, k)} need 1x128 scales of shape {(m, kb)}")
    block_m = min(block_m, max(8, m))
    grid = ((m + block_m - 1) // block_m,)
    if dequant:
        operands = (g, s_g) if u is None else (g, s_g, u, s_u)
        in_specs = []
        for op in operands:
            cols = k if op.shape[1] == k else kb
            in_specs.append(pl.BlockSpec((block_m, cols), lambda i: (i, 0)))
    else:
        operands = (g,) if u is None else (g, u)
        in_specs = [pl.BlockSpec((block_m, k), lambda i: (i, 0))
                    for _ in operands]
    return pl.pallas_call(
        functools.partial(_epilogue_kernel, kb=kb, act=act, dequant=dequant),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_m, k), lambda i: (i, 0)),
            pl.BlockSpec((block_m, kb), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.float8_e4m3fn),
            jax.ShapeDtypeStruct((m, kb), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
