"""TilePlan subsystem: plan-once/run-many grouped GEMM configuration.

The paper's core mechanism is a *preconfigured descriptor pool* with cheap
runtime selection (log2(block_M) TMA descriptors, Eq. 2): configure
expensive launch state once, select per launch.  This module is the
repo-wide analogue, split into three pieces:

``KernelConfig``
    One frozen record of every tile-shape decision (``block_m/n/k``), the
    dispatch backend, and the output dtype.  It replaces the loose
    ``block_m=128``-style kwargs that used to be scattered across
    ``dispatch.py``, ``core/``, models, serve, and benchmarks — tile
    shapes are a first-class tuned artifact, not folklore constants.
    Static alignment constraints are validated at construction; the
    shape-dependent ones via :meth:`KernelConfig.validate`.

``TilePlan``
    The visitation schedule (``group_offsets/group_ids/m_tile_ids``) the
    padding-free kernel walks — the descriptor-selection analogue.  It
    depends only on ``(group_sizes, m, block_m)``: *not* on K, N, or the
    weight operand.  One MoE layer application therefore builds it once
    per routing decision and reuses it across every GEMM that shares the
    same ``group_sizes`` — gate/up/down forward and the dgrads in the
    custom VJP (the transposed-N plan is the same plan, for free).

Pool autotuner
    ``CONFIG_POOL`` is a small pool of candidate configs (the descriptor
    pool analogue), ranked by a roofline cost model seeded from the
    ``benchmarks/roofline.py`` device table, then measured on the live
    backend.  Selections persist to a JSON cache keyed by
    ``(device kind, backend, M-bucket, K, N, G)`` so the measurement runs
    once per shape class per machine.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import logging
import os
import time
from typing import Any, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.analysis import events as _events
from repro.kernels import resources as _resources

logger = logging.getLogger("repro.plan")

QUANT_BLOCK = 128  # the paper's 1x128 / 128x128 quantization granularity


# ---------------------------------------------------------------------------
# KernelConfig
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Frozen tile-shape + backend + out-dtype descriptor for one grouped
    GEMM.  Hashable, so it can ride through ``jax.jit`` static args and
    ``custom_vjp`` nondiff args."""

    block_m: int = 128
    block_n: int = 128
    block_k: int = 128
    backend: Optional[str] = None      # dispatch registry name; None = auto
    # None = the call site decides (grouped_linear uses x.dtype, the raw
    # dispatch entry bf16); pin a dtype to override every consumer
    out_dtype: Any = None
    # operand precision of the training step's wgrad GEMM: "bf16" (the
    # DeepSeek recipe — wgrad keeps the highest-precision operands) or
    # "fp8" (arXiv 2505.20524's all-fp8 step: x and dy arrive as fp8 with
    # their 1x128 tile scales, dequantized per visit inside the kernel)
    wgrad_precision: str = "bf16"
    # route the fp8 FFN's gate/up GEMMs through the quantizing-epilogue
    # producer (``op="gemm_quant"``): the GEMMs emit fp8 + 1x128 scales
    # directly and the activation epilogue dequantizes on load, so the
    # bf16 g/u intermediates never exist.  Off by default — the fused
    # recipe quantizes g/u once more than the bf16-residual recipe, an
    # e4m3-relative-error tolerance delta (see core.grouped_gemm)
    fuse_producer: bool = False
    # multi-tile wgrad spans: one grid cell of the wgrad kernel owns an
    # (k_span*block_k, n_span*block_n) output super-tile, so the x operand
    # tile is fetched once per n_span N steps and the dy tile once per
    # k_span K steps (VMEM-resident reuse).  Only the wgrad family reads
    # these; every other op treats a span>1 config as its base block shape
    n_span: int = 1
    k_span: int = 1

    def __post_init__(self):
        # normalize out_dtype so configs built from jnp scalar types and
        # from the JSON cache (dtype names) are identical under ==/hash
        # (they ride through jit static args — a hash split compiles twice)
        if self.out_dtype is not None:
            object.__setattr__(self, "out_dtype", jnp.dtype(self.out_dtype))
        # static (shape-independent) constraints — TPU-adapted analogue of
        # the paper's block_N % 64 bookkeeping (§2.3)
        if self.block_m % 8 != 0:
            raise ValueError(
                f"block_m must be a multiple of 8 (sublane), got {self.block_m}")
        if self.block_n % 128 != 0:
            raise ValueError(
                f"block_n must be a multiple of 128 (lane width), got {self.block_n}")
        if self.block_k % QUANT_BLOCK != 0:
            raise ValueError(
                f"block_k must be a multiple of {QUANT_BLOCK}, got {self.block_k}")
        if self.wgrad_precision not in ("bf16", "fp8"):
            raise ValueError(
                f"wgrad_precision must be 'bf16' or 'fp8', "
                f"got {self.wgrad_precision!r}")
        for axis in ("n_span", "k_span"):
            v = getattr(self, axis)
            if not isinstance(v, int) or isinstance(v, bool) or v < 1:
                raise ValueError(f"{axis} must be an int >= 1, got {v!r}")

    def validate(self, m: int, k: int, n: int, *,
                 family: str = "gemm") -> "KernelConfig":
        """Shape-dependent constraints.  M is deliberately unconstrained —
        handling arbitrary (ragged) M without padding is the point of the
        paper.

        Beyond divisibility, the static resource model budget-checks the
        per-program VMEM footprint for ``family`` against the current
        device, so an explicitly infeasible config raises here with the
        computed footprint instead of surfacing as an opaque Mosaic
        allocation error at compile time."""
        eff_k, eff_n = self.effective_blocks(family)
        if k % eff_k != 0:
            raise ValueError(
                f"K={k} must be a multiple of block_k={self.block_k}"
                + (f" * k_span={self.k_span}" if eff_k != self.block_k else ""))
        if n % eff_n != 0:
            raise ValueError(
                f"N={n} must be a multiple of block_n={self.block_n}"
                + (f" * n_span={self.n_span}" if eff_n != self.block_n else ""))
        if family in _resources.FAMILIES:
            budget = device_spec().vmem_bytes
            fp = _resources.footprint(family, self, m=m, k=k, n=n,
                                      wgrad_precision=self.wgrad_precision)
            if fp["total_single"] > budget:
                raise ValueError(
                    f"{family} config (block_m={self.block_m}, "
                    f"block_n={self.block_n}, block_k={self.block_k}) needs "
                    f"{fp['total_single']} B of VMEM per program at "
                    f"M={m}, K={k}, N={n} — over the {budget} B device "
                    f"budget even single-buffered (buffers: {fp['buffers']})")
        return self

    def effective_blocks(self, family: str = "gemm") -> "tuple[int, int]":
        """(K, N) divisibility units for ``family``: the wgrad grid steps
        by whole (k_span*block_k, n_span*block_n) super-tiles; every other
        family ignores the spans."""
        if family == "wgrad":
            return self.block_k * self.k_span, self.block_n * self.n_span
        return self.block_k, self.block_n

    def compatible(self, k: int, n: int, family: str = "gemm") -> bool:
        eff_k, eff_n = self.effective_blocks(family)
        return k % eff_k == 0 and n % eff_n == 0

    def with_(self, **kw) -> "KernelConfig":
        return dataclasses.replace(self, **kw)

    # ---- (de)serialization for the autotune cache ----------------------
    def to_dict(self) -> dict:
        return {"block_m": self.block_m, "block_n": self.block_n,
                "block_k": self.block_k, "backend": self.backend,
                "out_dtype": (None if self.out_dtype is None
                              else jnp.dtype(self.out_dtype).name),
                "wgrad_precision": self.wgrad_precision,
                "fuse_producer": self.fuse_producer,
                "n_span": self.n_span, "k_span": self.k_span}

    @classmethod
    def from_dict(cls, d: dict) -> "KernelConfig":
        name = d.get("out_dtype")
        return cls(block_m=int(d["block_m"]), block_n=int(d["block_n"]),
                   block_k=int(d["block_k"]), backend=d.get("backend"),
                   out_dtype=None if name is None else jnp.dtype(name),
                   wgrad_precision=d.get("wgrad_precision", "bf16"),
                   fuse_producer=bool(d.get("fuse_producer", False)),
                   n_span=int(d.get("n_span", 1)),
                   k_span=int(d.get("k_span", 1)))

    @classmethod
    def default(cls, device_kind: Optional[str] = None) -> "KernelConfig":
        """Per-device default tile shape (untuned seed of the pool)."""
        kind = (device_kind or _device_kind()).lower()
        for prefix, cfg_kw in _DEVICE_DEFAULTS:
            if kind.startswith(prefix):
                return cls(**cfg_kw)
        return cls()


# per-device default block shapes, first prefix match wins.  v5e has half
# the VMEM of v4/v5p, so the default stays at one 128x128 output tile;
# larger parts get a taller M tile to amortize B traffic.
_DEVICE_DEFAULTS = (
    ("tpu v5 lite", dict(block_m=128)),
    ("tpu v5e", dict(block_m=128)),
    ("tpu", dict(block_m=256)),
    ("cpu", dict(block_m=128)),
)


def _device_kind() -> str:
    try:
        return jax.devices()[0].device_kind
    except Exception:  # no backend at all — import-time safety
        return "cpu"


# ---------------------------------------------------------------------------
# Default-config seam (serve/train thread a tuned config through here)
# ---------------------------------------------------------------------------

_default_config: Optional[KernelConfig] = None


def set_default_config(config: Optional[KernelConfig]) -> None:
    """Install the config that ``config=None`` call sites resolve to.

    TRACE-TIME semantics: the default is read while a function is being
    traced, so it does not affect already-jitted traces (the seam is not
    part of any jit cache key).  Install it *before* the first call of a
    jitted function — or thread the config explicitly as trainer
    (``make_train_step(kernel_config=...)``) and serve
    (``Engine(kernel_config=...)``) do, which re-trace by construction.
    """
    global _default_config
    _default_config = config


def get_default_config() -> KernelConfig:
    return _default_config if _default_config is not None \
        else KernelConfig.default()


def pinned_default() -> Optional[KernelConfig]:
    """The explicitly installed default, or None when unset — callers that
    would otherwise *tune* (benchmarks) check this to honour a pin."""
    return _default_config


@contextlib.contextmanager
def default_config(config: Optional[KernelConfig]):
    """Scoped :func:`set_default_config` (trainer wraps loss tracing)."""
    global _default_config
    prev = _default_config
    _default_config = config
    try:
        yield
    finally:
        _default_config = prev


def resolve_config(config: Optional[KernelConfig] = None, *,
                   backend: Optional[str] = None,
                   out_dtype: Any = None,
                   wgrad_precision: Optional[str] = None) -> KernelConfig:
    """Effective config for a call site: explicit ``config`` >
    installed default > per-device default, with per-call ``backend`` /
    ``out_dtype`` / ``wgrad_precision`` overrides applied on top."""
    cfg = config if config is not None else get_default_config()
    if backend is not None:
        # an explicit "auto" escapes a pinned concrete backend back to
        # auto-resolution (None is the config's backend field spelling)
        cfg = cfg.with_(backend=None if backend == "auto" else backend)
    if out_dtype is not None:
        cfg = cfg.with_(out_dtype=out_dtype)
    if wgrad_precision is not None:
        cfg = cfg.with_(wgrad_precision=wgrad_precision)
    return cfg


# ---------------------------------------------------------------------------
# Group metadata (descriptor selection, Eq. 2) and TilePlan
# ---------------------------------------------------------------------------

def make_group_metadata(group_sizes: jax.Array, m: int, block_m: int,
                        num_groups: int):
    """Device-side visitation schedule — the analogue of the paper's
    runtime descriptor selection (Eq. 2).

    Returns (group_offsets[G+1], group_ids[T], m_tile_ids[T]) where
    T = ceil(m/block_m) + num_groups - 1 is the static worst-case visit
    count: every tile is visited once, plus one extra visit per group
    boundary that splits a tile.

    Padding visits (t >= num_real) sweep the *tail tiles* — the output
    tiles entirely beyond ``sum(group_sizes)`` that no group owns — so the
    kernel's store can zero-fill every unowned row (rows in
    ``[sum(group_sizes), m)`` are DEFINED zeros, not garbage; the fp8
    backward's ``dx`` tail feeds a scatter-add and must not pollute real
    token gradients).  The worst-case visit count always suffices: the
    number of unused padding visits, ``T - num_real``, is at least
    ``num_tiles - ceil(total/block_m)``, the tail-tile count.  When there
    is no tail, padding visits clamp to the last real (group, tile) visit
    and redo an identical masked write — idempotent (the paper's "safe
    overlapping write").  Consumers that *accumulate* per visit instead of
    storing (the wgrad kernel) must therefore skip duplicate visits:
    ``(group_ids[t], m_tile_ids[t]) == (group_ids[t-1], m_tile_ids[t-1])``
    identifies them.

    When every group is empty (``num_real == 0``) every visit is a padding
    visit pinned to group 0; the sweep covers all tiles and the kernel
    zero-fills the whole buffer (``gmm_pallas`` still short-circuits to
    ``jnp.zeros`` to skip the launch).
    """
    # one event per schedule build: the plan-once/run-many contract
    # (REPRO-C02) counts these at trace time
    _events.emit("plan_build", m=m, block_m=block_m, num_groups=num_groups)
    group_sizes = group_sizes.astype(jnp.int32)
    group_offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(group_sizes)])
    starts = group_offsets[:-1]
    ends = group_offsets[1:]
    first_tile = starts // block_m
    last_tile_excl = (ends + block_m - 1) // block_m
    tiles_per = jnp.maximum(last_tile_excl - first_tile, 0)
    # zero-size groups get zero visits (even when their offset is unaligned)
    tiles_per = jnp.where(group_sizes == 0, 0, tiles_per)

    num_tiles = (m + block_m - 1) // block_m
    max_visits = max(num_tiles + num_groups - 1, 1)

    visit_ends = jnp.cumsum(tiles_per)            # [G]
    t = jnp.arange(max_visits, dtype=jnp.int32)
    # group that owns visit t (padding visits keep the last real group's
    # id — its row range never intersects a tail tile, so their masked
    # store owns no rows).  num_real == 0 would clamp to -1 and feed
    # searchsorted garbage — pin those schedules to group 0 (empty range).
    num_real = visit_ends[-1]
    t_clamped = jnp.maximum(jnp.minimum(t, num_real - 1), 0)
    group_ids = jnp.searchsorted(visit_ends, t_clamped, side="right")
    group_ids = jnp.minimum(group_ids, num_groups - 1).astype(jnp.int32)
    visits_before = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), visit_ends[:-1]])
    m_tile_ids = (first_tile[group_ids]
                  + (t_clamped - visits_before[group_ids])).astype(jnp.int32)
    m_tile_ids = jnp.clip(m_tile_ids, 0, max(num_tiles - 1, 0))
    # padding visits sweep the tail tiles (entirely beyond sum(sizes)) so
    # the kernel zero-fills them; with no tail they clamp to the last real
    # tile and redo its idempotent masked write (see docstring)
    total = ends[-1]
    last_real_tile = (total + block_m - 1) // block_m - 1      # -1 if total==0
    pad_tile = jnp.minimum(last_real_tile + 1 + (t - num_real),
                           max(num_tiles - 1, 0))
    m_tile_ids = jnp.where(t >= num_real,
                           jnp.maximum(pad_tile, 0).astype(jnp.int32),
                           m_tile_ids)
    group_ids = jnp.where(num_real == 0, 0, group_ids)
    return group_offsets, group_ids, m_tile_ids


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Precomputed grouped-GEMM schedule, reusable across every GEMM that
    shares the same ``group_sizes`` (M-side raggedness): gate/up/down
    forward GEMMs of one MoE application and the dgrads of its backward.
    A registered pytree, so it flows through ``jit`` and ``custom_vjp``
    residuals.

    CONTRACT: a plan is only valid for the exact ``group_sizes`` it was
    built from.  The static fields (m, block_m, num_groups) are checked
    at use; the offsets/ids are traced values that consumers trust
    without re-deriving (that is the point of plan-once/run-many — the
    same trade the paper's preconfigured descriptors make).  Passing a
    plan from a *different* routing decision that happens to share the
    static shape produces silently wrong output: never cache plans
    across routing decisions.
    """
    group_offsets: jax.Array   # [G+1] int32 row offsets (cumsum of sizes)
    group_ids: jax.Array       # [T]   int32 visit -> group
    m_tile_ids: jax.Array      # [T]   int32 visit -> output M tile
    m: int                     # static row count of the (capacity) buffer
    block_m: int
    num_groups: int

    @property
    def num_tiles(self) -> int:
        return (self.m + self.block_m - 1) // self.block_m

    @property
    def max_visits(self) -> int:
        return max(self.num_tiles + self.num_groups - 1, 1)

    def total_rows(self) -> jax.Array:
        """Traced sum of group sizes (rows the kernel actually owns)."""
        return self.group_offsets[-1]

    def check_against(self, m: int, block_m: int, num_groups: int) -> None:
        if (self.m, self.block_m, self.num_groups) != (m, block_m, num_groups):
            raise ValueError(
                f"TilePlan built for (m={self.m}, block_m={self.block_m}, "
                f"num_groups={self.num_groups}) used with (m={m}, "
                f"block_m={block_m}, num_groups={num_groups}); rebuild the "
                f"plan or pass a matching KernelConfig")


def _tile_plan_flatten(p: TilePlan):
    return ((p.group_offsets, p.group_ids, p.m_tile_ids),
            (p.m, p.block_m, p.num_groups))


def _tile_plan_unflatten(aux, children):
    return TilePlan(*children, *aux)


jax.tree_util.register_pytree_node(TilePlan, _tile_plan_flatten,
                                   _tile_plan_unflatten)


def make_tile_plan(group_sizes: jax.Array, m: int, *,
                   config: Optional[KernelConfig] = None,
                   block_m: Optional[int] = None,
                   num_groups: Optional[int] = None) -> TilePlan:
    """Build the visitation schedule once per routing decision."""
    if block_m is None:
        block_m = (config or get_default_config()).block_m
    num_groups = num_groups if num_groups is not None else group_sizes.shape[0]
    offsets, group_ids, m_tile_ids = make_group_metadata(
        group_sizes, m, block_m, num_groups)
    return TilePlan(offsets, group_ids, m_tile_ids, m=int(m),
                    block_m=int(block_m), num_groups=int(num_groups))


# ---------------------------------------------------------------------------
# PlanCache: serve every static plan shape once
# ---------------------------------------------------------------------------

class PlanCache:
    """Serves every *static* plan shape exactly once.

    A :class:`TilePlan`'s arrays depend on the ``group_sizes`` data, so
    the plan itself cannot be cached across calls — but the plan
    *builder* can: for one static key ``(m, block_m, num_groups,
    group_sizes dtype, device)`` the schedule derivation traces once and
    every later call (same static shape, new sizes) replays the compiled
    builder.  Eager call sites that used to re-derive the schedule per
    call — ``padded_baseline``'s block-aligned inner GEMM, a serving
    loop's per-step plans — pay the metadata math once per shape class,
    the same trade the paper's preconfigured descriptor pool makes.

    ``builds`` counts builder compilations (the regression surface for
    "two calls with the same static shape build exactly one plan").
    """

    def __init__(self):
        self._builders: "dict[tuple, Any]" = {}
        self.builds = 0

    def clear(self) -> None:
        self._builders.clear()
        self.builds = 0

    def get(self, group_sizes: jax.Array, m: int, *,
            block_m: Optional[int] = None,
            num_groups: Optional[int] = None) -> TilePlan:
        if block_m is None:
            block_m = get_default_config().block_m
        if num_groups is None:
            num_groups = group_sizes.shape[0]
        key = (int(m), int(block_m), int(num_groups),
               jnp.dtype(group_sizes.dtype).name, _device_kind())
        builder = self._builders.get(key)
        if builder is None:
            self.builds += 1

            def build(gs, _m=int(m), _bm=int(block_m), _g=int(num_groups)):
                return make_tile_plan(gs, _m, block_m=_bm, num_groups=_g)

            builder = jax.jit(build)
            self._builders[key] = builder
        return builder(group_sizes)


#: process-wide instance — cached plans sit beside the autotune entries as
#: the other per-shape-class artifact
PLAN_CACHE = PlanCache()


def shared_plan(group_sizes: jax.Array, m: int, *,
                block_m: Optional[int] = None,
                num_groups: Optional[int] = None) -> TilePlan:
    """Build (or replay) a :class:`TilePlan` through the process-wide
    :data:`PLAN_CACHE`."""
    return PLAN_CACHE.get(group_sizes, m, block_m=block_m,
                          num_groups=num_groups)


# ---------------------------------------------------------------------------
# Block-shape pool (the descriptor-pool analogue)
# ---------------------------------------------------------------------------

# block_m sweeps the paper's log2 descriptor axis; the (block_n, block_k)
# cross stays small — one 128-lane output tile or a double-wide variant.
# ONE pool serves every autotune op family (the keys of ``_AUTOTUNE_OPS``
# below — gemm/decode/wgrad/wgrad_fp8/quantize/act_quant/gemm_quant, i.e.
# the registry-derived family list, not a hardcoded enumeration): each op
# ranks the same candidates by its own roofline terms and caches the
# winner under its own key.
#
# The decode-specialized entries (block_m=8/16) extend the descriptor axis
# down to serving's tiny-M regime: a decode step's grouped GEMM has
# M = batch*top_k rows TOTAL, so a 128-row tile wastes >=87% of its
# fetched A rows and C flush.  The MXU-occupancy term in the cost model
# (``_eff_rows``) keeps these entries from ever ranking at training
# shapes: below 128 rows the compute time per visit is flat, so shrinking
# block_m only buys anything when it cuts *memory* traffic — i.e. when M
# itself is tiny.
DECODE_BLOCK_MS = (8, 16)
DECODE_POOL: "tuple[KernelConfig, ...]" = tuple(
    KernelConfig(block_m=bm) for bm in DECODE_BLOCK_MS)
# multi-tile wgrad span axis: same 128x128 base tile, but one grid cell
# owns a (k_span*128, n_span*128) output super-tile so the x operand tile
# is fetched once per n_span N steps and dy once per k_span K steps
# (kernels/wgrad_kernel.py).  Only the wgrad family reads the spans —
# autotune for every other op drops the span>1 entries up front, so the
# shared pool stays one namespace.  The axis stops at 4: span 8's
# (1024, 1024) f32 super-tile accumulator alone would blow the v5e VMEM
# budget the resource model proves entries against (REPRO-V01).
WGRAD_SPANS = (2, 4)
CONFIG_POOL: "tuple[KernelConfig, ...]" = DECODE_POOL + tuple(
    KernelConfig(block_m=bm, block_n=bn, block_k=bk)
    for bm in (64, 128, 256, 512)
    for bn, bk in ((128, 128), (256, 128))
) + tuple(
    KernelConfig(block_m=bm, n_span=s, k_span=s)
    for bm in (128, 256, 512)
    for s in WGRAD_SPANS
)


def candidate_pool(k: int, n: int,
                   pool: Optional[Iterable[KernelConfig]] = None,
                   require_transposable: bool = True,
                   family: str = "gemm"
                   ) -> "tuple[KernelConfig, ...]":
    """Pool entries legal for this (K, N) — never empty for 128-aligned
    shapes; falls back to the per-device default otherwise.

    ``require_transposable`` (default) additionally demands legality for
    the transposed (N, K) orientation: the fp8 custom VJP runs the dgrad
    through the same config against ``w^T``, so a forward-only-legal
    selection would crash every training step's backward.

    ``family`` feeds span-aware divisibility: for ``"wgrad"`` an entry
    must divide (K, N) by its whole (k_span*block_k, n_span*block_n)
    super-tile, so e.g. the span-4 entries drop out at K=256.
    """
    def legal(c):
        return c.compatible(k, n, family) and (
            not require_transposable or c.compatible(n, k, family))

    cands = tuple(c for c in (tuple(pool) if pool is not None else CONFIG_POOL)
                  if legal(c))
    if not cands:
        d = KernelConfig.default()
        cands = (d,) if legal(d) else ()
    return cands


# ---------------------------------------------------------------------------
# Roofline cost model (seeded from benchmarks/roofline.py device numbers)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    name: str
    peak_flops: float      # bf16 MXU (or SIMD) FLOP/s
    hbm_bw: float          # bytes/s
    hbm_bytes: float       # per-chip capacity (roofline "fits" column)
    # per-core VMEM budget the static resource model proves tile configs
    # against (kernels/resources.py owns the numbers; the "cpu" entry
    # carries the tightest real-TPU budget so interpret-mode selections
    # transfer to hardware)
    vmem_bytes: int = _resources.VMEM_BYTES["cpu"]


DEVICE_SPECS = {
    "tpu v5e": DeviceSpec("tpu v5e", peak_flops=1.97e14, hbm_bw=8.2e11,
                          hbm_bytes=16e9,
                          vmem_bytes=_resources.VMEM_BYTES["tpu v5e"]),
    "tpu": DeviceSpec("tpu", peak_flops=2.75e14, hbm_bw=1.2e12,
                      hbm_bytes=32e9,
                      vmem_bytes=_resources.VMEM_BYTES["tpu"]),
    "cpu": DeviceSpec("cpu", peak_flops=2e11, hbm_bw=5e10, hbm_bytes=64e9,
                      vmem_bytes=_resources.VMEM_BYTES["cpu"]),
}


def device_spec(device_kind: Optional[str] = None) -> DeviceSpec:
    kind = (device_kind or _device_kind()).lower()
    # real v5e hardware reports device_kind "TPU v5 lite"
    if kind.startswith(("tpu v5 lite", "tpu v5e")):
        return DEVICE_SPECS["tpu v5e"]
    for prefix in ("tpu", "cpu"):
        if kind.startswith(prefix):
            return DEVICE_SPECS[prefix]
    return DEVICE_SPECS["cpu"]


# the MXU processes a full 128-row pass regardless of how few rows a tile
# holds: compute time per visit is flat below this granularity, so the
# cost model charges tiles their *occupied* MXU rows — the term that
# confines the decode entries (block_m=8/16) to the tiny-M regime where
# their memory-traffic savings are real
MXU_M = 128


def _eff_rows(block_m: int) -> int:
    return -(-block_m // MXU_M) * MXU_M


def estimate_cost_s(m: int, k: int, n: int, g: int, config: KernelConfig,
                    spec: Optional[DeviceSpec] = None,
                    quant_output: bool = False,
                    precision: str = "fp8") -> float:
    """Roofline estimate of one grouped GEMM under ``config``: max of the
    compute and memory terms, with the visit-inflation the plan implies
    (worst case: every group boundary splits a tile, +G-1 visits).
    Compute charges MXU occupancy (``_eff_rows``): a sub-128-row tile
    takes a full MXU pass; memory charges the bytes actually moved.

    ``quant_output`` models the quantizing-epilogue variant
    (``op="gemm_quant"``): the bf16 C flush is replaced by the fp8
    payload + f32 1x128 scale rows — half the output bytes, same
    compute.  ``precision="bf16"`` models the true-bf16 kernel
    (``op="gemm_bf16"``): 2-byte operands, no scale-row traffic."""
    spec = spec or device_spec()
    bm, bn = config.block_m, config.block_n
    num_tiles = -(-m // bm)
    visits = num_tiles + max(g - 1, 0)
    n_steps = -(-n // bn)
    kb = -(-k // QUANT_BLOCK)
    nb = -(-n // QUANT_BLOCK)
    # every visit computes a full (bm, k) x (k, n) tile row
    flops = 2.0 * visits * _eff_rows(bm) * k * n
    if precision == "bf16":
        a_bytes = visits * n_steps * bm * k * 2        # bf16 A, no scales
        b_bytes = visits * k * n * 2                   # bf16 B per visit
    else:
        a_bytes = visits * n_steps * bm * (k + 4 * kb)  # fp8 A + f32 S_A
        b_bytes = visits * k * n                        # fp8 B per visit
    if quant_output:
        c_bytes = num_tiles * bm * (n + 4 * nb)        # fp8 C + f32 scales
    else:
        c_bytes = num_tiles * bm * n * 2               # bf16 C flush
    return max(flops / spec.peak_flops,
               (a_bytes + b_bytes + c_bytes) / spec.hbm_bw)


def wgrad_operand_bytes(m: int, k: int, n: int, g: int,
                        config: KernelConfig,
                        precision: str = "bf16") -> int:
    """Modeled operand HBM bytes of one wgrad pass (x + dy fetches; the
    dw flush is schedule-independent and excluded).  This is the traffic
    model the multi-tile schedule exists to shrink:

    * single-tile (``n_span = k_span = 1``): each visit walks every
      (k, n) grid cell, so per visit the operands cost
      ``kn_steps * (bm*bk + bm*bn)`` elements — x is re-fetched from HBM
      on every N step and dy on every K step.
    * multi-tile: one grid cell owns a ``(k_span*bk, n_span*bn)`` output
      super-tile, the x tile stays VMEM-resident across its n_span N
      steps and dy across its k_span K steps, so per visit the operands
      cost ``ceil(n_steps/n_span) * bm*k + ceil(k_steps/k_span) * bm*n``
      elements — at full span this is the ideal ``k*bm + n*bm``, one
      fetch of each operand tile per visit.

    With ``precision="fp8"`` the payloads are 1-byte and each grid cell
    additionally fetches the whole f32 1x128 scale rows for its tiles."""
    bm = config.block_m
    visits = -(-m // bm) + max(g - 1, 0)
    k_steps = -(-k // config.block_k)
    n_steps = -(-n // config.block_n)
    k_groups = -(-k_steps // config.k_span)
    n_groups = -(-n_steps // config.n_span)
    if precision == "fp8":
        kb = -(-k // QUANT_BLOCK)
        nb = -(-n // QUANT_BLOCK)
        x_bytes = visits * n_groups * bm * k              # fp8 payload
        dy_bytes = visits * k_groups * bm * n
        scale_bytes = visits * k_groups * n_groups * bm * 4 * (kb + nb)
        return int(x_bytes + dy_bytes + scale_bytes)
    x_bytes = visits * n_groups * bm * k * 2              # bf16 payload
    dy_bytes = visits * k_groups * bm * n * 2
    return int(x_bytes + dy_bytes)


def estimate_cost_s_wgrad(m: int, k: int, n: int, g: int,
                          config: KernelConfig,
                          spec: Optional[DeviceSpec] = None,
                          precision: str = "bf16") -> float:
    """Roofline estimate of the ragged-contraction (wgrad) grouped GEMM
    ``dw[g] = x_g^T @ dy_g`` under ``config``.  Same visit inflation as the
    forward (the contraction walks the same M-tile schedule); operand
    traffic is :func:`wgrad_operand_bytes` — per visit the old single-tile
    schedule moves ``kn_steps*(bm*bk + bm*bn)`` operand elements while a
    full-span multi-tile schedule moves ``k*bm + n*bm`` — and the dense
    ``[G, K, N]`` f32 output flushes once per group.  The memory term is
    what shrinks with wider spans, so on memory-bound wgrad shapes the
    model prefers the widest span that divides the shape and fits VMEM
    (the resource model prunes the rest); on compute-bound shapes the
    span axis is cost-neutral and measurement arbitrates.  With
    ``precision="fp8"`` the operands are 1-byte fp8 plus their f32 1x128
    tile-scale rows (over-fetched whole per grid cell, like the
    forward)."""
    spec = spec or device_spec()
    bm = config.block_m
    visits = -(-m // bm) + max(g - 1, 0)
    flops = 2.0 * visits * _eff_rows(bm) * k * n
    operand_bytes = wgrad_operand_bytes(m, k, n, g, config,
                                        precision=precision)
    dw_bytes = g * k * n * 4                             # f32 dw flush
    return max(flops / spec.peak_flops,
               (operand_bytes + dw_bytes) / spec.hbm_bw)


def estimate_cost_s_quantize(m: int, k: int, config: KernelConfig,
                             spec: Optional[DeviceSpec] = None) -> float:
    """Roofline estimate of one 1x128 tilewise quantization pass under
    ``config`` (the kernel's tile height is ``block_m``).  The pass is
    memory-bound and its traffic is tile-height-independent (read the
    f32 payload, write fp8 + f32 scale rows); the grid term models
    per-tile dispatch overhead, so the model ranks taller tiles first and
    live measurement arbitrates the rest — exactly the split the GEMM
    families use for their tile-free backends."""
    spec = spec or device_spec()
    tiles = -(-m // config.block_m)
    kb = -(-k // QUANT_BLOCK)
    bytes_moved = m * k * 4 + m * k * 1 + m * kb * 4
    return bytes_moved / spec.hbm_bw + tiles * 1e-6


def estimate_cost_s_act_quant(m: int, k: int, config: KernelConfig,
                              spec: Optional[DeviceSpec] = None) -> float:
    """Roofline estimate of one fused activation->quantize epilogue pass
    (``op="act_quant"``): reads the gate AND up GEMM outputs (bf16),
    writes fp8 payload + f32 scale rows — ~3x fewer HBM bytes for the
    intermediate than the unfused write-h/read-h/write-q sequence.  Same
    model split as :func:`estimate_cost_s_quantize`: traffic is
    tile-height-independent, the grid term ranks taller tiles first,
    measurement arbitrates."""
    spec = spec or device_spec()
    tiles = -(-m // config.block_m)
    kb = -(-k // QUANT_BLOCK)
    bytes_moved = 2 * m * k * 2 + m * k * 1 + m * kb * 4
    return bytes_moved / spec.hbm_bw + tiles * 1e-6


# ---------------------------------------------------------------------------
# Persistent autotune cache
# ---------------------------------------------------------------------------

_CACHE_VERSION = 1
_cache_mem: "dict[str, dict[str, dict]]" = {}   # path -> entries


def default_cache_path() -> str:
    return os.environ.get(
        "REPRO_TILEPLAN_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "tileplan_cache.json"))


def _m_bucket(m: int) -> int:
    """Paper-flavoured log2 bucketing: shapes in the same power-of-two M
    band share a tuned config."""
    b = 1
    while b < max(m, 1):
        b *= 2
    return b


def cache_key(device_kind: str, backend: str, m: int, k: int, n: int,
              g: int, op: str = "gemm") -> str:
    """Cache key for one (device, backend, shape-class, op) selection.

    ``op`` is any key of :data:`_AUTOTUNE_OPS` — the registry-derived
    family list (currently gemm, decode, wgrad, wgrad_fp8, quantize,
    act_quant, gemm_quant; new dispatch families join by adding an entry
    there, never by editing this function).  The non-default ops append
    ``|<op>``.

    Every key is additionally namespaced by the static resource model's
    version (``|rm<N>``): pool selections made under an older footprint
    model — in particular any selection from before static feasibility
    pruning existed — must be re-tuned, not trusted.  Old-format entries
    in an existing cache file simply never match (and are preserved on
    save), so stale caches are ignored rather than crashed on.
    """
    suffix = "" if op == "gemm" else f"|{op}"
    return (f"{device_kind}|{backend}|M{_m_bucket(m)}|K{k}|N{n}|G{g}{suffix}"
            f"|rm{_resources.RESOURCE_MODEL_VERSION}")


def _read_cache_file(path: str) -> "dict[str, dict]":
    try:
        with open(path) as f:
            raw = json.load(f)
        if raw.get("version") == _CACHE_VERSION:
            return dict(raw.get("entries", {}))
    except (OSError, ValueError):
        pass
    return {}


def load_cache(path: Optional[str] = None) -> "dict[str, dict]":
    path = path or default_cache_path()
    if path not in _cache_mem:
        _cache_mem[path] = _read_cache_file(path)
    return _cache_mem[path]


def save_cache(entries: "dict[str, dict]",
               path: Optional[str] = None) -> None:
    path = path or default_cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    # merge with whatever is on disk *now* — concurrent processes tuning
    # different shapes must not drop each other's (expensive, measured)
    # entries; ours win on key collisions
    merged = {**_read_cache_file(path), **entries}
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump({"version": _CACHE_VERSION, "entries": merged}, f,
                  indent=1, sort_keys=True)
    os.replace(tmp, path)
    _cache_mem[path] = merged


def clear_cache_memo() -> None:
    """Drop the in-process cache view (tests; does not touch the file)."""
    _cache_mem.clear()


# ---------------------------------------------------------------------------
# Autotuner: measured pool selection on the live backend
# ---------------------------------------------------------------------------

# autotune op family -> dispatch OpKey.  THE authoritative family list:
# cache_key suffixes, candidate legality, and the cost-model switch in
# autotune() all derive from these keys — a new dispatch family plugs in
# by adding one entry (+ a _measure_candidate branch), nothing else.
_AUTOTUNE_OPS = {
    "gemm": ("gemm", "fp8"),
    "gemm_bf16": ("gemm", "bf16"),   # true bf16 Pallas baseline kernel
    "decode": ("gemm", "fp8"),       # tiny-M serving shapes, decode pool
    "gemm_quant": ("gemm_quant", "fp8"),  # fused quantizing epilogue
    "wgrad": ("wgrad", "bf16"),
    "wgrad_fp8": ("wgrad", "fp8"),
    "quantize": ("quantize", "fp8"),
    "act_quant": ("act_quant", "fp8"),
}

# autotune op -> (resource-model family, operand precision) for the
# static feasibility pruning pass.  The precision slot feeds
# ``wgrad_precision`` for the wgrad family (scale-row buffers) and
# ``gemm_precision`` for the gemm family (bf16 = 2-byte operand tiles,
# no scale buffers); None means the family's fp8 default footprint.
_RESOURCE_FAMILIES = {
    "gemm": ("gemm", None),
    "gemm_bf16": ("gemm", "bf16"),
    "decode": ("gemm", None),
    "gemm_quant": ("gemm_quant", None),
    "wgrad": ("wgrad", "bf16"),
    "wgrad_fp8": ("wgrad", "fp8"),
    "quantize": ("quantize", None),
    "act_quant": ("act_quant", None),
}

# how many pool entries static feasibility pruning eliminated this
# process, per op — benchmarks/run.py snapshots this next to the rows it
# measured so BENCH_*.json records the model's contribution
_PRUNE_STATS: "dict[str, int]" = {}
# full report of the most recent autotune() call (tests + bench notes)
_LAST_REPORT: "dict[str, Any]" = {}


def prune_stats() -> "dict[str, int]":
    """Per-op count of statically-pruned pool entries this process."""
    return dict(_PRUNE_STATS)


def reset_prune_stats() -> None:
    _PRUNE_STATS.clear()


def last_autotune_report() -> "dict[str, Any]":
    """The most recent autotune() call's selection report: op, cache key,
    cache_hit, pruned [(config dict, reason)], skipped [(config dict,
    reason)] from the measurement loop, and the winning source."""
    return dict(_LAST_REPORT)


def _prune_infeasible(cands, op: str, m: int, k: int, n: int,
                      spec: "DeviceSpec"):
    """Drop statically-infeasible candidates before ranking/measuring.
    Returns ``(kept, pruned)`` with ``pruned`` as (config, reason) pairs.
    If the model would reject everything the original pool stands (the
    lint will flag the pool itself; selection must not dead-end)."""
    family, prec = _RESOURCE_FAMILIES[op]
    kept, pruned = [], []
    for c in cands:
        reason = _resources.infeasible_reason(
            family, c, m, k, n, vmem_bytes=spec.vmem_bytes,
            wgrad_precision=prec if family == "wgrad" else None,
            gemm_precision=prec if family == "gemm" else None)
        (kept if reason is None else pruned).append(
            c if reason is None else (c, reason))
    if not kept:
        return tuple(cands), []
    return tuple(kept), pruned


def _measure_candidate(config: KernelConfig, m: int, k: int, n: int, g: int,
                       *, iters: int = 3, warmup: int = 1,
                       seed: int = 0, op: str = "gemm") -> float:
    """Median wall seconds of one operator application under ``config`` on
    random operands (the live-backend measurement behind pool selection):
    grouped GEMM (``"gemm"``/``"decode"``), its quantizing-epilogue twin
    (``"gemm_quant"``), ragged wgrad contraction
    (``"wgrad"``/``"wgrad_fp8"``), tilewise quantization (``"quantize"``),
    or the fused activation->quantize epilogue (``"act_quant"``)."""
    import numpy as np
    from repro.kernels import dispatch, ref

    rng = np.random.default_rng(seed)
    g_eff = max(g, 1)                       # "quantize" callers pass g=0
    sizes = rng.multinomial(m, np.full(g_eff, 1.0 / g_eff)).astype(np.int32)
    gs = jnp.asarray(sizes)
    g = g_eff

    if op == "wgrad":
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)
        dy = jnp.asarray(rng.standard_normal((m, n)), jnp.bfloat16)

        def run():
            return dispatch.grouped_gemm_wgrad(x, dy, gs, num_groups=g,
                                               config=config)
    elif op == "wgrad_fp8":
        x8, sx = ref.quantize_tilewise_ref(
            jnp.asarray(rng.standard_normal((m, k)), jnp.float32))
        d8, sd = ref.quantize_tilewise_ref(
            jnp.asarray(rng.standard_normal((m, n)), jnp.float32))

        def run():
            return dispatch.grouped_gemm_wgrad_fp8(x8, sx, d8, sd, gs,
                                                   num_groups=g,
                                                   config=config)
    elif op == "quantize":
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)

        def run():
            return dispatch.quantize_tilewise(x, backend=config.backend,
                                              config=config)
    elif op == "act_quant":
        ga = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)
        ua = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)

        def run():
            return dispatch.act_quantize(ga, ua, backend=config.backend,
                                         config=config)
    elif op == "gemm_bf16":
        xb = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)
        wb = jnp.asarray(rng.standard_normal((g, k, n)), jnp.bfloat16)

        def run():
            return dispatch.grouped_gemm_bf16(xb, wb, gs, num_groups=g,
                                              config=config)
    elif op == "gemm_quant":
        a8, sa = ref.quantize_tilewise_ref(
            jnp.asarray(rng.standard_normal((m, k)), jnp.float32))
        b8, sb = jax.vmap(ref.quantize_blockwise_ref)(
            jnp.asarray(rng.standard_normal((g, k, n)), jnp.float32))

        def run():
            return dispatch.grouped_gemm_quant(a8, sa, b8, sb, gs,
                                               config=config)
    else:
        a8, sa = ref.quantize_tilewise_ref(
            jnp.asarray(rng.standard_normal((m, k)), jnp.float32))
        b8, sb = jax.vmap(ref.quantize_blockwise_ref)(
            jnp.asarray(rng.standard_normal((g, k, n)), jnp.float32))

        def run():
            return dispatch.grouped_gemm_fp8(a8, sa, b8, sb, gs,
                                             config=config)

    for _ in range(warmup):
        jax.block_until_ready(run())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def autotune(m: int, k: int, n: int, g: int, *,
             backend: Optional[str] = None,
             pool: Optional[Iterable[KernelConfig]] = None,
             cache_path: Optional[str] = None,
             measure: bool = True,
             max_candidates: int = 4,
             refresh: bool = False,
             seed: int = 0,
             op: str = "gemm") -> KernelConfig:
    """Select a ``KernelConfig`` for the shape class of (M, K, N, G).

    ``op`` is any key of :data:`_AUTOTUNE_OPS` — the registry-derived
    family list (a new dispatch family joins by adding an entry there):
    ``"gemm"`` is the forward/dgrad orientation (ragged M output rows),
    ``"decode"`` the same orientation restricted to the
    decode-specialized pool (tiny constant M per serving step;
    block_m<=16), ``"gemm_quant"`` the quantizing-epilogue producer
    (same orientation, fp8 + 1x128-scale output — its roofline drops the
    bf16 output write), ``"wgrad"`` the ragged-contraction orientation
    (``dw[g] = x_g^T @ dy_g``), ``"wgrad_fp8"`` that contraction on fp8
    operands + 1x128 tile scales, ``"quantize"`` the tilewise quantizer's
    tile height, and ``"act_quant"`` the fused activation->quantize
    epilogue's tile height (both K-only legality; N and G are ignored —
    pass 0).  Each ranks by its own roofline terms and caches under
    distinct keys: a routing decision tunes once per operator it uses.

    Pool candidates are ranked by the roofline cost model, the top
    ``max_candidates`` are measured on the live backend (skipped with
    ``measure=False`` — pure cost-model selection), and the winner is
    persisted to the JSON cache so later runs (and later processes) reuse
    it without re-measuring.
    """
    from repro.kernels import dispatch

    if op not in _AUTOTUNE_OPS:
        raise ValueError(f"unknown autotune op {op!r}; use one of "
                         f"{tuple(_AUTOTUNE_OPS)}")
    op_key = _AUTOTUNE_OPS[op]
    # configs carry the family-neutral backend name (one config string
    # rides a whole training step); the OpKey precision — not the name —
    # selects each family's twin at run time
    base = dispatch.resolve(op_key, backend)
    # cache keys keep the historical per-precision spelling (the fp8
    # wgrad entries were published as ``<name>_fp8``)
    resolved = base + ("_fp8" if op == "wgrad_fp8" else "")
    tile_free = dispatch.op_ignores_tiles(op_key, base)
    kind = _device_kind()
    key = cache_key(kind, resolved, m, k, n, g, op=op)
    entries = load_cache(cache_path)
    if not refresh and key in entries:
        entry = entries[key]
        # a cost-model-only entry does not satisfy a measured request —
        # upgrade it (tile-free backends never measure, so theirs stand)
        wants_measured = measure and not tile_free
        if entry.get("source") == "measured" or not wants_measured:
            _LAST_REPORT.clear()
            _LAST_REPORT.update(op=op, key=key, cache_hit=True,
                                pruned=[], skipped=[],
                                source=entry.get("source"))
            return KernelConfig.from_dict(entry["config"])

    if pool is None and op == "decode":
        pool = DECODE_POOL
    # wgrad's output is never transposed — forward/dgrad legality demands
    # both orientations, wgrad only its own; the quantizer has no (K, N)
    # output tile at all (its block_m is pure scheduling)
    # gemm_quant feeds the same FFN whose dgrads run the transposed
    # orientation under the same config, so it shares gemm's legality
    cands = candidate_pool(
        k, n, pool,
        require_transposable=(op in ("gemm", "gemm_bf16", "decode",
                                     "gemm_quant")),
        family=_RESOURCE_FAMILIES[op][0])
    if op not in ("wgrad", "wgrad_fp8"):
        # the span axes exist for the wgrad schedule only — every span>1
        # entry is a duplicate of its span-1 base for the other ops
        cands = tuple(c for c in cands if c.n_span == 1 and c.k_span == 1)
    if op in ("quantize", "act_quant"):
        # entries differing only in (block_n, block_k) are duplicates for
        # the quantizer/epilogue — keep one per tile height
        seen, uniq = set(), []
        for c in cands:
            if c.block_m not in seen:
                seen.add(c.block_m)
                uniq.append(c)
        cands = tuple(uniq)
    if not cands:
        raise ValueError(f"no pool candidate is legal for K={k}, N={n}")
    spec = device_spec(kind)
    # static feasibility pruning: the resource model eliminates entries
    # that can never run well at this shape (VMEM over budget, degenerate
    # grid) before a single measurement is spent on them
    cands, pruned = _prune_infeasible(cands, op, m, k, n, spec)
    if pruned:
        _PRUNE_STATS[op] = _PRUNE_STATS.get(op, 0) + len(pruned)
        for c, reason in pruned:
            logger.info("autotune[%s] statically pruned block_m=%d,"
                        "block_n=%d,block_k=%d: %s", op, c.block_m,
                        c.block_n, c.block_k, reason)
    if op in ("gemm", "decode"):
        cost = estimate_cost_s
    elif op == "gemm_bf16":
        cost = lambda m_, k_, n_, g_, c, s: \
            estimate_cost_s(m_, k_, n_, g_, c, s, precision="bf16")  # noqa: E731
    elif op == "gemm_quant":
        cost = lambda m_, k_, n_, g_, c, s: \
            estimate_cost_s(m_, k_, n_, g_, c, s, quant_output=True)  # noqa: E731
    elif op == "quantize":
        cost = lambda m_, k_, n_, g_, c, s: \
            estimate_cost_s_quantize(m_, k_, c, s)                # noqa: E731
    elif op == "act_quant":
        cost = lambda m_, k_, n_, g_, c, s: \
            estimate_cost_s_act_quant(m_, k_, c, s)               # noqa: E731
    else:
        prec = "fp8" if op == "wgrad_fp8" else "bf16"
        cost = lambda *a: estimate_cost_s_wgrad(*a, precision=prec)  # noqa: E731
    if op in ("wgrad", "wgrad_fp8"):
        # secondary key: modeled operand HBM bytes.  On compute-bound
        # shapes the roofline max() ties across span widths — prefer the
        # schedule that moves fewer bytes (the multi-tile point), leaving
        # measurement to arbitrate among the top candidates
        prec_rank = "fp8" if op == "wgrad_fp8" else "bf16"
        ranked = sorted(cands, key=lambda c: (
            cost(m, k, n, g, c, spec),
            wgrad_operand_bytes(m, k, n, g, c, precision=prec_rank)))
    else:
        ranked = sorted(cands, key=lambda c: cost(m, k, n, g, c, spec))
    overrides = {"backend": base}
    if op == "wgrad_fp8":
        overrides["wgrad_precision"] = "fp8"
    ranked = [c.with_(**overrides) for c in ranked]

    skipped: "list[tuple[KernelConfig, str]]" = []
    if measure and not tile_free:
        # a candidate that fails to compile/measure is recorded and
        # skipped, not allowed to abort the sweep (and a statically
        # pruned config never reaches this loop at all)
        timed = []
        for c in ranked[:max_candidates]:
            try:
                timed.append((_measure_candidate(c, m, k, n, g, seed=seed,
                                                 op=op), c))
            except Exception as exc:  # noqa: BLE001 - sweep must survive
                reason = f"{type(exc).__name__}: {exc}"
                skipped.append((c, reason))
                logger.warning("autotune[%s] measurement of block_m=%d,"
                               "block_n=%d,block_k=%d failed, skipping: %s",
                               op, c.block_m, c.block_n, c.block_k, reason)
        if timed:
            best_s, best = min(timed, key=lambda tc: tc[0])
            source = "measured"
        else:
            # every measurement failed — fall back to the cost-model
            # ranking rather than dead-ending the caller
            best, best_s = ranked[0], cost(m, k, n, g, ranked[0], spec)
            source = "cost_model"
    else:
        # tile-shape-independent backends (the XLA paths) or measure=False:
        # cost-model order is the selection
        best, best_s = ranked[0], cost(m, k, n, g, ranked[0], spec)
        source = "cost_model"

    entries[key] = {"config": best.to_dict(), "seconds": best_s,
                    "source": source, "pool_size": len(cands), "op": op,
                    "pruned": len(pruned),
                    "skipped": [{"config": c.to_dict(), "reason": r}
                                for c, r in skipped]}
    _LAST_REPORT.clear()
    _LAST_REPORT.update(op=op, key=key, cache_hit=False,
                        pruned=[(c.to_dict(), r) for c, r in pruned],
                        skipped=[(c.to_dict(), r) for c, r in skipped],
                        source=source)
    save_cache(entries, cache_path)
    return best


def decode_config(m: int, k: int, n: int, g: int, *,
                  backend: Optional[str] = None,
                  cache_path: Optional[str] = None,
                  measure: bool = False,
                  **kw) -> KernelConfig:
    """Decode-specialized pool selection (``op="decode"``): the serving
    engine's per-step grouped GEMM has tiny, *constant* M (batch x top_k
    rows total), so selection runs once at engine construction and the
    returned ``block_m<=16`` config rides every decode step.  Cost-model
    selection by default (``measure=False``) — engine construction should
    not block on kernel timing; pass ``measure=True`` to tune on-device.
    """
    # one event per pool selection: the decode-plan contract (REPRO-C06)
    # pins exactly one per Engine construction
    _events.emit("decode_select", m=m, k=k, n=n, g=g)
    return autotune(m, k, n, g, backend=backend, cache_path=cache_path,
                    measure=measure, op="decode", **kw)
