"""qwen2-moe-a2.7b — 60 routed (top-4) + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B].

60 experts do not divide the 16-way model axis, so this arch exercises the
TP-on-d_ff MoE fallback (DESIGN.md §4): per-expert d_ff 1408 is sharded
16-way (88 per shard) while experts stay replicated.
"""
import dataclasses

from repro.configs.base import ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=0, vocab_size=151936, head_dim=128, rope_theta=1e6,
    qkv_bias=True,
    moe=MoESpec(num_experts=60, top_k=4, d_ff_expert=1408,
                num_shared_experts=4, norm_topk_prob=False),
)

RUN_HINTS = {"train_microbatch": 32, "prefill_microbatch": 16}


def smoke_config():
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
        head_dim=64, vocab_size=512, attn_chunk=64,
        moe=MoESpec(num_experts=8, top_k=2, d_ff_expert=128,
                    num_shared_experts=2, norm_topk_prob=False))
