"""pixtral-12b — pixtral-ViT frontend (STUB) + mistral-nemo decoder
[hf:mistralai/Pixtral-12B-2409].

Per the assignment the vision frontend is a stub: ``input_specs()`` provides
precomputed patch embeddings [B, num_patches, patch_embed_dim] which are
linearly projected and prepended to the token sequence.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128, rope_theta=1e9,
    num_patches=256, patch_embed_dim=1024,
)

RUN_HINTS = {"train_microbatch": 16, "prefill_microbatch": 8}


def smoke_config():
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512, attn_chunk=64,
        num_patches=16, patch_embed_dim=64)
