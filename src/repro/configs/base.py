"""Model / run configuration dataclasses shared by all architectures."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp

from repro.kernels.plan import KernelConfig, resolve_config


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    norm_topk_prob: bool = False
    capacity_factor: float = 2.0
    first_dense_layers: int = 0        # deepseek-moe: layer 0 is dense FFN


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense|moe|ssm|hybrid|audio|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qk_norm: bool = False              # qwen3
    qkv_bias: bool = False             # qwen1.5
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # block layout: cycled pattern; "attn" = attn+ffn block,
    # "rglru" = recurrent block + ffn, "mlstm"/"slstm" = xLSTM blocks
    block_pattern: Tuple[str, ...] = ("attn",)
    window: Optional[int] = None       # sliding-window size for local attn
    moe: Optional[MoESpec] = None
    # recurrent dims
    lru_width: Optional[int] = None
    conv_width: int = 4
    # encoder-decoder (whisper): encoder frames are a precomputed stub
    encoder_layers: int = 0
    encoder_seq: int = 1500
    cross_attention: bool = False
    # vlm stub: precomputed patch embeddings projected + prepended
    num_patches: int = 0
    patch_embed_dim: int = 1024
    # numerics / execution
    dtype: Any = jnp.bfloat16
    precision: str = "bf16"            # "bf16" | "fp8" for grouped/linear GEMMs
    gemm_backend: Optional[str] = None
    # tile shapes for every grouped/linear GEMM (repro.kernels.plan) —
    # None resolves to the installed/per-device default; pin one (e.g. an
    # autotuned selection) to make tile shapes part of the run config
    kernel_config: Optional[KernelConfig] = None
    # training-recipe switch for the backward's wgrad operand precision:
    # None keeps the kernel_config's field (default "bf16" — the DeepSeek
    # recipe), "fp8" selects the all-fp8 step (arXiv 2505.20524) from the
    # preset without hand-building a KernelConfig.  Folded into
    # `resolved_kernel_config`, which every GEMM call site consumes.
    wgrad_precision: Optional[str] = None
    remat: bool = True
    attn_chunk: int = 512
    scan_layers: bool = True
    moe_dispatch: str = "ragged"       # "ragged" (paper) | "dense" (GShard)
    seq_shard: bool = False            # Megatron-SP: residual stream
                                       # seq-sharded over `model` (§Perf I2)
    moe_reduce_bf16: bool = False      # bf16 MoE psum (§Perf I3)
    attn_backend: str = "chunked"      # "chunked" (XLA) | "flash"
                                       # (fused Pallas kernel; TPU, or
                                       # interpret mode for tests)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def resolved_kernel_config(self) -> Optional[KernelConfig]:
        """``kernel_config`` with the preset's ``wgrad_precision`` folded
        in (stays ``None`` when neither field is set, preserving the
        installed-default resolution path).  The fold goes through
        ``plan.resolve_config`` so, with no explicit ``kernel_config``,
        the recipe lands on top of the installed/per-device default tile
        shapes instead of discarding them."""
        if self.wgrad_precision is None:
            return self.kernel_config
        return resolve_config(self.kernel_config,
                              wgrad_precision=self.wgrad_precision)

    def param_count(self) -> int:
        """Approximate parameter count (for 6ND roofline math)."""
        d, l = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        attn = d * hd * self.num_heads + 2 * d * hd * self.num_kv_heads \
            + hd * self.num_heads * d
        if self.moe is not None:
            ff_moe = 3 * d * self.moe.d_ff_expert * (
                self.moe.num_experts + self.moe.num_shared_experts)
            ff_dense = 3 * d * self.d_ff if self.d_ff else 3 * d * self.moe.d_ff_expert
            n_moe = l - self.moe.first_dense_layers
            ff = n_moe * ff_moe + self.moe.first_dense_layers * ff_dense
            blocks = l * attn + ff
        else:
            per = attn + (3 * d * self.d_ff if self.d_ff else 0)
            if self.family == "ssm":
                per = self._xlstm_block_params()
                blocks = l * per
            elif self.family == "hybrid":
                blocks = self._hybrid_block_params()
            else:
                blocks = l * per
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return blocks + emb

    def _xlstm_block_params(self) -> int:
        d = self.d_model
        return 8 * d * d  # qkv+gates+out projections (approx)

    def _hybrid_block_params(self) -> int:
        d, l = self.d_model, self.num_layers
        w = self.lru_width or d
        rec = 2 * d * w + w * d + 3 * w  # in/out proj + gates
        hd = self.resolved_head_dim
        attn = d * hd * self.num_heads + 2 * d * hd * self.num_kv_heads \
            + hd * self.num_heads * d
        ff = 3 * d * self.d_ff
        n_attn = sum(1 for i in range(l)
                     if self.block_pattern[i % len(self.block_pattern)] == "attn")
        return n_attn * attn + (l - n_attn) * rec + l * ff

    def active_param_count(self) -> int:
        """Active params per token (MoE counts only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        d, l = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) \
            + hd * self.num_heads * d
        ff_active = 3 * d * self.moe.d_ff_expert * (
            self.moe.top_k + self.moe.num_shared_experts)
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return l * (attn + ff_active) + emb


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"
    grad_accum: int = 1        # microbatch count for train shapes


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# architectures whose attention is strictly O(S^2) full attention — the
# long_500k cell is skipped for these (DESIGN.md §5)
FULL_ATTENTION_ARCHS = frozenset({
    "yi-9b", "minitron-8b", "qwen3-1.7b", "qwen1.5-110b", "whisper-tiny",
    "qwen2-moe-a2.7b", "deepseek-moe-16b", "pixtral-12b",
})


def cell_is_runnable(arch: str, shape: str) -> bool:
    if shape == "long_500k" and arch in FULL_ATTENTION_ARCHS:
        return False
    return True
