"""deepseek-moe-16b — fine-grained 64 routed (top-6) + 2 shared experts,
first layer dense [arXiv:2401.06066].

The paper's sweet spot: many small ragged groups per grouped GEMM.
64 experts divide the 16-way model axis -> full expert parallelism.
"""
import dataclasses

from repro.configs.base import ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=10944,  # layer-0 dense FFN width (deepseek-moe-16b)
    vocab_size=102400, head_dim=128, rope_theta=1e4,
    moe=MoESpec(num_experts=64, top_k=6, d_ff_expert=1408,
                num_shared_experts=2, norm_topk_prob=False,
                first_dense_layers=1),
)

RUN_HINTS = {"train_microbatch": 32, "prefill_microbatch": 16}


def smoke_config():
    return dataclasses.replace(
        CONFIG, num_layers=3, d_model=256, num_heads=4, num_kv_heads=4,
        head_dim=64, d_ff=512, vocab_size=512, attn_chunk=64,
        moe=MoESpec(num_experts=8, top_k=2, d_ff_expert=128,
                    num_shared_experts=1, norm_topk_prob=False,
                    first_dense_layers=1))
