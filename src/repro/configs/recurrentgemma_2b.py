"""recurrentgemma-2b — RG-LRU + local attention hybrid, 1 attn : 2 recurrent
[arXiv:2402.19427].

Sub-quadratic (RG-LRU state + 2048-token sliding-window attention) -> runs
the long_500k cell.  MQA (kv=1) for the attention blocks.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    d_ff=7680, vocab_size=256000, head_dim=256,
    block_pattern=("rglru", "rglru", "attn"),
    window=2048, lru_width=2560, conv_width=4,
)

RUN_HINTS = {"train_microbatch": 32, "prefill_microbatch": 16}


def smoke_config():
    return dataclasses.replace(
        CONFIG, num_layers=3, d_model=128, num_heads=2, num_kv_heads=1,
        head_dim=64, d_ff=256, vocab_size=512, window=32, lru_width=128,
        attn_chunk=64)
