"""xlstm-350m — sLSTM + mLSTM recurrent LM [arXiv:2405.04517].

Block pattern: 5 mLSTM : 1 sLSTM cycles (the xLSTM paper's sparse-sLSTM
placement), 24 layers = 4 cycles.  No separate FFN (d_ff=0): the up/down
projections live inside the xLSTM blocks, as in the paper.
Sub-quadratic: runs the long_500k cell with O(1) recurrent state.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=256,
    block_pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm"),
)

RUN_HINTS = {"train_microbatch": 32, "prefill_microbatch": 16,
             "mlstm_chunk": 256}


def smoke_config():
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=128, num_heads=2, num_kv_heads=2,
        head_dim=64, vocab_size=512,
        block_pattern=("mlstm", "slstm"))
