"""whisper-tiny — encoder-decoder audio backbone [arXiv:2212.04356].

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings [B, encoder_seq, d_model]; this module
implements the transformer backbone (4L encoder + 4L decoder, cross-attn).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865, head_dim=64,
    encoder_layers=4, encoder_seq=1500, cross_attention=True,
    rope_theta=1e4,  # backbone uses RoPE in this framework (stub frontend)
)

RUN_HINTS = {"train_microbatch": 64, "prefill_microbatch": 32}


def smoke_config():
    return dataclasses.replace(
        CONFIG, num_layers=2, encoder_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        encoder_seq=64, attn_chunk=64)
