"""minitron-8b — width-pruned nemotron dense LM [arXiv:2407.14679]."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=16384, vocab_size=256000, head_dim=128, rope_theta=5e5,
)

RUN_HINTS = {"train_microbatch": 16, "prefill_microbatch": 8}


def smoke_config():
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512, attn_chunk=64)
