"""qwen1.5-110b — large dense LM with QKV bias [hf:Qwen/Qwen1.5-110B]."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=49152, vocab_size=152064, head_dim=128, rope_theta=1e6,
    qkv_bias=True,
    attn_chunk=1024,   # §Perf I6: halves online-softmax rescale steps
)

# biggest model: 1 sample per data shard per microbatch
RUN_HINTS = {"train_microbatch": 16, "prefill_microbatch": 16}


def smoke_config():
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512, attn_chunk=64)
