"""qwen3-1.7b — dense LM with qk-norm and GQA [hf:Qwen/Qwen3-1.7B]."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=6144, vocab_size=151936, head_dim=128, rope_theta=1e6,
    qk_norm=True, tie_embeddings=True,
)

RUN_HINTS = {"train_microbatch": 32, "prefill_microbatch": 16}


def smoke_config():
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512, attn_chunk=64)
