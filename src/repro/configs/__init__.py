"""Architecture registry — one module per assigned architecture.

``get_config(name)`` returns the full (paper-exact) ModelConfig;
``smoke_config(name)`` returns a reduced same-family config for CPU tests.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (ModelConfig, MoESpec, ShapeConfig, SHAPES,
                                cell_is_runnable, FULL_ATTENTION_ARCHS)

ARCHS = (
    "yi-9b",
    "minitron-8b",
    "qwen3-1.7b",
    "qwen1.5-110b",
    "whisper-tiny",
    "xlstm-350m",
    "qwen2-moe-a2.7b",
    "deepseek-moe-16b",
    "pixtral-12b",
    "recurrentgemma-2b",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCHS}


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCHS}")
    return importlib.import_module(_MODULES[name])


def get_config(name: str) -> ModelConfig:
    return _mod(name).CONFIG


def smoke_config(name: str) -> ModelConfig:
    return _mod(name).smoke_config()


def run_hints(name: str) -> dict:
    """Per-arch launcher hints (microbatching etc.)."""
    m = _mod(name)
    return getattr(m, "RUN_HINTS", {})


def list_archs():
    return ARCHS
