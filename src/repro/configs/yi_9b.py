"""yi-9b — llama-arch dense LM with GQA [arXiv:2403.04652]."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b", family="dense",
    num_layers=48, d_model=4096, num_heads=32, num_kv_heads=4,
    d_ff=11008, vocab_size=64000, head_dim=128, rope_theta=5e6,
)

# train_4k: 256 global batch -> 16 microbatches of 16 (1 per data shard)
RUN_HINTS = {"train_microbatch": 16, "prefill_microbatch": 8}


def smoke_config():
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512, attn_chunk=64)
