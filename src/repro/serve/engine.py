"""Batched serving engine: prefill + greedy/temperature decode loop with a
static KV-cache capacity (continuous-batching-lite: per-sequence stop with
a done mask; finished rows keep decoding into padding, standard for
static-shape TPU serving).

Plan-aware decode: a decode step's MoE grouped GEMM sees *tiny, constant*
M (batch x top_k routed rows in total), where the training-shaped 128-row
tiles waste almost every fetched A row.  The engine therefore resolves a
decode-specialized :class:`~repro.kernels.plan.KernelConfig` (the
``block_m<=16`` pool entries, ``op="decode"`` in the autotuner) ONCE at
construction and rebuilds the decode-phase model closures over it —
prefill keeps the caller's (or default) config, so the two phases pin
separate tuned tile geometries while sharing one param tree.  Inside the
jitted decode loop the TilePlan schedule is then traced once and replayed
every step — one plan build per phase, the serving analogue of the
paper's configure-once/select-cheaply descriptor pool.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels import plan as plan_mod
from repro.kernels.plan import KernelConfig
from repro.models import model_zoo
from repro.models.model_zoo import Model


@dataclasses.dataclass
class GenerationResult:
    tokens: jax.Array          # [B, max_new]
    num_generated: jax.Array   # [B]


class Engine:
    """``kernel_config`` pins the *prefill* phase's tile shapes (and is
    inherited as the base of the decode selection); ``decode_kernel_config``
    pins the decode phase explicitly, skipping the pool selection.
    ``decode_batch_size`` is the M-bucket hint for that selection — the
    engine stays correct for any actual batch (plans are traced per
    shape), the hint only steers which pool entry is pinned."""

    def __init__(self, model: Model, params, *, max_new_tokens: int = 32,
                 eos_id: int = -1, temperature: float = 0.0,
                 kernel_config: Optional[KernelConfig] = None,
                 decode_kernel_config: Optional[KernelConfig] = None,
                 decode_batch_size: int = 8):
        if kernel_config is not None:
            # pin tuned tile shapes for every GEMM the prefill traces by
            # rebuilding the model closures over a config carrying them
            model = model_zoo.with_kernel_config(model, kernel_config)
        self.model = model
        self.prefill_config = model.cfg.resolved_kernel_config
        # decode-specialized plan: resolved exactly ONCE per engine
        self.decode_config = (decode_kernel_config
                              if decode_kernel_config is not None
                              else self._select_decode_config(
                                  model.cfg, decode_batch_size))
        self._decode_model = (
            model_zoo.with_kernel_config(model, self.decode_config)
            if self.decode_config is not None else model)
        self.params = params
        self.max_new = max_new_tokens
        self.eos_id = eos_id
        self.temperature = temperature
        self._prefill = jax.jit(
            functools.partial(self._prefill_impl),
            static_argnames=("cache_capacity",))
        self._decode_loop = jax.jit(self._decode_loop_impl)

    @staticmethod
    def _select_decode_config(cfg, batch_hint: int) -> Optional[KernelConfig]:
        """One-time decode pool selection (cost-model ranked, cached
        beside the measured autotune entries).  ``None`` when the model
        has no grouped GEMM to specialize (non-MoE families) or the
        decode pool has no legal entry for its dims."""
        if cfg.moe is None:
            return None
        m = max(batch_hint, 1) * cfg.moe.top_k
        k, n, g = cfg.d_model, cfg.moe.d_ff_expert, cfg.moe.num_experts
        try:
            sel = plan_mod.decode_config(m, k, n, g,
                                         backend=cfg.gemm_backend)
        except (ValueError, dispatch.BackendUnavailableError):
            return None
        base = cfg.resolved_kernel_config
        if base is not None:
            # keep the run config's backend/out_dtype/wgrad choices; only
            # the tile geometry is decode-specialized
            sel = base.with_(block_m=sel.block_m, block_n=sel.block_n,
                             block_k=sel.block_k)
        return sel

    def _prefill_impl(self, params, batch, cache_capacity):
        logits, cache = self.model.prefill(params, batch,
                                           cache_capacity=cache_capacity)
        return logits[:, -1], cache

    def _sample(self, logits, key):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / self.temperature
        ).astype(jnp.int32)

    def _decode_loop_impl(self, params, first_token, cache, key):
        def step(carry, _):
            tok, cache, done, key = carry
            key, sub = jax.random.split(key)
            logits, cache = self._decode_model.decode_step(
                params, tok[:, None], cache)
            nxt = self._sample(logits[:, 0], sub)
            nxt = jnp.where(done, 0, nxt)
            done = done | (nxt == self.eos_id)
            return (nxt, cache, done, key), nxt

        b = first_token.shape[0]
        done0 = jnp.zeros((b,), bool)
        (_, cache, done, _), toks = jax.lax.scan(
            step, (first_token, cache, done0, key), None,
            length=self.max_new - 1)
        return toks.swapaxes(0, 1), done  # [B, max_new-1]

    def generate(self, batch, *, key=None) -> GenerationResult:
        key = key if key is not None else jax.random.PRNGKey(0)
        prompt_len = batch["tokens"].shape[1]
        extra = (self.model.cfg.num_patches
                 if self.model.cfg.family == "vlm" else 0)
        cap = prompt_len + extra + self.max_new
        last_logits, cache = self._prefill(self.params, batch,
                                           cache_capacity=cap)
        key, sub = jax.random.split(key)
        first = self._sample(last_logits, sub)
        rest, done = self._decode_loop(self.params, first, cache, key)
        tokens = jnp.concatenate([first[:, None], rest], axis=1)
        num = jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)
        return GenerationResult(tokens=tokens, num_generated=num)


# ---------------------------------------------------------------------------
# Kernel contracts (repro.analysis layer 1)
# ---------------------------------------------------------------------------
# Decode plan discipline, checked by a REAL smoke generate (mode="run" —
# jit with concrete args executes; same cost as the serving CI gate this
# replaced): one decode-config pool selection per Engine, block_m<=16,
# and exactly one plan build per phase per expert group (routed + shared
# x prefill + decode = 4), with the decode-phase build using the decode
# config's tile height.

from repro.analysis.contracts import register_contract as _register_contract


def _build_engine_contract():
    import os
    import tempfile

    from repro.configs import smoke_config
    from repro.models.model_zoo import make_model, synthetic_batch

    cfg = dataclasses.replace(smoke_config("qwen2-moe-a2.7b"),
                              precision="fp8",
                              gemm_backend="pallas_interpret")
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = synthetic_batch(jax.random.PRNGKey(1), cfg, 16, 2)

    def fn():
        # the decode selection autotunes through the JSON plan cache —
        # route the write to a throwaway path, never the user's cache
        prev = os.environ.get("REPRO_TILEPLAN_CACHE")
        os.environ["REPRO_TILEPLAN_CACHE"] = os.path.join(
            tempfile.mkdtemp(), "tileplan_cache.json")
        try:
            engine = Engine(model, params, max_new_tokens=6,
                            decode_batch_size=2)
        finally:
            if prev is None:
                os.environ.pop("REPRO_TILEPLAN_CACHE", None)
            else:
                os.environ["REPRO_TILEPLAN_CACHE"] = prev
        res = engine.generate(batch, key=jax.random.PRNGKey(42))
        return engine, res
    return fn, ()


def _check_engine_contract(result, events):
    engine, res = result
    msgs = []
    dc = engine.decode_config
    if dc is None:
        msgs.append("engine resolved no decode config for an MoE model")
    elif dc.block_m > 16:
        msgs.append(f"decode config block_m={dc.block_m} > 16 — not a "
                    f"decode-pool entry")
    if tuple(res.tokens.shape) != (2, 6):
        msgs.append(f"generate returned tokens of shape "
                    f"{tuple(res.tokens.shape)}, expected (2, 6)")
    builds = [e for e in events if e.kind == "plan_build"]
    # build order: prefill routed, prefill shared, decode routed, decode
    # shared — the decode-phase builds must use the decode tile height
    if dc is not None and len(builds) == 4 \
            and builds[2].data["block_m"] != dc.block_m:
        msgs.append(f"decode-phase plan build used "
                    f"block_m={builds[2].data['block_m']}, not the "
                    f"decode config's {dc.block_m}")
    return msgs


_register_contract(
    "engine.generate.decode_plan",
    description="one decode-config selection per Engine; a full generate "
                "(prefill + >=4 decode steps) builds plan metadata once "
                "per phase per expert group; decode tiles block_m<=16",
    build=_build_engine_contract,
    mode="run",
    decode_selects=1, plan_builds=4,
    extra=_check_engine_contract)


# ---------------------------------------------------------------------------
# Compile contracts (repro.analysis layer 5: REPRO-T02)
# ---------------------------------------------------------------------------
# Engine.generate compiles exactly once per phase: the first generate
# traces the prefill step and the decode loop once each, and a second
# generate over a same-shaped batch hits both jit caches.  The Engine is
# constructed inside the contract's trace window (it jits in __init__),
# so its entry points are the observed ones.

from repro.analysis.retrace import \
    register_compile_contract as _register_compile_contract


def _build_engine_retrace():
    import os
    import tempfile

    from repro.configs import smoke_config
    from repro.models.model_zoo import make_model, synthetic_batch

    cfg = dataclasses.replace(smoke_config("qwen2-moe-a2.7b"),
                              precision="fp8",
                              gemm_backend="pallas_interpret")
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = synthetic_batch(jax.random.PRNGKey(1), cfg, 16, 2)

    prev = os.environ.get("REPRO_TILEPLAN_CACHE")
    os.environ["REPRO_TILEPLAN_CACHE"] = os.path.join(
        tempfile.mkdtemp(), "tileplan_cache.json")
    try:
        engine = Engine(model, params, max_new_tokens=6,
                        decode_batch_size=2)
    finally:
        if prev is None:
            os.environ.pop("REPRO_TILEPLAN_CACHE", None)
        else:
            os.environ["REPRO_TILEPLAN_CACHE"] = prev

    def generate(key):
        return engine.generate(batch, key=key)
    calls = [(jax.random.PRNGKey(42),), (jax.random.PRNGKey(43),)]
    return generate, calls


_register_compile_contract(
    "engine.generate.retrace",
    description="two same-shape generates compile the prefill step and "
                "the decode loop exactly once each",
    build=_build_engine_retrace,
    expected={"_prefill_impl": 1, "_decode_loop_impl": 1},
    rule="REPRO-T02")
