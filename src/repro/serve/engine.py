"""Batched serving engine: prefill + greedy/temperature decode loop with a
static KV-cache capacity (continuous-batching-lite: per-sequence stop with
a done mask; finished rows keep decoding into padding, standard for
static-shape TPU serving)."""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.plan import KernelConfig
from repro.models.model_zoo import Model


@dataclasses.dataclass
class GenerationResult:
    tokens: jax.Array          # [B, max_new]
    num_generated: jax.Array   # [B]


class Engine:
    def __init__(self, model: Model, params, *, max_new_tokens: int = 32,
                 eos_id: int = -1, temperature: float = 0.0,
                 kernel_config: Optional[KernelConfig] = None):
        if kernel_config is not None:
            # pin tuned tile shapes for every GEMM this engine traces
            # (prefill + decode) by rebuilding the model closures over a
            # config carrying the KernelConfig
            from repro.models.model_zoo import make_model
            model = make_model(dataclasses.replace(
                model.cfg, kernel_config=kernel_config))
        self.model = model
        self.params = params
        self.max_new = max_new_tokens
        self.eos_id = eos_id
        self.temperature = temperature
        self._prefill = jax.jit(
            functools.partial(self._prefill_impl),
            static_argnames=("cache_capacity",))
        self._decode_loop = jax.jit(self._decode_loop_impl)

    def _prefill_impl(self, params, batch, cache_capacity):
        logits, cache = self.model.prefill(params, batch,
                                           cache_capacity=cache_capacity)
        return logits[:, -1], cache

    def _sample(self, logits, key):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / self.temperature
        ).astype(jnp.int32)

    def _decode_loop_impl(self, params, first_token, cache, key):
        def step(carry, _):
            tok, cache, done, key = carry
            key, sub = jax.random.split(key)
            logits, cache = self.model.decode_step(params, tok[:, None],
                                                   cache)
            nxt = self._sample(logits[:, 0], sub)
            nxt = jnp.where(done, 0, nxt)
            done = done | (nxt == self.eos_id)
            return (nxt, cache, done, key), nxt

        b = first_token.shape[0]
        done0 = jnp.zeros((b,), bool)
        (_, cache, done, _), toks = jax.lax.scan(
            step, (first_token, cache, done0, key), None,
            length=self.max_new - 1)
        return toks.swapaxes(0, 1), done  # [B, max_new-1]

    def generate(self, batch, *, key=None) -> GenerationResult:
        key = key if key is not None else jax.random.PRNGKey(0)
        prompt_len = batch["tokens"].shape[1]
        extra = (self.model.cfg.num_patches
                 if self.model.cfg.family == "vlm" else 0)
        cap = prompt_len + extra + self.max_new
        last_logits, cache = self._prefill(self.params, batch,
                                           cache_capacity=cap)
        key, sub = jax.random.split(key)
        first = self._sample(last_logits, sub)
        rest, done = self._decode_loop(self.params, first, cache, key)
        tokens = jnp.concatenate([first[:, None], rest], axis=1)
        num = jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)
        return GenerationResult(tokens=tokens, num_generated=num)
