"""JAX version-adaptation layer.

The repo targets a range of JAX releases whose APIs drifted in three places
the kernels and models care about:

  * ``shard_map``      — ``jax.experimental.shard_map.shard_map`` (<= 0.4.x,
                         ``check_rep=`` kwarg) vs ``jax.shard_map``
                         (>= 0.5, ``check_vma=`` kwarg).
  * Pallas TPU params  — ``pltpu.TPUCompilerParams`` (<= 0.4.x) vs
                         ``pltpu.CompilerParams`` (newer releases).
  * ragged contraction — ``jax.lax.ragged_dot_general`` +
                         ``RaggedDotDimensionNumbers`` (newer releases) vs
                         plain ``jax.lax.ragged_dot`` only (0.4.x).

Everything version-dependent is resolved HERE, once, at import time; the
rest of the codebase imports the resolved name and never touches
``jax.experimental`` feature detection again.  Capability *probes*
(``has_tpu()``, ``has_ragged_dot_general()``, ...) are plain functions so
tests can monkeypatch them to exercise every dispatch branch on any box.
"""
from __future__ import annotations

import inspect
from typing import Any

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Capability probes (monkeypatchable; keep them trivial)
# ---------------------------------------------------------------------------

def has_tpu() -> bool:
    """True iff the default JAX backend is a real TPU (compiled Pallas)."""
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


def has_ragged_dot() -> bool:
    return hasattr(jax.lax, "ragged_dot")


def has_ragged_dot_general() -> bool:
    return (hasattr(jax.lax, "ragged_dot_general")
            and hasattr(jax.lax, "RaggedDotDimensionNumbers"))


def has_shard_map_in_jax() -> bool:
    """``jax.shard_map`` was promoted out of ``jax.experimental`` in 0.5."""
    return hasattr(jax, "shard_map")


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

def _resolve_shard_map():
    if has_shard_map_in_jax():
        return jax.shard_map
    from jax.experimental.shard_map import shard_map as sm
    return sm


_shard_map_impl = _resolve_shard_map()
_shard_map_kwargs = frozenset(
    inspect.signature(_shard_map_impl).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None,
              **kwargs):
    """Version-stable ``shard_map``.

    Callers use the modern spelling (``check_vma=``); on JAX 0.4.x the flag
    is forwarded as ``check_rep`` (same meaning: verify that outputs marked
    replicated really are).
    """
    if check_vma is not None:
        if "check_vma" in _shard_map_kwargs:
            kwargs["check_vma"] = check_vma
        else:
            kwargs["check_rep"] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)


# ---------------------------------------------------------------------------
# Pallas TPU compiler params
# ---------------------------------------------------------------------------

def _resolve_tpu_compiler_params():
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls


# The resolved class: construct with the same kwargs on every JAX release
# (e.g. ``TPUCompilerParams(dimension_semantics=(...,))``).
TPUCompilerParams = _resolve_tpu_compiler_params()


def tpu_compiler_params(**kwargs) -> Any:
    return TPUCompilerParams(**kwargs)


# ---------------------------------------------------------------------------
# Compiled-artifact introspection
# ---------------------------------------------------------------------------

def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a one-element *list* of dicts
    on JAX 0.4.x and a plain dict on newer releases; normalize to a dict
    (empty when XLA provides nothing)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


# ---------------------------------------------------------------------------
# Ragged contractions
# ---------------------------------------------------------------------------

def ragged_dot(lhs, rhs, group_sizes, *, preferred_element_type=None):
    """``y[i, n] = sum_k lhs[i, k] * rhs[group_of(i), k, n]`` over the
    concatenated ragged row buffer.  Passthrough on every supported JAX;
    a dense gather fallback (memory-heavy, correctness-only) covers
    hypothetical builds without ``jax.lax.ragged_dot``.
    """
    gs = group_sizes.astype(jnp.int32)
    if has_ragged_dot():
        return jax.lax.ragged_dot(
            lhs, rhs, gs, preferred_element_type=preferred_element_type)
    m = lhs.shape[0]
    g = rhs.shape[0]
    seg = jnp.repeat(jnp.arange(g, dtype=jnp.int32), gs,
                     total_repeat_length=m)
    return jnp.einsum("mk,mkn->mn", lhs, rhs[seg],
                      preferred_element_type=preferred_element_type)


def ragged_wgrad(x, dy, group_sizes, *, num_groups: int):
    """Grouped weight gradient ``dw[g] = x_g^T @ dy_g`` (f32 accumulation)
    over the ragged contracting (row) dimension.

    Two equivalent formulations, picked by capability:

      * ``ragged_dot_general`` with ``lhs_ragged_dimensions=[0]`` and the
        rows as contracting dims — the direct spelling (JAX >= 0.5-era).
      * transpose-of-``ragged_dot``: since ``y = ragged_dot(x, w, gs)`` is
        linear in ``w``, its VJP at cotangent ``dy`` IS exactly
        ``dw[g] = x_g^T @ dy_g``.  ``jax.vjp`` pulls that transpose out of
        the existing primitive, so JAX 0.4.x needs nothing beyond
        ``ragged_dot`` itself.

    ``tests/test_compat_dispatch.py`` pins numerical agreement between the
    two formulations (and both against a dense one-hot oracle).
    """
    if has_ragged_dot_general():
        dn = jax.lax.RaggedDotDimensionNumbers(
            dot_dimension_numbers=(((0,), (0,)), ((), ())),
            lhs_ragged_dimensions=[0],
            rhs_group_dimensions=[])
        return jax.lax.ragged_dot_general(
            x, dy, group_sizes.astype(jnp.int32), dn,
            preferred_element_type=jnp.float32)
    return _ragged_wgrad_via_transpose(x, dy, group_sizes,
                                       num_groups=num_groups)


def _ragged_wgrad_via_transpose(x, dy, group_sizes, *, num_groups: int):
    if not has_ragged_dot():
        raise NotImplementedError(
            "ragged_wgrad needs jax.lax.ragged_dot_general or "
            f"jax.lax.ragged_dot; neither exists in jax {jax.__version__}")
    k, n = x.shape[1], dy.shape[1]
    gs = group_sizes.astype(jnp.int32)
    # f32 operands reproduce ragged_dot_general's semantics exactly: the
    # callers pre-round x/dy to bf16, and preferred_element_type=f32 means
    # products/accumulation happen in f32 either way.
    xf = x.astype(jnp.float32)
    w0 = jax.ShapeDtypeStruct((num_groups, k, n), jnp.float32)
    # linear_transpose (not vjp): the map is linear in w, and this skips
    # evaluating a throwaway forward ragged_dot against zero weights
    transpose = jax.linear_transpose(
        lambda w: jax.lax.ragged_dot(
            xf, w, gs, preferred_element_type=jnp.float32), w0)
    (dw,) = transpose(dy.astype(jnp.float32))
    return dw
