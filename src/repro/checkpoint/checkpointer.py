"""Fault-tolerant checkpointing: atomic, versioned, auto-resume.

Layout:  <dir>/step_<n>/{arrays.npz, meta.json}  written to a tmp dir and
``os.rename``d into place (atomic on POSIX), then ``latest`` rewritten.
A crash mid-write leaves at most an orphan tmp dir; ``latest_step`` only
ever sees complete checkpoints.  ``keep_last`` bounds disk usage.

On a real multi-host fleet each host writes its own param shards (the tree
structure is identical); here arrays are gathered (single-process).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, keep_last: int = 3,
         extra_meta: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _flatten(tree)
    arrays = {f"a{i}": np.asarray(jax.device_get(x)) for i, x in
              enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"step": step, "num_leaves": len(leaves),
            "treedef": str(treedef), **(extra_meta or {})}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic publish
    with open(os.path.join(ckpt_dir, ".latest_tmp"), "w") as f:
        f.write(str(step))
    os.rename(os.path.join(ckpt_dir, ".latest_tmp"),
              os.path.join(ckpt_dir, "latest"))

    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            try:
                out.append(int(name.split("_", 1)[1]))
            except ValueError:
                pass
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "latest")
    if os.path.exists(path):
        with open(path) as f:
            s = int(f.read().strip())
        if os.path.isdir(os.path.join(ckpt_dir, f"step_{s}")):
            return s
    steps = all_steps(ckpt_dir)     # fall back to scan (torn 'latest')
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any) -> Tuple[Any, dict]:
    """Restore into the structure (and shardings) of `like`."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(like)
    assert meta["num_leaves"] == len(leaves), "checkpoint/model mismatch"
    new_leaves = []
    for i, ref in enumerate(leaves):
        arr = data[f"a{i}"]
        if hasattr(ref, "sharding"):
            arr = jax.device_put(arr.astype(ref.dtype), ref.sharding)
        new_leaves.append(arr)
    return jax.tree.unflatten(treedef, new_leaves), meta


def restore_latest(ckpt_dir: str, like: Any):
    s = latest_step(ckpt_dir)
    if s is None:
        return None, None, None
    tree, meta = restore(ckpt_dir, s, like)
    return tree, meta, s
