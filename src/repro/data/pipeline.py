"""Deterministic, stateless synthetic token pipeline.

Every batch is a pure function of (seed, step) — restart-safe by
construction: after a crash the loop resumes from the checkpointed step and
regenerates identical batches (no iterator state to persist beyond the step
counter).  Batches are placed with the train step's input sharding so the
host->device transfer is per-shard.

The "dataset" is a mixture of structured sequences (ngram-ish repeats) so
tiny models show a real, decreasing loss rather than ln(V) noise.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    batch_size: int = 8
    seq_len: int = 128
    repeat_period: int = 16      # structure the stream so loss can fall


class SyntheticLM:
    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig,
                 sharding: Optional[Any] = None):
        self.cfg = cfg
        self.mcfg = model_cfg
        self.sharding = sharding

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        v = self.mcfg.vocab_size
        # one fixed cyclic pattern per dataset seed (memorizable: the
        # bigram token->successor map is deterministic), sampled at random
        # phases per row, with 5% token noise
        base_rng = np.random.default_rng(c.seed)
        base = base_rng.permutation(v)[:c.repeat_period]
        reps = int(np.ceil(c.seq_len / c.repeat_period)) + 1
        stream = np.tile(base, reps)
        phase = rng.integers(0, c.repeat_period, c.batch_size)
        tokens = np.stack([stream[p:p + c.seq_len] for p in phase])
        noise_mask = rng.random(tokens.shape) < 0.05
        tokens = np.where(noise_mask,
                          rng.integers(0, v, tokens.shape), tokens)
        batch = {"tokens": tokens.astype(np.int32),
                 "labels": tokens.astype(np.int32)}
        if self.mcfg.family == "audio":
            batch["frames"] = rng.standard_normal(
                (c.batch_size, self.mcfg.encoder_seq, self.mcfg.d_model)
            ).astype(np.float32)
        if self.mcfg.family == "vlm":
            batch["patch_embeds"] = rng.standard_normal(
                (c.batch_size, self.mcfg.num_patches,
                 self.mcfg.patch_embed_dim)).astype(np.float32)
        if self.sharding is not None:
            batch = {k: jax.device_put(val, self.sharding.get(k))
                     if isinstance(self.sharding, dict)
                     else jax.device_put(val, self.sharding)
                     for k, val in batch.items()}
        return batch

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
