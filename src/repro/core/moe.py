"""Padding-free Mixture-of-Experts layer built on the grouped GEMM.

This is the paper's target workload: top-k routing produces *dynamic* group
sizes per expert; the expert FFNs run as one padding-free fp8 grouped GEMM
over the concatenated, ragged token buffer.

Distribution (DESIGN.md §4): the layer runs inside ``shard_map`` over the
``model`` mesh axis with tokens replicated on that axis.

  * **EP mode** (``num_experts % ep_size == 0``): each shard owns
    ``E/ep_size`` experts, packs only the rows routed to its local experts
    into a static *capacity* buffer (ragged inside — the grouped GEMM never
    pads group-to-group), and contributes a partial output; one ``psum``
    over the axis combines routed + shared-expert partials.
  * **TP mode** (fallback, e.g. qwen2-moe's 60 experts on a 16-way axis):
    experts replicated, every weight's ``d_ff`` dim sharded; all rows are
    processed on every shard against its ``d_ff`` slice; same single
    ``psum``.

Routing is computed redundantly on each shard (router weights are tiny);
this avoids a second collective.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.grouped_gemm import (dense_ffn_fp8, dense_linear_fp8,
                                     dense_linear_fp8_fused, grouped_linear,
                                     grouped_linear_ffn, grouped_linear_fused)
from repro.core.quantization import quantize_activation
from repro.kernels import dispatch
from repro.kernels.plan import KernelConfig, make_tile_plan, resolve_config


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_model: int
    d_ff_expert: int
    num_shared_experts: int = 0
    norm_topk_prob: bool = False
    capacity_factor: float = 2.0
    precision: str = "bf16"           # "bf16" | "fp8"
    # grouped-GEMM backend (repro.kernels.dispatch registry name, e.g.
    # "pallas" / "pallas_interpret" / "xla_ragged"; None == "auto")
    backend: Optional[str] = None
    # tile shapes etc. for the expert GEMMs; None -> installed/per-device
    # default (``backend`` above overrides the config's backend field).
    # ``kernel_config.wgrad_precision="fp8"`` opts the expert GEMMs'
    # backward into the all-fp8 wgrad (bf16 stays the default recipe)
    kernel_config: Optional[KernelConfig] = None
    router_dtype: Any = jnp.float32
    # expert-compute dispatch:
    #   "ragged" — padding-free grouped GEMM (the paper; on TPU this is the
    #              Pallas kernel, on other backends jax.lax.ragged_dot —
    #              NOTE: XLA's ragged_dot lowering one-hot-expands the LHS
    #              to [rows, G_local*K], a G_local x flop/memory blow-up)
    #   "dense"  — GShard-style per-expert capacity buckets + batched
    #              einsum (the padding regime the paper eliminates; on the
    #              XLA path it avoids the expansion artifact)
    dispatch: str = "ragged"
    # dtype of the cross-shard expert-output reduction (§Perf I3):
    # bf16 halves psum wire bytes; partial sums are few-term adds
    reduce_dtype: Any = jnp.float32


def ep_size_for(cfg: MoEConfig, model_axis_size: int) -> int:
    """EP when experts divide the axis, else TP-on-d_ff (DESIGN.md §4)."""
    if model_axis_size > 1 and cfg.num_experts % model_axis_size == 0:
        return model_axis_size
    return 1


def init_moe_params(key, cfg: MoEConfig, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.num_experts
    # 7 splits: every param draws from its own subkey — reusing the parent
    # ``key`` for shared_down correlated its init with the subkey stream
    ks = jax.random.split(key, 7)
    scale_in = d ** -0.5
    scale_mid = f ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * scale_in,
        "w_gate": jax.random.normal(ks[1], (e, d, f), dtype) * scale_in,
        "w_up": jax.random.normal(ks[2], (e, d, f), dtype) * scale_in,
        "w_down": jax.random.normal(ks[3], (e, f, d), dtype) * scale_mid,
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        p["shared_gate"] = jax.random.normal(ks[4], (d, fs), dtype) * scale_in
        p["shared_up"] = jax.random.normal(ks[5], (d, fs), dtype) * scale_in
        p["shared_down"] = (jax.random.normal(ks[6], (fs, d), dtype)
                            * fs ** -0.5)
    return p


def _capacity(num_slots: int, ep_size: int, cf: float,
              align: int = 128) -> int:
    """Static EP capacity, rounded up to the active tile height so the
    packed buffer stays an integral number of kernel M-tiles (``align`` =
    ``KernelConfig.block_m``; non-default tile shapes would otherwise
    silently mis-bucket capacity).

    The clamp is the aligned *ceiling* of ``num_slots``, not ``num_slots``
    itself — ``min(num_slots, ...)`` used to return an unaligned capacity
    whenever ``num_slots`` wasn't tile-aligned, breaking this docstring's
    invariant and splitting autotune cache keys across M buckets.  The
    capacity may therefore exceed ``num_slots`` by up to ``align - 1``
    dead rows; the packed buffer's tail rows beyond ``sum(group_sizes)``
    are defined zeros on every kernel path, so the slack is harmless.
    TP mode (``ep_size == 1``) keeps the exact ``num_slots`` buffer: every
    slot is real, nothing is clamped, and the kernel handles ragged M."""
    if ep_size == 1:
        return num_slots
    cap_all = -(-num_slots // align) * align      # aligned ceiling
    c = -(-int(num_slots / ep_size * cf) // align) * align
    return min(cap_all, max(c, align))


def moe_apply(params, x, cfg: MoEConfig, *, ep_rank=0, ep_size: int = 1,
              axis_name: Optional[str] = None):
    """x: [T, d_model] (tokens local to this shard's data slice, replicated
    over the model axis).  Returns (y [T, d_model], aux dict).

    When ``axis_name`` is given the caller is inside shard_map and the
    params carry this shard's slice (experts sliced in EP mode, d_ff sliced
    in TP mode); output is psum'd over the axis.
    """
    t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    e_loc = e // ep_size
    lo = ep_rank * e_loc
    kcfg = resolve_config(cfg.kernel_config, backend=cfg.backend)

    # ---- routing (replicated) ------------------------------------------
    logits = x.astype(cfg.router_dtype) @ params["router"].astype(
        cfg.router_dtype)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, k)                  # [T, k]
    if cfg.norm_topk_prob:
        weights = weights / jnp.sum(weights, -1, keepdims=True)

    # ---- pack rows routed to local experts into the capacity buffer ----
    num_slots = t * k
    cap = _capacity(num_slots, ep_size, cfg.capacity_factor,
                    align=kcfg.block_m)
    flat_ids = ids.reshape(-1)                              # [T*k]
    local_id = flat_ids - lo
    is_local = (local_id >= 0) & (local_id < e_loc)
    sort_key = jnp.where(is_local, local_id, e_loc)         # dead rows last
    order = jnp.argsort(sort_key)                           # stable
    if cap > num_slots:
        # tile-aligned capacity can exceed the slot count by < block_m;
        # replicate the last slot into the padding rows.  The replica may
        # duplicate a REAL token's row — that is safe only because those
        # rows sit beyond sum(group_sizes): every kernel path zero-fills
        # them forward and backward, and the combine's `valid` mask below
        # excludes them — do not weaken either of those invariants
        order = jnp.pad(order, (0, cap - num_slots), mode="edge")
    sel = order[:cap]                                       # packed slots

    gs_full = jnp.bincount(jnp.where(is_local, local_id, e_loc),
                           length=e_loc + 1)[:e_loc]
    # clip group sizes to the capacity prefix (drops bias to high ids)
    starts = jnp.concatenate([jnp.zeros(1, gs_full.dtype),
                              jnp.cumsum(gs_full)[:-1]])
    gs = jnp.clip(jnp.minimum(gs_full, cap - starts), 0)
    total = jnp.sum(gs)

    token_of = sel // k
    xs = jnp.take(x, token_of, axis=0)                      # [cap, d]

    if cfg.dispatch == "dense":
        # GShard-style capacity buckets: [E_loc, cap_e, d] batched einsum.
        # Ceil of the float-scaled per-expert capacity, like _capacity —
        # int() truncation would turn capacity_factor=1.5 into 1x and
        # silently drop tokens the ragged path keeps
        cap_e = max(-(-int(num_slots * cfg.capacity_factor) // e), 1)
        cap_e = (cap_e + 7) // 8 * 8
        ends = jnp.cumsum(gs)
        row = jnp.arange(cap)
        gid = jnp.searchsorted(ends, row, side="right")
        gid = jnp.minimum(gid, e_loc - 1)
        pos = row - jnp.concatenate([jnp.zeros(1, ends.dtype),
                                     ends[:-1]])[gid]
        keep = (row < jnp.sum(gs)) & (pos < cap_e)
        xe = jnp.zeros((e_loc, cap_e, d), x.dtype).at[
            jnp.where(keep, gid, e_loc - 1),
            jnp.where(keep, pos, cap_e - 1)].set(
            jnp.where(keep[:, None], xs, 0), mode="drop")
        ge = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])
        ue = jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
        he = jax.nn.silu(ge) * ue                       # bf16 act (§Perf I5)
        ye = jnp.einsum("ecf,efd->ecd", he, params["w_down"])
        y = jnp.where(keep[:, None],
                      ye[gid, jnp.minimum(pos, cap_e - 1)], 0.0)
    else:
        # ---- padding-free ragged expert FFN (the paper's kernel) -------
        # Plan once per routing decision: the gate/up/down GEMMs (and the
        # backward dgrads inside the custom VJP) all share this routing's
        # group_sizes, so one TilePlan serves all of them — the paper's
        # configure-once/select-cheaply descriptor pool, at the layer
        # level.  The XLA backends don't consume plans; skip the build.
        tile_plan = None
        qx = None
        if cfg.precision == "fp8":
            if dispatch.backend_uses_plan(kcfg.backend):
                tile_plan = make_tile_plan(gs, cap, block_m=kcfg.block_m,
                                           num_groups=e_loc)
            # quantize once per routing decision, like the plan: ONE
            # 1x128 tilewise quantization of the packed buffer serves the
            # gate AND up GEMMs (and, under wgrad_precision="fp8", their
            # backward wgrads via the VJP residual) — previously each
            # GEMM re-quantized the same xs.  Passing the layer config
            # batches the quantizer's grid to THIS phase's tile height
            # (kcfg.block_m — e.g. the engine's decode config shrinks it
            # to the tiny decode buffer); a quantize-specific tuned
            # height would come from autotune(op="quantize") and can be
            # passed here instead — the record's values are tile-height
            # independent either way, only wall time moves.
            qx = quantize_activation(xs, backend=kcfg.backend, config=kcfg)
        if cfg.precision == "fp8" and kcfg.fuse_producer:
            # producer-fused FFN: the gate/up GEMMs emit fp8 + 1x128
            # scales straight from their store phase (grouped_gemm_quant)
            # and the activation dequantizes them on load — g/u never
            # exist in bf16 anywhere, and the whole expert FFN performs
            # exactly ONE standalone quantize (the qx above).  Numerics
            # differ from the unfused recipe by one extra e4m3 rounding
            # of g/u (see grouped_linear_ffn's docstring).
            y = grouped_linear_ffn(xs, params["w_gate"], params["w_up"],
                                   params["w_down"], gs, act="silu_mul",
                                   config=kcfg, plan=tile_plan,
                                   quantized=qx)             # [cap, d]
        else:
            glin = functools.partial(grouped_linear,
                                     precision=cfg.precision,
                                     config=kcfg, plan=tile_plan)
            g = glin(xs, params["w_gate"], gs, quantized=qx)  # [cap, f_loc]
            u = glin(xs, params["w_up"], gs, quantized=qx)
            if cfg.precision == "fp8":
                # fused epilogue: silu(g)*u + 1x128 quantization in one
                # (act_quant, fp8) pass — the bf16 h intermediate never
                # touches HBM and the down GEMM consumes the
                # QuantizedActivation directly (zero standalone quantizes
                # of h, forward and backward)
                y = grouped_linear_fused(g, u, params["w_down"], gs,
                                         act="silu_mul", config=kcfg,
                                         plan=tile_plan)     # [cap, d]
            else:
                h = jax.nn.silu(g) * u                      # bf16 act (I5)
                y = glin(h, params["w_down"], gs)           # [cap, d]

    # ---- combine (rows beyond `total` are defined zeros on the kernel
    # path, but hard-masking stays: it is cheap, explicit, and covers the
    # dense-dispatch branch too) ----------------------------------------
    valid = jnp.arange(cap) < total
    w_flat = jnp.take(weights.reshape(-1), sel)
    contrib = jnp.where(valid[:, None],
                        y.astype(jnp.float32) * w_flat[:, None], 0.0)
    out = jnp.zeros((t, d), jnp.float32).at[token_of].add(
        contrib, mode="drop")

    # ---- shared experts (TP over the axis in both modes) ---------------
    if cfg.num_shared_experts:
        fs = params["shared_gate"].shape[1]
        if cfg.precision == "fp8" and d % 128 == 0 and fs % 128 == 0:
            # BUGFIX: this FFN used to run bf16 ``@`` regardless of
            # cfg.precision — the shared experts now follow the layer's
            # precision through dense_linear_fp8 and finish with the same
            # fused silu·mul->quantize epilogue as the routed experts.
            # Plan-once + quantize-once, like the routed path: ONE G=1
            # TilePlan and ONE quantization of x serve all three GEMMs.
            splan = None
            if dispatch.backend_uses_plan(kcfg.backend):
                splan = make_tile_plan(jnp.array([t], jnp.int32), t,
                                       block_m=kcfg.block_m, num_groups=1)
            qs = quantize_activation(x, backend=kcfg.backend, config=kcfg)
            if kcfg.fuse_producer:
                # producer-fused shared-expert FFN — same seam as the
                # routed experts: gate/up emit fp8 directly, one
                # standalone quantize (qs) for the whole FFN
                out = out + dense_ffn_fp8(
                    x, params["shared_gate"], params["shared_up"],
                    params["shared_down"], act="silu_mul", config=kcfg,
                    out_dtype=jnp.float32, plan=splan, quantized=qs)
            else:
                sg = dense_linear_fp8(x, params["shared_gate"], config=kcfg,
                                      plan=splan, quantized=qs)
                su = dense_linear_fp8(x, params["shared_up"], config=kcfg,
                                      plan=splan, quantized=qs)
                out = out + dense_linear_fp8_fused(
                    sg, su, params["shared_down"], act="silu_mul",
                    config=kcfg, out_dtype=jnp.float32, plan=splan)
        else:
            sg = x @ params["shared_gate"]
            su = x @ params["shared_up"]
            sh = jax.nn.silu(sg) * su                       # bf16 act (I5)
            out = out + (sh @ params["shared_down"]).astype(jnp.float32)

    if axis_name is not None:
        out = jax.lax.psum(out.astype(cfg.reduce_dtype), axis_name) \
            .astype(jnp.float32)

    # ---- aux: load-balance loss + drop stats (replicated math) ---------
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(ids, e,
                                 dtype=jnp.float32).sum(1), axis=0)
    if axis_name is not None and ep_size > 1:
        kept = jax.lax.psum(total, axis_name)   # shards own disjoint experts
    else:
        kept = total                            # TP/local: every slot local
    aux = {
        "load_balance_loss": e * jnp.sum(me * ce) / k,
        "dropped_fraction": 1.0 - kept / num_slots,
    }
    return out.astype(x.dtype), aux


def shard_moe_params(params, cfg: MoEConfig, ep_size: int):
    """PartitionSpec tree for the params under shard_map over `model`."""
    from jax.sharding import PartitionSpec as P
    if ep_size > 1:
        spec = {"router": P(), "w_gate": P("model"), "w_up": P("model"),
                "w_down": P("model")}
    else:
        spec = {"router": P(), "w_gate": P(None, None, "model"),
                "w_up": P(None, None, "model"),
                "w_down": P(None, "model", None)}
    if cfg.num_shared_experts:
        spec.update({"shared_gate": P(None, "model"),
                     "shared_up": P(None, "model"),
                     "shared_down": P("model", None)})
    return spec


# ---------------------------------------------------------------------------
# Kernel contracts (repro.analysis layer 1)
# ---------------------------------------------------------------------------
# The MoE-layer invariants the ci_tier1.sh count gates used to pin with
# monkeypatched counters: quantize-once (4 standalone quantizes per
# fwd+bwd, two of them xs-shaped), producer-fusion (forward = exactly the
# shared xs, gate/up through grouped_gemm_quant), and plan-once (one
# schedule build per routing decision).  cap = _capacity(32*top_k, 1, cf)
# = 64 for this example config (TP mode keeps the exact slot count).

from repro.analysis.contracts import register_contract as _register_contract


def _contract_cfg(fuse_producer=False):
    return MoEConfig(num_experts=4, top_k=2, d_model=128, d_ff_expert=256,
                     precision="fp8", backend="pallas_interpret",
                     kernel_config=KernelConfig(wgrad_precision="fp8",
                                                fuse_producer=fuse_producer))


def _contract_inputs(cfg):
    params = init_moe_params(jax.random.PRNGKey(0), cfg)
    xt = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
    return params, xt


def _build_moe_fwd():
    cfg = _contract_cfg()
    params, xt = _contract_inputs(cfg)
    return (lambda p, x: moe_apply(p, x, cfg)[0]), (params, xt)


def _build_moe_grad():
    cfg = _contract_cfg()
    params, xt = _contract_inputs(cfg)

    def loss(p, x):
        return jnp.mean(moe_apply(p, x, cfg)[0].astype(jnp.float32) ** 2)
    return jax.grad(loss, argnums=(0, 1)), (params, xt)


def _build_moe_fused_fwd():
    cfg = _contract_cfg(fuse_producer=True)
    params, xt = _contract_inputs(cfg)
    return (lambda p, x: moe_apply(p, x, cfg)[0]), (params, xt)


def _build_moe_fused_grad():
    cfg = _contract_cfg(fuse_producer=True)
    params, xt = _contract_inputs(cfg)

    def loss(p, x):
        return jnp.mean(moe_apply(p, x, cfg)[0].astype(jnp.float32) ** 2)
    return jax.grad(loss, argnums=(0, 1)), (params, xt)


_register_contract(
    "moe_apply.fp8.fwd",
    description="MoE forward: ONE standalone quantize of the packed xs "
                "serves the gate AND up GEMMs; one plan build per "
                "routing decision; no padding of the token buffer",
    build=_build_moe_fwd,
    quantize_count=1, quantize_shapes=((64, 128),),
    plan_builds=1, forbid_padding=True)

_register_contract(
    "moe_apply.fp8.grad",
    description="quantize-once over fwd+bwd: exactly {xs, down-dy, dg, "
                "du} — 4 calls, two xs-shaped; h never standalone-"
                "quantized (the fused epilogue owns it)",
    build=_build_moe_grad,
    quantize_count=4,
    quantize_shapes=((64, 128), (64, 128), (64, 256), (64, 256)),
    plan_builds=1, forbid_padding=True)

_register_contract(
    "moe_apply.fused_producer.fwd",
    description="producer-fused forward: the ONLY standalone quantize is "
                "the shared xs; gate/up route through grouped_gemm_quant "
                "(2 dispatches); g/u/h never exist wider than fp8",
    build=_build_moe_fused_fwd,
    quantize_count=1, quantize_shapes=((64, 128),),
    plan_builds=1, gemm_quant_calls=2, forbid_padding=True,
    forbid_wide_shapes=((64, 256),))

_register_contract(
    "moe_apply.fused_producer.grad",
    description="producer-fused fwd+bwd: same 4-quantize floor {xs, "
                "down-dy, dg, du}, gate/up still through "
                "grouped_gemm_quant, one plan build",
    build=_build_moe_fused_grad,
    quantize_count=4,
    quantize_shapes=((64, 128), (64, 128), (64, 256), (64, 256)),
    plan_builds=1, gemm_quant_calls=2, forbid_padding=True)
