"""FP8 quantization with straight-through gradients.

1x128 per-tile activation quant + 128x128 per-block weight quant — the
paper's (= DeepSeek-V3's) scheme.  ``quantize_*_ste`` are the autodiff-safe
entry points used by the training path.

:class:`QuantizedActivation` is the quantize-once record: one
``quantize_tilewise`` of a shared activation buffer, carried alongside the
:class:`~repro.kernels.plan.TilePlan` through ``grouped_linear`` so every
GEMM consuming the same buffer (the MoE gate and up projections, and —
under ``wgrad_precision="fp8"`` — the backward's wgrad via the VJP
residual) amortizes the quantization like the schedule metadata.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.analysis import events as _events
from repro.kernels import ops as kops
from repro.kernels import ref as kref

QUANT_BLOCK = kref.QUANT_BLOCK
FP8_MAX = kref.FP8_MAX


@dataclasses.dataclass(frozen=True)
class QuantizedActivation:
    """1x128-tile fp8 representation of one activation buffer.

    ``q``: [M, K] fp8 e4m3; ``scale``: [M, ceil(K/128)] f32 with
    ``x ≈ q * repeat(scale, 128, axis=1)``.  A registered pytree, so it
    rides through ``jit``/``shard_map`` and custom_vjp arguments next to
    the TilePlan.

    CONTRACT: a record is only valid for the exact buffer it was built
    from — passing it to ``grouped_linear(x, ...)`` with a *different*
    ``x`` produces silently wrong output (the forward consumes ``(q,
    scale)`` wholesale and only uses ``x`` for dtype/VJP bookkeeping).
    Build it with :func:`quantize_activation` at the point the buffer is
    produced, never cache it across routing decisions.
    """
    q: jax.Array       # [M, K] fp8 e4m3
    scale: jax.Array   # [M, ceil(K/128)] f32


jax.tree_util.register_pytree_node(
    QuantizedActivation,
    lambda qa: ((qa.q, qa.scale), None),
    lambda _, children: QuantizedActivation(*children))


def quantize_activation(x, *, backend=None, config=None) -> QuantizedActivation:
    """ONE ``quantize_tilewise`` call producing the shareable record.

    The input is ``stop_gradient``-ed: gradients flow to the activation
    through ``grouped_linear``'s custom VJP (which returns a zero
    cotangent for the record itself), not through the quantization graph.
    ``config`` (optional) routes an autotuned quantizer tile height
    (``op="quantize"``) into the kernel; the record is tile-height
    independent either way.
    """
    q8, s = quantize_tilewise(
        jax.lax.stop_gradient(x).astype(jnp.float32), backend=backend,
        config=config)
    return QuantizedActivation(q8, s)


def fused_act_quantize(g, u=None, *, act="silu_mul", backend=None,
                       config=None) -> QuantizedActivation:
    """Fused producer: activation + ONE tilewise quantization, no bf16
    intermediate.

    Routes ``silu(g)*u`` (or unary ``gelu(g)``) through the
    ``(act_quant, fp8)`` operator and wraps the result as a
    :class:`QuantizedActivation` — the same record
    :func:`quantize_activation` builds, minus the HBM round-trip of the
    activation buffer.  Inputs are ``stop_gradient``-ed: gradients reach
    ``g``/``u`` through the fused ``grouped_linear`` VJP's activation
    recompute, not through the quantization graph.  ``config`` routes an
    autotuned tile height (``op="act_quant"``); the record is
    tile-height independent.
    """
    gq = jax.lax.stop_gradient(g).astype(jnp.float32)
    uq = None if u is None else jax.lax.stop_gradient(u).astype(jnp.float32)
    q8, s = kops.act_quantize(gq, uq, act=act, backend=backend,
                              config=config)
    return QuantizedActivation(q8, s)


def fused_act_quantize_fp8(g8, s_g, u8=None, s_u=None, *, act="silu_mul",
                           backend=None, config=None) -> QuantizedActivation:
    """Fused producer epilogue on *fp8* operands.

    The fused-producer GEMM (``grouped_gemm_quant``) emits gate/up as fp8
    payloads + 1x128 scales; this routes them through the ``(act_quant,
    fp8)`` operator's dequant-on-load mode, so the bf16 g/u buffers never
    exist anywhere.  Payloads and scales are already detached (they come
    out of a non-differentiable producer), so no ``stop_gradient`` is
    needed; gradients reach the FFN inputs through the fused VJP's
    activation recompute.
    """
    q8, s = kops.act_quantize(g8, u8, act=act, backend=backend,
                              config=config, s_g=s_g, s_u=s_u)
    return QuantizedActivation(q8, s)


@jax.custom_vjp
def quantize_dequantize_tilewise(x):
    """fake-quant (quant->dequant) with straight-through gradient; used to
    inject fp8 noise into reference paths when validating training."""
    q, s = kref.quantize_tilewise_ref(x)
    return kref.dequantize_tilewise_ref(q, s).astype(x.dtype)


def _qdq_fwd(x):
    return quantize_dequantize_tilewise(x), None


def _qdq_bwd(_, g):
    return (g,)


quantize_dequantize_tilewise.defvjp(_qdq_fwd, _qdq_bwd)


def quantize_tilewise(x, *, backend=None, config=None):
    """[M, K] -> (fp8[M, K], f32[M, K/128]).  Not differentiable — use
    inside custom_vjp boundaries (see core.grouped_gemm).  ``config``
    optionally carries an autotuned quantizer tile height (the output is
    tile-height independent)."""
    # one event per STANDALONE tilewise quantization — the quantize-once
    # contracts (REPRO-C01) count these; fused epilogues (act_quantize,
    # grouped_gemm_quant) quantize in-kernel and do not pass through here
    _events.emit("quantize_tilewise", shape=tuple(x.shape))
    return kops.quantize_tilewise(x, backend=backend, config=config)


def quantize_blockwise(w, *, backend=None):
    """[K, N] -> (fp8[K, N], f32[K/128, N/128])."""
    return kops.quantize_blockwise(w, backend=backend)


def quantize_blockwise_batched(w, *, backend=None):
    """[G, K, N] -> (fp8[G, K, N], f32[G, K/128, N/128]).  Routes through
    the dispatch registry like the unbatched form, so a future quant
    kernel covers both paths."""
    return kops.quantize_blockwise_batched(w, backend=backend)
