"""FP8 quantization with straight-through gradients.

1x128 per-tile activation quant + 128x128 per-block weight quant — the
paper's (= DeepSeek-V3's) scheme.  ``quantize_*_ste`` are the autodiff-safe
entry points used by the training path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref as kref

QUANT_BLOCK = kref.QUANT_BLOCK
FP8_MAX = kref.FP8_MAX


@jax.custom_vjp
def quantize_dequantize_tilewise(x):
    """fake-quant (quant->dequant) with straight-through gradient; used to
    inject fp8 noise into reference paths when validating training."""
    q, s = kref.quantize_tilewise_ref(x)
    return kref.dequantize_tilewise_ref(q, s).astype(x.dtype)


def _qdq_fwd(x):
    return quantize_dequantize_tilewise(x), None


def _qdq_bwd(_, g):
    return (g,)


quantize_dequantize_tilewise.defvjp(_qdq_fwd, _qdq_bwd)


def quantize_tilewise(x, *, backend=None):
    """[M, K] -> (fp8[M, K], f32[M, K/128]).  Not differentiable — use
    inside custom_vjp boundaries (see core.grouped_gemm)."""
    return kops.quantize_tilewise(x, backend=backend)


def quantize_blockwise(w, *, backend=None):
    """[K, N] -> (fp8[K, N], f32[K/128, N/128])."""
    return kops.quantize_blockwise(w, backend=backend)


def quantize_blockwise_batched(w, *, backend=None):
    """[G, K, N] -> (fp8[G, K, N], f32[G, K/128, N/128]).  Routes through
    the dispatch registry like the unbatched form, so a future quant
    kernel covers both paths."""
    return kops.quantize_blockwise_batched(w, backend=backend)
