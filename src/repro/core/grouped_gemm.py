"""Differentiable padding-free FP8 grouped GEMM — the paper's contribution
as a composable JAX module.

``grouped_linear(x, w, group_sizes)`` computes ``y[rows of group g] =
x[rows of g] @ w[g]`` over the *unpadded* concatenated token buffer.

Precision modes
  * ``fp8``  — forward:  x -> 1x128-tile fp8, w -> 128x128-block fp8,
               padding-free grouped GEMM kernel (paper);
               backward: dgrad in fp8 through the same kernel
               (dy quantized 1x128, w^T re-quantized 128x128),
               wgrad in bf16 via ``ragged_dot_general`` over the ragged
               contracting dim.  This mirrors the DeepSeek-V3 recipe the
               paper builds on (wgrad highest precision).
  * ``bf16`` — ragged_dot in bf16 both ways (numerics baseline; also the
               portable GSPMD path the multi-pod dry-run lowers).

The group structure (``group_sizes``) is data-dependent and never padded —
that is the paper's whole point.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels import dispatch
from repro.core import quantization as q


# ---------------------------------------------------------------------------
# bf16 ragged path (portable; GSPMD-partitionable)
# ---------------------------------------------------------------------------

def _ragged_dot(x, w, group_sizes, out_dtype):
    return compat.ragged_dot(
        x, w, group_sizes.astype(jnp.int32),
        preferred_element_type=jnp.float32).astype(out_dtype)


def _ragged_wgrad(x, dy, group_sizes, num_groups):
    """dw[g] = x_g^T @ dy_g — ragged contracting dim.  compat picks
    ``ragged_dot_general`` or the transpose-of-``ragged_dot`` fallback."""
    return compat.ragged_wgrad(x, dy, group_sizes, num_groups=num_groups)


# ---------------------------------------------------------------------------
# fp8 path with custom VJP
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _grouped_linear_fp8(x, w, group_sizes, backend, out_dtype):
    y, _ = _fp8_fwd(x, w, group_sizes, backend, out_dtype)
    return y


def _fp8_fwd(x, w, group_sizes, backend, out_dtype):
    a8, sa = q.quantize_tilewise(x.astype(jnp.float32), backend=backend)
    b8, sb = q.quantize_blockwise_batched(w.astype(jnp.float32))
    y = dispatch.grouped_gemm_fp8(a8, sa, b8, sb, group_sizes,
                                  backend=backend, out_dtype=out_dtype)
    return y, (x, w, group_sizes)


def _fp8_bwd(backend, out_dtype, res, dy):
    x, w, group_sizes = res
    num_groups = w.shape[0]
    # dgrad: dx = dy @ w^T  (fp8 through the padding-free kernel)
    d8, sd = q.quantize_tilewise(dy.astype(jnp.float32), backend=backend)
    wt = jnp.swapaxes(w, 1, 2)                       # [G, N, K]
    bt8, sbt = q.quantize_blockwise_batched(wt.astype(jnp.float32))
    dx = dispatch.grouped_gemm_fp8(d8, sd, bt8, sbt, group_sizes,
                                   backend=backend, out_dtype=jnp.float32)
    # wgrad: bf16 ragged contraction (highest-precision operand, DeepSeek
    # keeps wgrad un-quantized on the K axis)
    dw = _ragged_wgrad(x.astype(jnp.bfloat16), dy.astype(jnp.bfloat16),
                       group_sizes, num_groups)
    return dx.astype(x.dtype), dw.astype(w.dtype), None


_grouped_linear_fp8.defvjp(_fp8_fwd, _fp8_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _grouped_linear_bf16(x, w, group_sizes, out_dtype):
    y, _ = _bf16_fwd(x, w, group_sizes, out_dtype)
    return y


def _bf16_fwd(x, w, group_sizes, out_dtype):
    y = _ragged_dot(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                    group_sizes, out_dtype)
    return y, (x, w, group_sizes)


def _bf16_bwd(out_dtype, res, dy):
    x, w, group_sizes = res
    wt = jnp.swapaxes(w, 1, 2)
    dx = _ragged_dot(dy.astype(jnp.bfloat16), wt.astype(jnp.bfloat16),
                     group_sizes, jnp.float32)
    dw = _ragged_wgrad(x.astype(jnp.bfloat16), dy.astype(jnp.bfloat16),
                       group_sizes, w.shape[0])
    return dx.astype(x.dtype), dw.astype(w.dtype), None


_grouped_linear_bf16.defvjp(_bf16_fwd, _bf16_bwd)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def grouped_linear(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
                   precision: str = "bf16", backend: str | None = None,
                   out_dtype: Any = None) -> jax.Array:
    """Padding-free grouped linear: rows of ``x`` are grouped by
    ``group_sizes`` (concatenated, ragged); group g matmuls ``w[g]``.

    x: [M, K]; w: [G, K, N]; group_sizes: [G] (sum <= M; rows beyond the
    last group are left undefined — callers mask them).
    """
    out_dtype = out_dtype or x.dtype
    if precision == "fp8":
        return _grouped_linear_fp8(x, w, group_sizes, backend, out_dtype)
    if precision == "bf16":
        return _grouped_linear_bf16(x, w, group_sizes, out_dtype)
    raise ValueError(f"unknown precision {precision!r}")


def dense_linear_fp8(x: jax.Array, w: jax.Array, *,
                     backend: str | None = None) -> jax.Array:
    """The G=1 degenerate case — DeepSeek-style fp8 linear for dense layers
    (optional beyond-paper feature for the dense architectures)."""
    m = x.shape[0]
    gs = jnp.array([m], jnp.int32)
    return grouped_linear(x, w[None], gs, precision="fp8",
                          backend=backend)
