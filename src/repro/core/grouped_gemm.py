"""Differentiable padding-free FP8 grouped GEMM — the paper's contribution
as a composable JAX module.

``grouped_linear(x, w, group_sizes)`` computes ``y[rows of group g] =
x[rows of g] @ w[g]`` over the *unpadded* concatenated token buffer.

Precision modes
  * ``fp8``  — forward:  x -> 1x128-tile fp8, w -> 128x128-block fp8,
               padding-free grouped GEMM kernel (paper);
               backward: dgrad in fp8 through the same kernel
               (dy quantized 1x128, w^T re-quantized 128x128),
               wgrad through the *wgrad registry*
               (``dispatch.grouped_gemm_wgrad``): bf16 operands by
               default (the DeepSeek-V3 recipe — wgrad highest
               precision), or fp8 operands with per-visit dequantization
               under ``wgrad_precision="fp8"`` (arXiv 2505.20524's
               all-fp8 step; ``dispatch.grouped_gemm_wgrad_fp8``).  All
               three GEMMs of the step consume ONE :class:`TilePlan`.
  * ``bf16`` — ragged_dot in bf16 both ways (numerics baseline; also the
               portable GSPMD path the multi-pod dry-run lowers); its
               wgrad routes through the same registry.

Quantize-once: a :class:`~repro.core.quantization.QuantizedActivation`
passed as ``quantized=`` replaces the forward's ``quantize_tilewise`` of
``x`` — several GEMMs sharing one activation buffer (the MoE gate/up
pair) amortize ONE quantization, and under ``wgrad_precision="fp8"`` the
VJP saves ``(a8, s_a)`` as residuals so the backward never re-quantizes
``x`` either.  The backward's single ``quantize_tilewise(dy)`` likewise
serves both the dgrad and the fp8 wgrad.

The group structure (``group_sizes``) is data-dependent and never padded —
that is the paper's whole point.
"""
from __future__ import annotations

import functools
import warnings
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels import ref as kref
from repro.kernels.plan import KernelConfig, TilePlan, make_tile_plan, \
    resolve_config
from repro.core import quantization as q


# ---------------------------------------------------------------------------
# bf16 ragged path (portable; GSPMD-partitionable)
# ---------------------------------------------------------------------------

def _ragged_dot(x, w, group_sizes, out_dtype):
    # the (gemm, bf16) operator of the unified registry — the bf16
    # baseline is a first-class registry citizen, not a side channel
    return dispatch.grouped_gemm_bf16(x, w, group_sizes,
                                      out_dtype=out_dtype,
                                      config=KernelConfig())


def _wgrad(x, dy, group_sizes, num_groups, *, config=None, plan=None):
    """dw[g] = x_g^T @ dy_g — ragged contracting dim, bf16 operands / f32
    accumulation, through the wgrad dispatch registry (the padding-free
    kernel where available; ``compat.ragged_wgrad`` is the registry's
    ``xla_ragged`` fallback, no longer the only path)."""
    return dispatch.grouped_gemm_wgrad(
        x.astype(jnp.bfloat16), dy.astype(jnp.bfloat16), group_sizes,
        num_groups=num_groups, config=config, out_dtype=jnp.float32,
        plan=plan)


# ---------------------------------------------------------------------------
# fp8 path with custom VJP
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _grouped_linear_fp8(x, w, group_sizes, plan, qa, config):
    y, _ = _fp8_fwd(x, w, group_sizes, plan, qa, config)
    return y


def _fp8_fwd(x, w, group_sizes, plan, qa, config):
    # quantize-once: a caller-supplied QuantizedActivation (the MoE layer
    # shares one across the gate/up GEMMs) replaces the tilewise quant of x
    if qa is None:
        a8, sa = q.quantize_tilewise(x.astype(jnp.float32),
                                     backend=config.backend, config=config)
    else:
        a8, sa = qa.q, qa.scale
    b8, sb = q.quantize_blockwise_batched(w.astype(jnp.float32),
                                          backend=config.backend)
    # plan-once/run-many: one TilePlan per group_sizes serves this forward
    # GEMM *and* the backward dgrad (the schedule depends only on M-side
    # raggedness, not on which weight it multiplies)
    if plan is None and dispatch.backend_uses_plan(config.backend):
        plan = make_tile_plan(group_sizes, x.shape[0],
                              block_m=config.block_m,
                              num_groups=w.shape[0])
    y = dispatch.grouped_gemm_fp8(a8, sa, b8, sb, group_sizes,
                                  config=config, plan=plan)
    if config.wgrad_precision == "fp8":
        # the residual IS the quantized activation: the backward's fp8
        # wgrad dequantizes per visit instead of re-quantizing x (and the
        # raw x can be freed — only a dtype stub is kept for the dx cast)
        x_raw, x_res = x[:0], (a8, sa)
    else:
        # DeepSeek recipe: wgrad contracts the highest-precision operand
        x_raw, x_res = x, None
    qa_marker = () if qa is not None else None     # structure-only flag
    return y, (x_raw, x_res, w, group_sizes, plan, qa_marker)


def _fp8_bwd(config, res, dy):
    x_raw, x_res, w, group_sizes, plan, qa_marker = res
    num_groups = w.shape[0]
    # dgrad: dx = dy @ w^T  (fp8 through the padding-free kernel, reusing
    # the forward's TilePlan — same group_sizes, same schedule).  This one
    # quantize_tilewise(dy) also feeds the fp8 wgrad below.
    d8, sd = q.quantize_tilewise(dy.astype(jnp.float32),
                                 backend=config.backend, config=config)
    wt = jnp.swapaxes(w, 1, 2)                       # [G, N, K]
    bt8, sbt = q.quantize_blockwise_batched(wt.astype(jnp.float32),
                                            backend=config.backend)
    dx = dispatch.grouped_gemm_fp8(d8, sd, bt8, sbt, group_sizes,
                                   config=config.with_(out_dtype=jnp.float32),
                                   plan=plan)
    # wgrad through the registry, reusing the SAME TilePlan as the forward
    # and the dgrad above — the contraction schedule depends only on the
    # routing decision
    if config.wgrad_precision == "fp8":
        a8, sa = x_res
        dw = dispatch.grouped_gemm_wgrad_fp8(
            a8, sa, d8, sd, group_sizes, num_groups=num_groups,
            config=config, out_dtype=jnp.float32, plan=plan)
    else:
        dw = _wgrad(x_raw, dy, group_sizes, num_groups, config=config,
                    plan=plan)
    # zero cotangent for a supplied QuantizedActivation (its producer is
    # stop_gradient-ed; gradients to the activation flow through dx)
    dqa = None
    if qa_marker is not None:
        m, k = dy.shape[0], w.shape[1]
        kb = (k + q.QUANT_BLOCK - 1) // q.QUANT_BLOCK
        dqa = q.QuantizedActivation(
            jnp.zeros((m, k), jnp.float8_e4m3fn),
            jnp.zeros((m, kb), jnp.float32))
    return dx.astype(x_raw.dtype), dw.astype(w.dtype), None, None, dqa


_grouped_linear_fp8.defvjp(_fp8_fwd, _fp8_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _grouped_linear_bf16(x, w, group_sizes, out_dtype):
    y, _ = _bf16_fwd(x, w, group_sizes, out_dtype)
    return y


def _bf16_fwd(x, w, group_sizes, out_dtype):
    y = _ragged_dot(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                    group_sizes, out_dtype)
    return y, (x, w, group_sizes)


def _bf16_bwd(out_dtype, res, dy):
    x, w, group_sizes = res
    wt = jnp.swapaxes(w, 1, 2)
    dx = _ragged_dot(dy.astype(jnp.bfloat16), wt.astype(jnp.bfloat16),
                     group_sizes, jnp.float32)
    # registry-routed wgrad.  The explicit default config keeps this path
    # auto-resolved (a pinned global backend must not turn the bf16
    # baseline's backward into a hard kernel requirement); arbitrary
    # model dims fall back to the tile-free xla_ragged entry
    dw = _wgrad(x, dy, group_sizes, w.shape[0], config=KernelConfig())
    return dx.astype(x.dtype), dw.astype(w.dtype), None


_grouped_linear_bf16.defvjp(_bf16_fwd, _bf16_bwd)


# ---------------------------------------------------------------------------
# fp8 path with FUSED activation epilogue (gate/up outputs in, no bf16 h)
# ---------------------------------------------------------------------------

def _act_recompute(g, u, act):
    """f32 activation as a VJP-able function of (g, u) — the same
    elementwise definition the fused kernel runs, so the backward's
    recompute matches the forward's quantization input exactly."""
    from repro.kernels.epilogue_kernel import _act_f32
    if u is None:
        return jax.vjp(lambda gg: _act_f32(gg, None, act), g)
    return jax.vjp(lambda gg, uu: _act_f32(gg, uu, act), g, u)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _grouped_linear_fp8_fused(g, u, w, group_sizes, plan, ctx):
    y, _ = _fused_fwd(g, u, w, group_sizes, plan, ctx)
    return y


def _fused_fwd(g, u, w, group_sizes, plan, ctx):
    config, act = ctx
    # ONE fused pass: activation + 1x128 quantization, the bf16
    # intermediate h never exists — the down GEMM consumes the
    # QuantizedActivation straight from the epilogue kernel
    qh = q.fused_act_quantize(g, u, act=act, backend=config.backend,
                              config=config)
    b8, sb = q.quantize_blockwise_batched(w.astype(jnp.float32),
                                          backend=config.backend)
    if plan is None and dispatch.backend_uses_plan(config.backend):
        plan = make_tile_plan(group_sizes, g.shape[0],
                              block_m=config.block_m,
                              num_groups=w.shape[0])
    y = dispatch.grouped_gemm_fp8(qh.q, qh.scale, b8, sb, group_sizes,
                                  config=config, plan=plan)
    # (g, u) are the residuals for dsilu(g)*u / silu(g)*du — under
    # wgrad_precision="fp8" the quantized h additionally rides along so
    # the backward performs ZERO standalone quantizes of h
    h_res = (qh.q, qh.scale) if config.wgrad_precision == "fp8" else None
    return y, (g, u, h_res, w, group_sizes, plan)


def _fused_bwd(ctx, res, dy):
    config, act = ctx
    g, u, h_res, w, group_sizes, plan = res
    num_groups = w.shape[0]
    # one quantize_tilewise(dy) serves the dgrad AND the fp8 wgrad
    d8, sd = q.quantize_tilewise(dy.astype(jnp.float32),
                                 backend=config.backend, config=config)
    wt = jnp.swapaxes(w, 1, 2)                       # [G, N, K]
    bt8, sbt = q.quantize_blockwise_batched(wt.astype(jnp.float32),
                                            backend=config.backend)
    dh = dispatch.grouped_gemm_fp8(d8, sd, bt8, sbt, group_sizes,
                                   config=config.with_(out_dtype=jnp.float32),
                                   plan=plan)
    # dsilu(g)·u / silu(g)·du from residuals: autodiff of the exact f32
    # activation the kernel fused (tail rows of dh are zero, so dg/du
    # keep the defined-zeros tail contract)
    h_f32, act_vjp = _act_recompute(g, u, act)
    if u is None:
        (dg,) = act_vjp(dh)
        du = None
    else:
        dg, du = act_vjp(dh)
    if config.wgrad_precision == "fp8":
        h8, sh = h_res
        dw = dispatch.grouped_gemm_wgrad_fp8(
            h8, sh, d8, sd, group_sizes, num_groups=num_groups,
            config=config, out_dtype=jnp.float32, plan=plan)
    else:
        # DeepSeek recipe: the wgrad contracts the recomputed h (bf16
        # operands, f32 accumulation) — recompute beats materializing
        dw = _wgrad(h_f32, dy, group_sizes, num_groups, config=config,
                    plan=plan)
    return (dg.astype(g.dtype), du if du is None else du.astype(u.dtype),
            dw.astype(w.dtype), None, None)


_grouped_linear_fp8_fused.defvjp(_fused_fwd, _fused_bwd)


# ---------------------------------------------------------------------------
# fp8 FFN with PRODUCER-side quantizing epilogues (gate/up emit fp8 directly)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(7,))
def _grouped_linear_ffn_fp8(x, w_gate, w_up, w_down, group_sizes, plan, qa,
                            ctx):
    y, _ = _ffn_fwd(x, w_gate, w_up, w_down, group_sizes, plan, qa, ctx)
    return y


def _ffn_fwd(x, w_gate, w_up, w_down, group_sizes, plan, qa, ctx):
    config, act = ctx
    # quantize-once: ONE tilewise quant of x feeds the gate AND up GEMMs
    # (and, under wgrad_precision="fp8", both of their wgrads)
    if qa is None:
        a8, sa = q.quantize_tilewise(x.astype(jnp.float32),
                                     backend=config.backend, config=config)
    else:
        a8, sa = qa.q, qa.scale
    num_groups = w_up.shape[0]
    if plan is None and dispatch.backend_uses_plan(config.backend):
        plan = make_tile_plan(group_sizes, x.shape[0],
                              block_m=config.block_m, num_groups=num_groups)
    # producer epilogue: the gate/up GEMMs round through the intermediate
    # dtype in-register and emit fp8 payload + 1x128 scales directly — the
    # bf16 g/u buffers never reach HBM, and the activation kernel
    # dequantizes them on load.  ``out_dtype`` here is the *rounding*
    # dtype, chosen to match what the unfused composition would have
    # stored (x.dtype), so fused-vs-unfused stays bitwise at this seam.
    idt = x.dtype
    bu8, sbu = q.quantize_blockwise_batched(w_up.astype(jnp.float32),
                                            backend=config.backend)
    u8, su = dispatch.grouped_gemm_quant(a8, sa, bu8, sbu, group_sizes,
                                         num_groups=num_groups,
                                         config=config, out_dtype=idt,
                                         plan=plan)
    if w_gate is not None:
        bg8, sbg = q.quantize_blockwise_batched(w_gate.astype(jnp.float32),
                                                backend=config.backend)
        g8, sg = dispatch.grouped_gemm_quant(a8, sa, bg8, sbg, group_sizes,
                                             num_groups=num_groups,
                                             config=config, out_dtype=idt,
                                             plan=plan)
        qh = q.fused_act_quantize_fp8(g8, sg, u8, su, act=act,
                                      backend=config.backend, config=config)
    else:
        # unary activation (gelu): w_up is the single projection
        g8 = sg = None
        qh = q.fused_act_quantize_fp8(u8, su, act=act,
                                      backend=config.backend, config=config)
    bd8, sbd = q.quantize_blockwise_batched(w_down.astype(jnp.float32),
                                            backend=config.backend)
    y = dispatch.grouped_gemm_fp8(qh.q, qh.scale, bd8, sbd, group_sizes,
                                  config=config, plan=plan)
    if config.wgrad_precision == "fp8":
        # all-fp8 step: the quantized x and h ride along as residuals so
        # the backward performs zero re-quantizations of either
        x_raw, x_res = x[:0], (a8, sa)
        h_res = (qh.q, qh.scale)
    else:
        # DeepSeek recipe: raw x kept; h recomputed in f32 for the wgrad
        x_raw, x_res, h_res = x, None, None
    qa_marker = () if qa is not None else None     # structure-only flag
    return y, (x_raw, x_res, g8, sg, u8, su, h_res, w_gate, w_up, w_down,
               group_sizes, plan, qa_marker)


def _ffn_bwd(ctx, res, dy):
    config, act = ctx
    (x_raw, x_res, g8, sg, u8, su, h_res, w_gate, w_up, w_down,
     group_sizes, plan, qa_marker) = res
    num_groups = w_up.shape[0]
    f32cfg = config.with_(out_dtype=jnp.float32)
    # ONE quantize_tilewise(dy) serves the down dgrad AND its fp8 wgrad
    d8, sd = q.quantize_tilewise(dy.astype(jnp.float32),
                                 backend=config.backend, config=config)
    wdt8, sdt = q.quantize_blockwise_batched(
        jnp.swapaxes(w_down, 1, 2).astype(jnp.float32),
        backend=config.backend)
    dh = dispatch.grouped_gemm_fp8(d8, sd, wdt8, sdt, group_sizes,
                                   config=f32cfg, plan=plan)
    # recompute the activation from the fp8 producer residuals — the
    # dequantized payloads ARE the values the fused epilogue ran on, so
    # this recompute sees exactly the forward's activation inputs.  Tail
    # rows stay defined zeros: payload 0 / scale 1 dequantizes to 0.
    u_f32 = kref.dequantize_tilewise_ref(u8, su)
    if w_gate is not None:
        g_f32 = kref.dequantize_tilewise_ref(g8, sg)
        h_f32, act_vjp = _act_recompute(g_f32, u_f32, act)
        dg, du = act_vjp(dh)
    else:
        h_f32, act_vjp = _act_recompute(u_f32, None, act)
        (du,) = act_vjp(dh)
        dg = None
    # quantize dg/du ONCE each: the records serve the gate/up dgrads and,
    # under wgrad_precision="fp8", the matching wgrads.  Total standalone
    # quantize_tilewise calls for fwd+bwd: x, dy, dg, du — never h.
    du8, sdu = q.quantize_tilewise(du, backend=config.backend, config=config)
    wut8, sut = q.quantize_blockwise_batched(
        jnp.swapaxes(w_up, 1, 2).astype(jnp.float32), backend=config.backend)
    dx = dispatch.grouped_gemm_fp8(du8, sdu, wut8, sut, group_sizes,
                                   config=f32cfg, plan=plan)
    if w_gate is not None:
        dg8, sdg = q.quantize_tilewise(dg, backend=config.backend,
                                       config=config)
        wgt8, sgt = q.quantize_blockwise_batched(
            jnp.swapaxes(w_gate, 1, 2).astype(jnp.float32),
            backend=config.backend)
        dx = dx + dispatch.grouped_gemm_fp8(dg8, sdg, wgt8, sgt, group_sizes,
                                            config=f32cfg, plan=plan)
    if config.wgrad_precision == "fp8":
        a8, sa = x_res
        h8, sh = h_res
        dw_down = dispatch.grouped_gemm_wgrad_fp8(
            h8, sh, d8, sd, group_sizes, num_groups=num_groups,
            config=config, out_dtype=jnp.float32, plan=plan)
        dw_up = dispatch.grouped_gemm_wgrad_fp8(
            a8, sa, du8, sdu, group_sizes, num_groups=num_groups,
            config=config, out_dtype=jnp.float32, plan=plan)
        dw_gate = None if w_gate is None else dispatch.grouped_gemm_wgrad_fp8(
            a8, sa, dg8, sdg, group_sizes, num_groups=num_groups,
            config=config, out_dtype=jnp.float32, plan=plan)
    else:
        dw_down = _wgrad(h_f32, dy, group_sizes, num_groups, config=config,
                         plan=plan)
        dw_up = _wgrad(x_raw, du, group_sizes, num_groups, config=config,
                       plan=plan)
        dw_gate = None if w_gate is None else _wgrad(
            x_raw, dg, group_sizes, num_groups, config=config, plan=plan)
    dqa = None
    if qa_marker is not None:
        m, k = dy.shape[0], w_up.shape[1]
        kb = (k + q.QUANT_BLOCK - 1) // q.QUANT_BLOCK
        dqa = q.QuantizedActivation(
            jnp.zeros((m, k), jnp.float8_e4m3fn),
            jnp.zeros((m, kb), jnp.float32))
    return (dx.astype(x_raw.dtype),
            None if w_gate is None else dw_gate.astype(w_gate.dtype),
            dw_up.astype(w_up.dtype), dw_down.astype(w_down.dtype),
            None, None, dqa)


_grouped_linear_ffn_fp8.defvjp(_ffn_fwd, _ffn_bwd)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def grouped_linear(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
                   precision: str = "bf16", backend: str | None = None,
                   out_dtype: Any = None,
                   config: KernelConfig | None = None,
                   plan: TilePlan | None = None,
                   quantized: "q.QuantizedActivation | None" = None,
                   wgrad_precision: str | None = None) -> jax.Array:
    """Padding-free grouped linear: rows of ``x`` are grouped by
    ``group_sizes`` (concatenated, ragged); group g matmuls ``w[g]``.

    x: [M, K]; w: [G, K, N]; group_sizes: [G] with ``sum <= M``.  Rows
    beyond the last group (the unowned tail of a capacity buffer) come
    back as defined zeros on every backend — forward AND backward: the
    kernel's schedule sweeps the tail tiles and zero-fills them, and tail
    rows are excluded from the wgrad contraction.  Downstream gathers /
    scatter-adds (MoE combine, the take-VJP) are therefore safe without
    masking, though masking remains cheap and explicit.

    ``config`` carries tile shapes/backend (:class:`KernelConfig`);
    ``plan`` is an optional precomputed :class:`TilePlan` — pass the same
    plan to every grouped_linear sharing ``group_sizes`` (e.g. the
    gate/up/down GEMMs of one MoE application) so the schedule is built
    once per routing decision.  Without one, the fp8 path still builds a
    single plan per call and reuses it for the backward dgrad and wgrad.

    ``quantized`` (fp8 path only) is the quantize-once analogue of
    ``plan``: a :class:`~repro.core.quantization.QuantizedActivation`
    built from exactly this ``x`` (see
    :func:`~repro.core.quantization.quantize_activation`) replaces the
    forward's ``quantize_tilewise`` — pass the same record to every
    grouped_linear consuming the same activation buffer (the MoE gate/up
    pair).  It must be the quantization OF ``x``; a mismatched record
    gives silently wrong output.

    ``wgrad_precision`` (fp8 path only) picks the backward wgrad's
    operand precision: ``"bf16"`` (default — the DeepSeek recipe keeps
    the wgrad at the highest precision) or ``"fp8"`` (the all-fp8 step of
    arXiv 2505.20524: the VJP saves the quantized activation as its
    residual and the wgrad kernel dequantizes per visit).  Overrides the
    ``config``'s ``wgrad_precision`` field.
    """
    if precision == "fp8":
        # explicit out_dtype > config's pinned out_dtype > x.dtype
        cfg = resolve_config(config, backend=backend, out_dtype=out_dtype,
                             wgrad_precision=wgrad_precision)
        if cfg.out_dtype is None:
            cfg = cfg.with_(out_dtype=x.dtype)
        return _grouped_linear_fp8(x, w, group_sizes, plan, quantized, cfg)
    if precision == "bf16":
        if quantized is not None:
            warnings.warn(
                "grouped_linear(precision='bf16') ignores quantized=...: "
                "the bf16 path never quantizes; use precision='fp8' to "
                "consume a QuantizedActivation", stacklevel=2)
        # the kwarg AND a config-carried field both reach here — dropping
        # the config's wgrad_precision silently would be the same trap
        # the backend= kwarg warning exists for
        eff_wgrad = wgrad_precision if wgrad_precision is not None \
            else resolve_config(config).wgrad_precision
        if eff_wgrad == "fp8":
            warnings.warn(
                "grouped_linear(precision='bf16') ignores "
                "wgrad_precision='fp8': the fp8-operand wgrad needs the "
                "fp8 forward's quantized residual; use precision='fp8'",
                stacklevel=2)
        if backend is not None and backend != "auto":
            # the bf16 forward has exactly one implementation (ragged_dot)
            # — honouring this request is impossible, and dropping it
            # silently made callers think they were benchmarking a kernel
            warnings.warn(
                f"grouped_linear(precision='bf16') ignores "
                f"backend={backend!r}: the bf16 path always runs "
                "jax.lax.ragged_dot (its wgrad auto-resolves through the "
                "dispatch registry); use precision='fp8' to select a "
                "grouped-GEMM backend", stacklevel=2)
        # the bf16 path ignores tile shapes (ragged_dot), but a pinned
        # config out_dtype applies to every consumer, this one included
        cfg = resolve_config(config, out_dtype=out_dtype)
        return _grouped_linear_bf16(x, w, group_sizes,
                                    cfg.out_dtype or x.dtype)
    raise ValueError(f"unknown precision {precision!r}")


def dense_linear_fp8(x: jax.Array, w: jax.Array, *,
                     backend: str | None = None,
                     out_dtype: Any = None,
                     config: KernelConfig | None = None,
                     plan: TilePlan | None = None,
                     quantized: "q.QuantizedActivation | None" = None
                     ) -> jax.Array:
    """The G=1 degenerate case — DeepSeek-style fp8 linear for dense layers
    (optional beyond-paper feature for the dense architectures).

    ``out_dtype`` forwards like :func:`grouped_linear`'s (explicit kwarg >
    the ``config``'s pinned ``out_dtype`` > ``x.dtype``) instead of being
    silently dropped.  ``plan``/``quantized`` forward too, so several
    dense GEMMs sharing one input buffer (the MoE shared-expert gate/up
    pair) amortize one G=1 TilePlan and one quantization."""
    m = x.shape[0]
    gs = jnp.array([m], jnp.int32)
    return grouped_linear(x, w[None], gs, precision="fp8",
                          backend=backend, out_dtype=out_dtype,
                          config=config, plan=plan, quantized=quantized)


def grouped_linear_fused(g: jax.Array, u: jax.Array | None,
                         w: jax.Array, group_sizes: jax.Array, *,
                         act: str = "silu_mul",
                         backend: str | None = None,
                         out_dtype: Any = None,
                         config: KernelConfig | None = None,
                         plan: TilePlan | None = None,
                         wgrad_precision: str | None = None) -> jax.Array:
    """Fused-epilogue fp8 grouped linear: ``y[rows of group g'] =
    act(g, u)[rows of g'] @ w[g']`` where ``act(g, u)`` is ``silu(g)*u``
    (SwiGLU; ``u`` required) or unary ``gelu(g)`` (``u=None``).

    The replacement for the unfused ``h = silu(g)*u;
    grouped_linear(h, ...)`` pair on the fp8 path: the activation and its
    1x128 quantization run as ONE ``(act_quant, fp8)`` pass, so the bf16
    ``h`` intermediate never touches HBM and the down GEMM consumes the
    :class:`~repro.core.quantization.QuantizedActivation` directly.

    The custom VJP computes ``dsilu(g)·u`` / ``silu(g)·du`` (or gelu')
    from the ``(g, u)`` residuals; the wgrad follows ``wgrad_precision``
    exactly like :func:`grouped_linear` — ``"fp8"`` reuses the fused
    pass's quantized h as the residual (zero standalone quantizes of h),
    ``"bf16"`` recomputes h in f32 for the highest-precision contraction.
    ``plan`` semantics match :func:`grouped_linear`: pass the routing
    decision's TilePlan so the schedule is built once.
    """
    from repro.kernels.epilogue_kernel import ACTIVATIONS
    if act not in ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}; "
                         f"expected one of {ACTIVATIONS}")
    if act == "silu_mul" and u is None:
        raise ValueError("act='silu_mul' needs both g and u")
    if act != "silu_mul" and u is not None:
        raise ValueError(f"act={act!r} is unary; got a second operand")
    cfg = resolve_config(config, backend=backend, out_dtype=out_dtype,
                         wgrad_precision=wgrad_precision)
    if cfg.out_dtype is None:
        cfg = cfg.with_(out_dtype=g.dtype)
    return _grouped_linear_fp8_fused(g, u, w, group_sizes, plan, (cfg, act))


def dense_linear_fp8_fused(g: jax.Array, u: jax.Array | None,
                           w: jax.Array, *, act: str = "silu_mul",
                           backend: str | None = None,
                           out_dtype: Any = None,
                           config: KernelConfig | None = None,
                           plan: TilePlan | None = None) -> jax.Array:
    """G=1 fused-epilogue fp8 linear for dense layers (the MLP down
    projection and the MoE shared-expert FFN).  Accepts arbitrary leading
    dims on ``g``/``u`` (flattened to rows like ``models.layers.linear``);
    ``plan`` is the same G=1 TilePlan the sibling gate/up GEMMs consumed.
    """
    lead, f = g.shape[:-1], g.shape[-1]
    g2 = g.reshape(-1, f)
    u2 = None if u is None else u.reshape(-1, f)
    gs = jnp.array([g2.shape[0]], jnp.int32)
    y = grouped_linear_fused(g2, u2, w[None], gs, act=act, backend=backend,
                             out_dtype=out_dtype, config=config, plan=plan)
    return y.reshape(*lead, w.shape[-1])


def grouped_linear_ffn(x: jax.Array, w_gate: jax.Array | None,
                       w_up: jax.Array, w_down: jax.Array,
                       group_sizes: jax.Array, *, act: str = "silu_mul",
                       backend: str | None = None,
                       out_dtype: Any = None,
                       config: KernelConfig | None = None,
                       plan: TilePlan | None = None,
                       quantized: "q.QuantizedActivation | None" = None,
                       wgrad_precision: str | None = None) -> jax.Array:
    """Whole fp8 expert FFN with producer-side quantizing epilogues:
    ``y = act(x @ w_gate, x @ w_up) @ w_down`` per group, where the
    gate/up GEMMs emit fp8 payload + 1x128 scales DIRECTLY from their
    store phase (``grouped_gemm_quant``) and the activation kernel
    dequantizes them on load.  Nothing wider than fp8 crosses HBM between
    the producer GEMMs and the down GEMM.

    ``w_gate``: [G, K, F] (or ``None`` for the unary ``gelu``, where
    ``w_up`` is the single projection); ``w_up``: [G, K, F]; ``w_down``:
    [G, F, N].  ``quantized`` is the quantize-once record of exactly this
    ``x``; ``plan``/``wgrad_precision`` follow :func:`grouped_linear`.

    Numerics: the kernel-level producer is bitwise identical to the
    unfused GEMM->quantize composition, but the *FFN* differs from the
    unfused recipe by one extra e4m3 quantization of g/u before the
    activation (the price of never materializing them wide) — expect a
    small tolerance delta vs :func:`grouped_linear_fused` pipelines, not
    equality.  Standalone quantize count: forward exactly one
    (``x``, skipped when ``quantized`` is given); forward+backward four
    (``x``, ``dy``, ``dg``, ``du``) — zero quantizes of g/u/h anywhere.
    """
    from repro.kernels.epilogue_kernel import ACTIVATIONS
    if act not in ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}; "
                         f"expected one of {ACTIVATIONS}")
    if act == "silu_mul" and w_gate is None:
        raise ValueError("act='silu_mul' needs both w_gate and w_up")
    if act != "silu_mul" and w_gate is not None:
        raise ValueError(f"act={act!r} is unary; pass the single projection "
                         "as w_up with w_gate=None")
    cfg = resolve_config(config, backend=backend, out_dtype=out_dtype,
                         wgrad_precision=wgrad_precision)
    if cfg.out_dtype is None:
        cfg = cfg.with_(out_dtype=x.dtype)
    return _grouped_linear_ffn_fp8(x, w_gate, w_up, w_down, group_sizes,
                                   plan, quantized, (cfg, act))


def dense_ffn_fp8(x: jax.Array, w_gate: jax.Array | None, w_up: jax.Array,
                  w_down: jax.Array, *, act: str = "silu_mul",
                  backend: str | None = None, out_dtype: Any = None,
                  config: KernelConfig | None = None,
                  plan: TilePlan | None = None,
                  quantized: "q.QuantizedActivation | None" = None
                  ) -> jax.Array:
    """G=1 producer-fused fp8 FFN for dense layers (the MoE shared expert
    and the dense MLP).  Accepts arbitrary leading dims on ``x``
    (flattened to rows like ``models.layers.linear``); ``plan`` is the
    same G=1 TilePlan the caller built for the token buffer."""
    lead, k = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, k)
    gs = jnp.array([x2.shape[0]], jnp.int32)
    y = grouped_linear_ffn(
        x2, None if w_gate is None else w_gate[None], w_up[None],
        w_down[None], gs, act=act, backend=backend, out_dtype=out_dtype,
        config=config, plan=plan, quantized=quantized)
    return y.reshape(*lead, w_down.shape[-1])


# ---------------------------------------------------------------------------
# Kernel contracts (repro.analysis layer 1)
# ---------------------------------------------------------------------------
# Declarative invariants for every public fp8 path in this module, checked
# by ``python -m repro.analysis --contracts`` (and tests/test_analysis.py)
# via abstract tracing — the replacement for the monkeypatch-count CI
# gates.  Builders are deferred: registration costs nothing at import.

from repro.analysis.contracts import register_contract as _register_contract


def _contract_operands():
    """Shared example problem: G=3 with an empty group and a ragged tail
    (sum(gs)=190 < M=256) — the shapes every padding-free claim is about."""
    import numpy as _np
    rng = _np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 128, 128)), jnp.float32)
    gu = jnp.asarray(rng.standard_normal((2, 256, 256)), jnp.float32)
    wd = jnp.asarray(rng.standard_normal((3, 256, 128)), jnp.float32)
    gs = jnp.asarray([60, 0, 130], jnp.int32)
    return x, w, gu, wd, gs


def _build_linear_fwd():
    x, w, _, _, gs = _contract_operands()
    cfg = KernelConfig(backend="pallas_interpret")
    return (lambda x, w: grouped_linear(x, w, gs, precision="fp8",
                                        config=cfg)), (x, w)


def _build_linear_grad():
    x, w, _, _, gs = _contract_operands()
    cfg = KernelConfig(backend="pallas_interpret")

    def loss(x, w):
        y = grouped_linear(x, w, gs, precision="fp8", config=cfg)
        return jnp.sum(y.astype(jnp.float32) ** 2)
    return jax.grad(loss, argnums=(0, 1)), (x, w)


def _build_fused_fwd():
    _, _, gu, wd, gs = _contract_operands()
    cfg = KernelConfig(backend="pallas_interpret")
    return (lambda g, u: grouped_linear_fused(g, u, wd, gs, act="silu_mul",
                                              config=cfg)), (gu[0], gu[1])


def _build_fused_grad():
    _, _, gu, wd, gs = _contract_operands()
    cfg = KernelConfig(backend="pallas_interpret")

    def loss(g, u, w):
        y = grouped_linear_fused(g, u, w, gs, act="silu_mul", config=cfg)
        return jnp.sum(y.astype(jnp.float32) ** 2)
    return jax.grad(loss, argnums=(0, 1, 2)), (gu[0], gu[1], wd)


def _build_ffn_fwd():
    x, _, _, _, gs = _contract_operands()
    import numpy as _np
    rng = _np.random.default_rng(1)
    wg = jnp.asarray(rng.standard_normal((3, 128, 256)), jnp.float32)
    wu = jnp.asarray(rng.standard_normal((3, 128, 256)), jnp.float32)
    wd = jnp.asarray(rng.standard_normal((3, 256, 128)), jnp.float32)
    cfg = KernelConfig(backend="pallas_interpret")
    return (lambda x: grouped_linear_ffn(x, wg, wu, wd, gs, act="silu_mul",
                                         config=cfg)), (x,)


def _build_ffn_grad():
    x, _, _, _, gs = _contract_operands()
    import numpy as _np
    rng = _np.random.default_rng(1)
    wg = jnp.asarray(rng.standard_normal((3, 128, 256)), jnp.float32)
    wu = jnp.asarray(rng.standard_normal((3, 128, 256)), jnp.float32)
    wd = jnp.asarray(rng.standard_normal((3, 256, 128)), jnp.float32)
    cfg = KernelConfig(backend="pallas_interpret", wgrad_precision="fp8")

    def loss(x, wg_, wu_, wd_):
        y = grouped_linear_ffn(x, wg_, wu_, wd_, gs, act="silu_mul",
                               config=cfg)
        return jnp.sum(y.astype(jnp.float32) ** 2)
    return jax.grad(loss, argnums=(0, 1, 2, 3)), (x, wg, wu, wd)


_register_contract(
    "grouped_linear.fp8.fwd",
    description="fp8 forward: ONE standalone quantize (x), one plan "
                "build, zero padding primitives",
    build=_build_linear_fwd,
    quantize_count=1, quantize_shapes=((256, 128),),
    plan_builds=1, forbid_padding=True)

_register_contract(
    "grouped_linear.fp8.grad",
    description="fp8 fwd+bwd: quantizes exactly {x, dy}; the forward's "
                "TilePlan serves the dgrad and wgrad (one build total)",
    build=_build_linear_grad,
    quantize_count=2, quantize_shapes=((256, 128), (256, 128)),
    plan_builds=1, forbid_padding=True)

_register_contract(
    "grouped_linear_fused.fp8.fwd",
    description="fused epilogue forward: ZERO standalone quantizes (the "
                "act_quant pass owns h), no wide h materialization",
    build=_build_fused_fwd,
    quantize_count=0, plan_builds=1, forbid_padding=True,
    forbid_wide_shapes=((256, 256),))

_register_contract(
    "grouped_linear_fused.fp8.grad",
    description="fused epilogue fwd+bwd: quantizes exactly {dy}; one "
                "plan build serves forward, dgrad, and wgrad",
    build=_build_fused_grad,
    quantize_count=1, quantize_shapes=((256, 128),),
    plan_builds=1, forbid_padding=True)

_register_contract(
    "grouped_linear_ffn.fp8.fwd",
    description="producer-fused FFN forward: ONE standalone quantize "
                "(x), gate/up through grouped_gemm_quant, g/u/h never "
                "wider than fp8",
    build=_build_ffn_fwd,
    quantize_count=1, quantize_shapes=((256, 128),),
    plan_builds=1, gemm_quant_calls=2, forbid_padding=True,
    forbid_wide_shapes=((256, 256),))

_register_contract(
    "grouped_linear_ffn.fp8.grad",
    description="producer-fused FFN fwd+bwd (all-fp8 wgrad): quantizes "
                "exactly {x, dy, dg, du} — never g/u/h",
    build=_build_ffn_grad,
    quantize_count=4,
    quantize_shapes=((256, 128), (256, 128), (256, 256), (256, 256)),
    plan_builds=1, gemm_quant_calls=2, forbid_padding=True)


# ---------------------------------------------------------------------------
# Compile contracts (repro.analysis layer 5: REPRO-T01)
# ---------------------------------------------------------------------------
# Shape-stable repeat calls must hit the jit cache: three steps with
# DIFFERENT routings (new group_sizes values, same shapes) may trace the
# step function exactly once.  group_sizes rides as a traced operand —
# retracing here would mean every MoE routing decision recompiles the
# layer, the failure mode the TilePlan's value-independent schedule
# exists to avoid.

from repro.analysis.retrace import \
    register_compile_contract as _register_compile_contract


def _build_linear_retrace():
    x, w, _, _, _ = _contract_operands()
    cfg = KernelConfig(backend="pallas_interpret")

    def linear_step(x, w, gs):
        y = grouped_linear(x, w, gs, precision="fp8", config=cfg)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    fn = jax.jit(jax.value_and_grad(linear_step, argnums=(0, 1)))
    routings = ([60, 0, 130], [100, 50, 40], [0, 0, 256])
    calls = [(x, w, jnp.asarray(r, jnp.int32)) for r in routings]
    return fn, calls


def _build_ffn_retrace():
    x, _, _, _, _ = _contract_operands()
    import numpy as _np
    rng = _np.random.default_rng(1)
    wg = jnp.asarray(rng.standard_normal((3, 128, 256)), jnp.float32)
    wu = jnp.asarray(rng.standard_normal((3, 128, 256)), jnp.float32)
    wd = jnp.asarray(rng.standard_normal((3, 256, 128)), jnp.float32)
    cfg = KernelConfig(backend="pallas_interpret", wgrad_precision="fp8")

    def ffn_step(x, wg_, wu_, wd_, gs):
        y = grouped_linear_ffn(x, wg_, wu_, wd_, gs, act="silu_mul",
                               config=cfg)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    fn = jax.jit(jax.value_and_grad(ffn_step, argnums=(0, 1, 2, 3)))
    routings = ([60, 0, 130], [100, 50, 40], [256, 0, 0])
    calls = [(x, wg, wu, wd, jnp.asarray(r, jnp.int32))
             for r in routings]
    return fn, calls


_register_compile_contract(
    "grouped_linear.fp8.retrace",
    description="fp8 fwd+bwd step compiles ONCE across three routing "
                "changes of the same shape",
    build=_build_linear_retrace,
    expected={"linear_step": 1}, rule="REPRO-T01")

_register_compile_contract(
    "grouped_linear_ffn.fp8.retrace",
    description="producer-fused FFN fwd+bwd step (all-fp8 wgrad) "
                "compiles ONCE across three routing changes of the same "
                "shape",
    build=_build_ffn_retrace,
    expected={"ffn_step": 1}, rule="REPRO-T01")
