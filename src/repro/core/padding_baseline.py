"""The paper's baseline: explicit padding + aligned grouped GEMM.

Paper §3: "Our baseline implementation integrates explicit input padding
with DeepGEMM".  We reproduce that pipeline faithfully so the benchmarks
can compare like-for-like:

  1. a padding pass copies each group's rows of ``A`` and ``S_A`` into a
     buffer where every group starts at a ``block_m``-aligned offset
     (the memory + bandwidth overhead the paper eliminates);
  2. the aligned grouped GEMM runs over the padded buffer (group sizes all
     multiples of ``block_m`` — zero boundary tiles);
  3. an unpadding pass extracts the valid rows of ``C``.

All three stages are measurable separately (see benchmarks/).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import plan as plan_mod
from repro.kernels.plan import KernelConfig, resolve_config


def padded_group_sizes(group_sizes, block_m: int = 128):
    gs = group_sizes.astype(jnp.int32)
    return ((gs + block_m - 1) // block_m) * block_m


def pad_groups(a, s_a, group_sizes, *, block_m: int = 128,
               padded_m: int | None = None):
    """Scatter each group's rows to block-aligned offsets.

    ``padded_m`` must be a static bound (worst case:
    ``M + G*(block_m-1)`` rounded up); rows beyond the data are zero.
    Returns (a_padded, s_a_padded, padded_sizes, row_map) where
    ``row_map[i]`` is the padded row of source row i.
    """
    m = a.shape[0]
    g = group_sizes.shape[0]
    if padded_m is None:
        padded_m = int(np.ceil((m + g * (block_m - 1)) / block_m) * block_m)
    gs = group_sizes.astype(jnp.int32)
    psz = padded_group_sizes(gs, block_m)
    src_off = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(gs)[:-1]])
    dst_off = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(psz)[:-1]])
    # group of each source row, then its destination row
    seg = jnp.repeat(jnp.arange(g, dtype=jnp.int32), gs, total_repeat_length=m)
    row_in_group = jnp.arange(m, dtype=jnp.int32) - src_off[seg]
    row_map = dst_off[seg] + row_in_group
    a_p = jnp.zeros((padded_m, a.shape[1]), a.dtype).at[row_map].set(a)
    s_p = jnp.ones((padded_m, s_a.shape[1]), s_a.dtype).at[row_map].set(s_a)
    return a_p, s_p, psz, row_map


def unpad_groups(c_padded, row_map):
    return c_padded[row_map]


def grouped_gemm_fp8_padded(a_fp8, s_a, b_fp8, s_b, group_sizes, *,
                            config: "KernelConfig | None" = None,
                            backend=None, out_dtype=None, padded_m=None):
    """The full baseline pipeline: pad -> aligned grouped GEMM -> unpad.

    Tile shapes come from ``config`` (:class:`KernelConfig`); the aligned
    GEMM routes through the dispatch registry with ``backend`` /
    ``config.backend`` naming the *inner* backend (default:
    auto-resolved).  The padded buffer's group offsets differ from the
    caller's, so any caller-side :class:`TilePlan` does not apply here —
    instead the baseline's own block-aligned plan comes from the
    :class:`~repro.kernels.plan.PlanCache`: keyed by the padded buffer's
    static shape (padded_m, block_m, num_groups, dtype, device), it is
    derived once per shape class and replayed on every later call, next
    to the autotune entries.  (Re-planning per call was the historical
    behaviour — and pure waste, since the padded schedule's static key
    never changes across steps of one workload.)
    """
    cfg = resolve_config(config, backend=backend, out_dtype=out_dtype)
    a_p, s_p, psz, row_map = pad_groups(a_fp8, s_a, group_sizes,
                                        block_m=cfg.block_m,
                                        padded_m=padded_m)
    plan = None
    if kops.backend_uses_plan(cfg.backend):
        plan = plan_mod.shared_plan(psz, a_p.shape[0],
                                    block_m=cfg.block_m,
                                    num_groups=group_sizes.shape[0])
    c_p = kops.grouped_gemm_fp8(a_p, s_p, b_fp8, s_b, psz, config=cfg,
                                plan=plan)
    return unpad_groups(c_p, row_map)


def padding_overhead_bytes(group_sizes, k, kb, block_m: int = 128):
    """Extra bytes the baseline allocates + moves for (A, S_A, C) —
    the quantity behind the paper's Fig. 2b."""
    gs = np.asarray(group_sizes, np.int64)
    pad_rows = int((np.ceil(gs / block_m) * block_m - gs).sum())
    a_bytes = pad_rows * k            # fp8 = 1 byte
    sa_bytes = pad_rows * kb * 4      # f32 scales
    return {"pad_rows": pad_rows, "a_bytes": a_bytes, "sa_bytes": sa_bytes}
