"""The paper's baseline: explicit padding + aligned grouped GEMM.

Paper §3: "Our baseline implementation integrates explicit input padding
with DeepGEMM".  We reproduce that pipeline faithfully so the benchmarks
can compare like-for-like:

  1. a padding pass copies each group's rows of ``A`` and ``S_A`` into a
     buffer where every group starts at a ``block_m``-aligned offset
     (the memory + bandwidth overhead the paper eliminates);
  2. the aligned grouped GEMM runs over the padded buffer (group sizes all
     multiples of ``block_m`` — zero boundary tiles);
  3. an unpadding pass extracts the valid rows of ``C``.

All three stages are measurable separately (see benchmarks/).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import plan as plan_mod
from repro.kernels.plan import KernelConfig, resolve_config


def padded_group_sizes(group_sizes, block_m: int = 128):
    gs = group_sizes.astype(jnp.int32)
    return ((gs + block_m - 1) // block_m) * block_m


def pad_groups(a, s_a, group_sizes, *, block_m: int = 128,
               padded_m: int | None = None):
    """Scatter each group's rows to block-aligned offsets.

    ``padded_m`` must be a static bound (worst case:
    ``M + G*(block_m-1)`` rounded up); rows beyond the data are zero.
    Returns (a_padded, s_a_padded, padded_sizes, row_map) where
    ``row_map[i]`` is the padded row of source row i.
    """
    m = a.shape[0]
    g = group_sizes.shape[0]
    if padded_m is None:
        padded_m = int(np.ceil((m + g * (block_m - 1)) / block_m) * block_m)
    gs = group_sizes.astype(jnp.int32)
    psz = padded_group_sizes(gs, block_m)
    src_off = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(gs)[:-1]])
    dst_off = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(psz)[:-1]])
    # group of each source row, then its destination row
    seg = jnp.repeat(jnp.arange(g, dtype=jnp.int32), gs, total_repeat_length=m)
    row_in_group = jnp.arange(m, dtype=jnp.int32) - src_off[seg]
    row_map = dst_off[seg] + row_in_group
    a_p = jnp.zeros((padded_m, a.shape[1]), a.dtype).at[row_map].set(a)
    s_p = jnp.ones((padded_m, s_a.shape[1]), s_a.dtype).at[row_map].set(s_a)
    return a_p, s_p, psz, row_map


def unpad_groups(c_padded, row_map):
    return c_padded[row_map]


def grouped_gemm_fp8_padded(a_fp8, s_a, b_fp8, s_b, group_sizes, *,
                            config: "KernelConfig | None" = None,
                            backend=None, out_dtype=None, padded_m=None):
    """The full baseline pipeline: pad -> aligned grouped GEMM -> unpad.

    Tile shapes come from ``config`` (:class:`KernelConfig`); the aligned
    GEMM routes through the dispatch registry with ``backend`` /
    ``config.backend`` naming the *inner* backend (default:
    auto-resolved).  The padded buffer's group offsets differ from the
    caller's, so any caller-side :class:`TilePlan` does not apply here —
    instead the baseline's own block-aligned plan comes from the
    :class:`~repro.kernels.plan.PlanCache`: keyed by the padded buffer's
    static shape (padded_m, block_m, num_groups, dtype, device), it is
    derived once per shape class and replayed on every later call, next
    to the autotune entries.  (Re-planning per call was the historical
    behaviour — and pure waste, since the padded schedule's static key
    never changes across steps of one workload.)
    """
    cfg = resolve_config(config, backend=backend, out_dtype=out_dtype)
    a_p, s_p, psz, row_map = pad_groups(a_fp8, s_a, group_sizes,
                                        block_m=cfg.block_m,
                                        padded_m=padded_m)
    plan = None
    if kops.backend_uses_plan(cfg.backend):
        plan = plan_mod.shared_plan(psz, a_p.shape[0],
                                    block_m=cfg.block_m,
                                    num_groups=group_sizes.shape[0])
    c_p = kops.grouped_gemm_fp8(a_p, s_p, b_fp8, s_b, psz, config=cfg,
                                plan=plan)
    return unpad_groups(c_p, row_map)


def padding_overhead_bytes(group_sizes, k, kb, block_m: int = 128):
    """Extra bytes the baseline allocates + moves for (A, S_A, C) —
    the quantity behind the paper's Fig. 2b."""
    gs = np.asarray(group_sizes, np.int64)
    pad_rows = int((np.ceil(gs / block_m) * block_m - gs).sum())
    a_bytes = pad_rows * k            # fp8 = 1 byte
    sa_bytes = pad_rows * kb * 4      # f32 scales
    return {"pad_rows": pad_rows, "a_bytes": a_bytes, "sa_bytes": sa_bytes}


# ---------------------------------------------------------------------------
# Compile contracts (repro.analysis layer 5: REPRO-T03)
# ---------------------------------------------------------------------------
# The padded baseline's selling point is that the aligned buffer's STATIC
# shape amortizes compilation: one compile per (padded_m) M-bucket, i.e.
# routing changes inside the same bucket hit the jit cache and only a
# genuinely new bucket pays a trace.  A retrace on a bucket-stable call
# sequence would reintroduce the recompilation cost padding exists to buy
# off — exactly what benchmarks comparing against it must not mismeasure.

from repro.analysis.retrace import \
    register_compile_contract as _register_compile_contract


def _build_baseline_retrace():
    import functools

    import numpy as _np
    from repro.kernels import ref as kref

    rng = _np.random.default_rng(0)
    k = n = 128
    g = 3

    def operands(m, seed):
        r = _np.random.default_rng(seed)
        a8, sa = kref.quantize_tilewise_ref(
            jnp.asarray(r.standard_normal((m, k)), jnp.float32))
        b8, sb = jax.vmap(kref.quantize_blockwise_ref)(
            jnp.asarray(rng.standard_normal((g, k, n)), jnp.float32))
        return a8, sa, b8, sb

    # the tile-free XLA backend keeps the trace free of PlanCache's own
    # (once-per-shape) jitted schedule builds — this contract is about
    # the baseline step itself
    cfg = KernelConfig(backend="xla_ragged")

    def baseline_step(a8, sa, b8, sb, gs, *, padded_m):
        return grouped_gemm_fp8_padded(a8, sa, b8, sb, gs, config=cfg,
                                       padded_m=padded_m)

    fn = jax.jit(functools.partial(baseline_step),
                 static_argnames=("padded_m",))

    def run(m, gs_vals, seed, bucket):
        a8, sa, b8, sb = operands(m, seed)
        return fn(a8, sa, b8, sb, jnp.asarray(gs_vals, jnp.int32),
                  padded_m=bucket)

    # two same-bucket calls (different routings) + one new bucket:
    # exactly two traces
    calls = [(256, [60, 0, 130], 2, 640),
             (256, [100, 50, 40], 3, 640),
             (512, [200, 12, 44], 4, 896)]
    return run, calls


_register_compile_contract(
    "padding_baseline.bucket.retrace",
    description="the padded pipeline compiles once per (padded_m) "
                "M-bucket: two same-bucket routings share one trace, a "
                "new bucket adds exactly one",
    build=_build_baseline_retrace,
    expected={"baseline_step": 2}, rule="REPRO-T03")
