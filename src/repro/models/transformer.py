"""Generic decoder LM covering the dense / MoE / ssm / hybrid / vlm
families via the config's cycled ``block_pattern``.

Layer layout = [pre_layers (unscanned; e.g. deepseek's dense layer-0)]
             + [cycles x pattern (lax.scan over stacked params, remat)]
             + [tail_layers (pattern remainder, unscanned)].

Modes: "train" (no cache), "prefill" (returns per-layer caches),
"decode" (one token against caches).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.configs.base import ModelConfig
from repro.core.moe import (MoEConfig, init_moe_params, moe_apply,
                            ep_size_for, shard_moe_params)
from repro.distributed import context as dctx
from repro.models import attention as attn
from repro.models import rglru as rg
from repro.models import xlstm as xl
from repro.models.layers import (init_rms_norm, rms_norm, init_mlp, mlp,
                                 init_embedding, embed, unembed, ninit,
                                 cross_entropy)


def effective_pattern(cfg: ModelConfig):
    return cfg.block_pattern if cfg.block_pattern else ("attn",)


def moe_config(cfg: ModelConfig) -> MoEConfig:
    m = cfg.moe
    return MoEConfig(
        num_experts=m.num_experts, top_k=m.top_k, d_model=cfg.d_model,
        d_ff_expert=m.d_ff_expert, num_shared_experts=m.num_shared_experts,
        norm_topk_prob=m.norm_topk_prob, capacity_factor=m.capacity_factor,
        precision=cfg.precision, backend=cfg.gemm_backend,
        kernel_config=cfg.resolved_kernel_config,
        dispatch=cfg.moe_dispatch,
        reduce_dtype=jnp.bfloat16 if cfg.moe_reduce_bf16 else jnp.float32)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def init_block(key, kind: str, cfg: ModelConfig, *, moe_layer: bool):
    dtype = cfg.dtype
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if kind == "attn":
        p = {"ln1": init_rms_norm(d), "ln2": init_rms_norm(d),
             "attn": attn.init_attention(ks[0], cfg, dtype)}
        if moe_layer:
            p["moe"] = init_moe_params(ks[1], moe_config(cfg), dtype)
        else:
            act = "gelu" if cfg.family == "audio" else "swiglu"
            f = cfg.d_ff or (cfg.moe.d_ff_expert *
                             (cfg.moe.top_k + cfg.moe.num_shared_experts)
                             if cfg.moe else 4 * d)
            p["mlp"] = init_mlp(ks[1], d, f, act, dtype)
        return p
    if kind == "rglru":
        return {"ln1": init_rms_norm(d), "ln2": init_rms_norm(d),
                "rglru": rg.init_rglru(ks[0], cfg, dtype),
                "mlp": init_mlp(ks[1], d, cfg.d_ff, "swiglu", dtype)}
    if kind == "mlstm":
        return {"ln1": init_rms_norm(d),
                "mlstm": xl.init_mlstm(ks[0], cfg, dtype)}
    if kind == "slstm":
        return {"ln1": init_rms_norm(d),
                "slstm": xl.init_slstm(ks[0], cfg, dtype)}
    raise ValueError(kind)


def _apply_moe(p, x, cfg: ModelConfig):
    mcfg = moe_config(cfg)
    b, s, d = x.shape
    mesh = dctx.get_mesh()
    if mesh is None or "model" not in mesh.axis_names \
            or mesh.shape["model"] == 1:
        y, aux = moe_apply(p, x.reshape(b * s, d), mcfg)
        return y.reshape(b, s, d), aux["load_balance_loss"]

    ep = ep_size_for(mcfg, mesh.shape["model"])
    pspecs = shard_moe_params(p, mcfg, ep)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    xspec = P(batch_axes if batch_axes else None, None, None)

    def local_fn(p_loc, x_loc):
        rank = jax.lax.axis_index("model") if ep > 1 else 0
        bl, sl, dl = x_loc.shape
        y, aux = moe_apply(p_loc, x_loc.reshape(bl * sl, dl), mcfg,
                           ep_rank=rank, ep_size=ep, axis_name="model")
        return y.reshape(bl, sl, dl), aux["load_balance_loss"]

    y, lb = shard_map(local_fn, mesh=mesh, in_specs=(pspecs, xspec),
                      out_specs=(xspec, P()), check_vma=False)(p, x)
    return y, lb


def block_apply(kind: str, p, x, cfg: ModelConfig, positions, *,
                cache=None, mode: str = "train", cache_capacity=None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        x_in = rms_norm(p["ln1"], x, cfg.norm_eps)
        if cfg.seq_shard:
            # Megatron-SP gather point: residual stream is seq-sharded;
            # attention needs the full sequence (explicit AG here keeps
            # GSPMD from replicating the whole attention computation)
            x_in = dctx.constrain(x_in, "batch", None, "embed")
        h, new_cache = attn.attention_block(
            p["attn"], x_in, cfg, positions,
            cache=cache, layer_window=cfg.window, mode=mode,
            cache_capacity=cache_capacity)
        x = x + h
        h2 = rms_norm(p["ln2"], x, cfg.norm_eps)
        if cfg.seq_shard:
            h2 = dctx.constrain(h2, "batch", None, "embed")
        if "moe" in p:
            ff, aux = _apply_moe(p["moe"], h2, cfg)
        else:
            act = "gelu" if cfg.family == "audio" else "swiglu"
            ff = mlp(p["mlp"], h2, act, precision=cfg.precision,
                     backend=cfg.gemm_backend,
                     config=cfg.resolved_kernel_config)
        return x + ff, new_cache, aux
    if kind == "rglru":
        h, new_state = rg.rglru_apply(
            p["rglru"], rms_norm(p["ln1"], x, cfg.norm_eps),
            state=cache)
        if mode == "train":
            new_state = None
        x = x + h
        ff = mlp(p["mlp"], rms_norm(p["ln2"], x, cfg.norm_eps), "swiglu",
                 precision=cfg.precision, backend=cfg.gemm_backend,
                 config=cfg.resolved_kernel_config)
        return x + ff, new_state, aux
    if kind == "mlstm":
        h, new_state = xl.mlstm_apply(
            p["mlstm"], rms_norm(p["ln1"], x, cfg.norm_eps), state=cache)
        return x + h, (None if mode == "train" else new_state), aux
    if kind == "slstm":
        h, new_state = xl.slstm_apply(
            p["slstm"], rms_norm(p["ln1"], x, cfg.norm_eps), state=cache)
        return x + h, (None if mode == "train" else new_state), aux
    raise ValueError(kind)


def init_block_cache(kind: str, cfg: ModelConfig, batch: int, seq_len: int):
    if kind == "attn":
        return attn.init_kv_cache(cfg, batch, seq_len, cfg.window)
    if kind == "rglru":
        return rg.init_rglru_state(cfg, batch)
    if kind == "mlstm":
        return xl.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return xl.init_slstm_state(cfg, batch)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

def _layout(cfg: ModelConfig):
    pattern = effective_pattern(cfg)
    n_pre = cfg.moe.first_dense_layers if cfg.moe else 0
    rest = cfg.num_layers - n_pre
    cycles = rest // len(pattern)
    tail = tuple(pattern[i] for i in range(rest % len(pattern)))
    return pattern, n_pre, cycles, tail


def init_decoder(key, cfg: ModelConfig):
    pattern, n_pre, cycles, tail = _layout(cfg)
    keys = jax.random.split(key, 8)
    params = {
        "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model,
                                cfg.dtype, cfg.tie_embeddings),
        "final_norm": init_rms_norm(cfg.d_model),
    }
    if cfg.family == "vlm" and cfg.num_patches:
        params["vision_proj"] = ninit(keys[1], (cfg.patch_embed_dim,
                                                cfg.d_model),
                                      cfg.patch_embed_dim ** -0.5, cfg.dtype)
    moe_layer = cfg.moe is not None

    def init_cycle(k):
        ks = jax.random.split(k, len(pattern))
        return {f"b{i}": init_block(ks[i], kind, cfg, moe_layer=moe_layer)
                for i, kind in enumerate(pattern)}

    if cycles:
        if cfg.scan_layers:
            params["layers"] = jax.vmap(init_cycle)(
                jax.random.split(keys[2], cycles))
        else:
            params["layers"] = [init_cycle(k)
                                for k in jax.random.split(keys[2], cycles)]
    for i in range(n_pre):
        params[f"pre{i}"] = init_block(jax.random.split(keys[3], n_pre)[i],
                                       "attn", cfg, moe_layer=False)
    for i, kind in enumerate(tail):
        params[f"tail{i}"] = init_block(jax.random.split(keys[4],
                                                         max(len(tail), 1))[i],
                                        kind, cfg, moe_layer=moe_layer)
    return params


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    pattern, n_pre, cycles, tail = _layout(cfg)
    cache = {}
    if cycles:
        def one_cycle(_):
            return {f"b{i}": init_block_cache(kind, cfg, batch, seq_len)
                    for i, kind in enumerate(pattern)}
        cache["layers"] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[one_cycle(c) for c in range(cycles)]) \
            if cycles > 1 else jax.tree.map(lambda x: x[None], one_cycle(0))
    for i in range(n_pre):
        cache[f"pre{i}"] = init_block_cache("attn", cfg, batch, seq_len)
    for i, kind in enumerate(tail):
        cache[f"tail{i}"] = init_block_cache(kind, cfg, batch, seq_len)
    return cache


def decoder_forward(params, tokens, cfg: ModelConfig, *, mode="train",
                    cache=None, patch_embeds=None, pos_offset=None,
                    cache_capacity=None):
    """tokens: [B, S] int32.  Returns (logits, new_cache, aux_loss).

    decode mode: S == 1, ``cache`` holds per-layer state.
    vlm: ``patch_embeds`` [B, P, patch_dim] are projected and prepended
    (loss positions for patches carry label -1 upstream).
    """
    pattern, n_pre, cycles, tail = _layout(cfg)
    b, s = tokens.shape
    x = embed(params["embed"], tokens)
    if patch_embeds is not None:
        pe = jnp.einsum("bpe,ed->bpd", patch_embeds.astype(x.dtype),
                        params["vision_proj"].astype(x.dtype))
        x = jnp.concatenate([pe, x], axis=1)
        s = x.shape[1]
    x = dctx.constrain(x, "batch", "seq", "embed")

    if mode == "decode":
        positions = None  # per-layer caches carry the position
    else:
        positions = jnp.arange(s, dtype=jnp.int32)
        if pos_offset is not None:
            positions = positions + pos_offset

    aux_total = jnp.zeros((), jnp.float32)
    new_cache = {} if mode in ("prefill", "decode") else None

    # --- pre layers (unscanned) -----------------------------------------
    for i in range(n_pre):
        c = cache.get(f"pre{i}") if cache else None
        x, nc, aux = block_apply("attn", params[f"pre{i}"], x, cfg,
                                 positions, cache=c, mode=mode,
                                 cache_capacity=cache_capacity)
        aux_total += aux
        if new_cache is not None:
            new_cache[f"pre{i}"] = nc

    # --- scanned cycles ---------------------------------------------------
    if cycles:
        def cycle_body(xc, layer_in):
            x, aux_acc = xc
            lp, lcache = layer_in
            ncache = {}
            for i, kind in enumerate(pattern):
                c = lcache[f"b{i}"] if lcache is not None else None
                x, nc, aux = block_apply(kind, lp[f"b{i}"], x, cfg,
                                         positions, cache=c, mode=mode,
                                         cache_capacity=cache_capacity)
                ncache[f"b{i}"] = nc
                aux_acc = aux_acc + aux
            return (x, aux_acc), (ncache if mode != "train" else None)

        body = cycle_body
        if cfg.remat and mode == "train":
            body = jax.checkpoint(
                cycle_body,
                policy=jax.checkpoint_policies.nothing_saveable)

        if cfg.scan_layers:
            layer_cache = cache.get("layers") if cache else None
            if layer_cache is not None:
                (x, aux_total), caches_out = jax.lax.scan(
                    body, (x, aux_total), (params["layers"], layer_cache))
            else:
                (x, aux_total), caches_out = jax.lax.scan(
                    lambda c, lp: body(c, (lp, None)), (x, aux_total),
                    params["layers"])
            if new_cache is not None:
                new_cache["layers"] = caches_out
        else:
            for li, lp in enumerate(params["layers"]):
                lcache = (jax.tree.map(lambda v: v[li], cache["layers"])
                          if cache else None)
                (x, aux_total), nc = body((x, aux_total), (lp, lcache))
                if new_cache is not None:
                    new_cache.setdefault("_layer_list", []).append(nc)
            if new_cache is not None and "_layer_list" in new_cache:
                lst = new_cache.pop("_layer_list")
                new_cache["layers"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *lst)

    # --- tail layers ------------------------------------------------------
    for i, kind in enumerate(tail):
        c = cache.get(f"tail{i}") if cache else None
        x, nc, aux = block_apply(kind, params[f"tail{i}"], x, cfg,
                                 positions, cache=c, mode=mode,
                                 cache_capacity=cache_capacity)
        aux_total += aux
        if new_cache is not None:
            new_cache[f"tail{i}"] = nc

    if mode == "prefill":
        x = x[:, -1:]        # serving prefill needs only the last position
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x)
    return logits, new_cache, aux_total


def lm_loss(params, batch, cfg: ModelConfig, *, aux_weight=0.01):
    """batch: {tokens [B,S], labels [B,S] (-1 = ignore), optional
    patch_embeds}.  Next-token CE + MoE load-balance aux."""
    logits, _, aux = decoder_forward(
        params, batch["tokens"], cfg, mode="train",
        patch_embeds=batch.get("patch_embeds"))
    labels = batch["labels"]
    if batch.get("patch_embeds") is not None:
        p = batch["patch_embeds"].shape[1]
        pad = jnp.full((labels.shape[0], p), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    loss = cross_entropy(logits[:, :-1], labels[:, 1:])
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}
