"""Unified model API over all assigned architectures.

``make_model(cfg)`` returns a ``Model`` namespace with init / loss /
prefill / decode entry points; ``input_specs`` produces the
ShapeDtypeStruct stand-ins the multi-pod dry-run lowers against
(no device allocation).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tfm
from repro.models import whisper as whs


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init_params: Callable
    loss: Callable                  # (params, batch) -> (loss, metrics)
    prefill: Callable               # (params, batch) -> (logits, cache)
    decode_step: Callable           # (params, tokens, cache) -> (logits, cache)
    init_cache: Callable            # (params, batch, batch_size, seq) -> cache


def make_model(cfg: ModelConfig) -> Model:
    if cfg.family == "audio":
        def init_params(key):
            return whs.init_whisper(key, cfg)

        def loss(params, batch):
            return whs.whisper_loss(params, batch, cfg)

        def prefill(params, batch, cache_capacity=None):
            logits, cache, _ = whs.whisper_forward(
                params, batch["tokens"], batch["frames"], cfg,
                mode="prefill", cache_capacity=cache_capacity)
            return logits, cache

        def decode_step(params, tokens, cache):
            logits, cache, _ = whs.whisper_forward(
                params, tokens, None, cfg, mode="decode", cache=cache)
            return logits, cache

        def init_cache(params, batch, batch_size, seq):
            return whs.whisper_init_cache(params, batch["frames"], cfg,
                                          batch_size, seq)
    else:
        def init_params(key):
            return tfm.init_decoder(key, cfg)

        def loss(params, batch):
            return tfm.lm_loss(params, batch, cfg)

        def prefill(params, batch, cache_capacity=None):
            logits, cache, _ = tfm.decoder_forward(
                params, batch["tokens"], cfg, mode="prefill",
                patch_embeds=batch.get("patch_embeds"),
                cache_capacity=cache_capacity)
            return logits, cache

        def decode_step(params, tokens, cache):
            logits, cache, _ = tfm.decoder_forward(
                params, tokens, cfg, mode="decode", cache=cache)
            return logits, cache

        def init_cache(params, batch, batch_size, seq):
            return tfm.init_cache(cfg, batch_size, seq)

    return Model(cfg=cfg, init_params=init_params, loss=loss,
                 prefill=prefill, decode_step=decode_step,
                 init_cache=init_cache)


def with_kernel_config(model: Model, kernel_config) -> Model:
    """Rebuild a :class:`Model`'s closures over ``kernel_config`` (tile
    shapes/backend for every grouped/linear GEMM it traces).  Params are
    untouched — tile shapes are execution schedule, not weights — so one
    param tree serves several phase-specialized models (the serving
    engine pins separate prefill and decode configs this way).  No-op
    when the config already matches."""
    if model.cfg.kernel_config == kernel_config:
        return model
    return make_model(dataclasses.replace(model.cfg,
                                          kernel_config=kernel_config))


# ---------------------------------------------------------------------------
# Batches & specs
# ---------------------------------------------------------------------------

def batch_struct(cfg: ModelConfig, shape: ShapeConfig,
                 batch_size: Optional[int] = None, *, decode: bool = False):
    """ShapeDtypeStruct pytree for one step's data inputs."""
    b = batch_size or shape.global_batch
    s = 1 if decode else shape.seq_len
    d = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if not decode:
        d["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.family == "audio" and not decode:
        d["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model),
                                           jnp.bfloat16)
    if cfg.family == "vlm" and not decode:
        d["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_patches, cfg.patch_embed_dim), jnp.bfloat16)
    return d


def synthetic_batch(key, cfg: ModelConfig, seq_len: int, batch_size: int):
    """Concrete random batch (smoke tests, examples, CPU training)."""
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(ks[0], (batch_size, seq_len), 0,
                                cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[1], (batch_size, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            ks[2], (batch_size, cfg.num_patches, cfg.patch_embed_dim),
            jnp.bfloat16)
    return batch
