"""Shared model building blocks (functional, pytree params).

Naming conventions here are load-bearing: distributed/sharding.py assigns
PartitionSpecs by leaf name (wq/wk/wv/wo, w_gate/w_up/w_down, embedding,
lm_head, ...).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.grouped_gemm import (dense_ffn_fp8, dense_linear_fp8,
                                     dense_linear_fp8_fused)
from repro.distributed.context import constrain


def ninit(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rms_norm(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [..., S] int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs
    sin, cos = jnp.sin(angles), jnp.cos(angles)   # [..., S, 1, half]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Linear / MLP
# ---------------------------------------------------------------------------

def linear(x, w, *, precision: str = "bf16", backend=None, config=None):
    """2-D weight matmul with optional DeepSeek-style fp8 path (the G=1
    degenerate case of the paper's grouped GEMM).  ``config`` is the
    :class:`repro.kernels.plan.KernelConfig` carrying tile shapes."""
    if precision == "fp8" and x.shape[-1] % 128 == 0 and w.shape[-1] % 128 == 0:
        lead = x.shape[:-1]
        y = dense_linear_fp8(x.reshape(-1, x.shape[-1]), w, backend=backend,
                             config=config)
        return y.reshape(*lead, w.shape[-1]).astype(x.dtype)
    return jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))


def init_mlp(key, d, f, act: str, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_up": ninit(ks[0], (d, f), d ** -0.5, dtype),
         "w_down": ninit(ks[1], (f, d), f ** -0.5, dtype)}
    if act == "swiglu":
        p["w_gate"] = ninit(ks[2], (d, f), d ** -0.5, dtype)
    return p


def mlp(p, x, act: str = "swiglu", *, precision="bf16", backend=None,
        config=None):
    # §Perf I5: activation nonlinearities run in the compute dtype (bf16)
    # — MaxText practice; the f32 upcast doubled MLP elementwise traffic
    f, d_out = p["w_down"].shape
    if (precision == "fp8" and config is not None and config.fuse_producer
            and x.shape[-1] % 128 == 0 and f % 128 == 0 and d_out % 128 == 0):
        # producer-fused FFN: the gate/up GEMMs quantize in their store
        # phase, so the whole MLP runs one quantize of x and nothing
        # wider than fp8 between its three GEMMs
        if act == "swiglu":
            y = dense_ffn_fp8(x, p["w_gate"], p["w_up"], p["w_down"],
                              act="silu_mul", backend=backend, config=config)
        else:  # gelu
            y = dense_ffn_fp8(x, None, p["w_up"], p["w_down"], act="gelu",
                              backend=backend, config=config)
        return y.astype(x.dtype)
    up = linear(x, p["w_up"], precision=precision, backend=backend,
                config=config)
    fused = (precision == "fp8" and f % 128 == 0 and d_out % 128 == 0)
    if act == "swiglu":
        gate = linear(x, p["w_gate"], precision=precision, backend=backend,
                      config=config)
        if fused:
            # fused (act_quant, fp8) epilogue: h never materializes, the
            # down GEMM consumes fp8 values + 1x128 scales directly
            y = dense_linear_fp8_fused(gate, up, p["w_down"],
                                       act="silu_mul", backend=backend,
                                       config=config)
            return y.astype(x.dtype)
        h = jax.nn.silu(gate) * up
    else:  # gelu
        if fused:
            y = dense_linear_fp8_fused(up, None, p["w_down"], act="gelu",
                                       backend=backend, config=config)
            return y.astype(x.dtype)
        h = jax.nn.gelu(up)
    h = constrain(h, "batch", "seq", "mlp")
    return linear(h, p["w_down"], precision=precision, backend=backend,
                  config=config)


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------

def init_embedding(key, vocab, d, dtype, tie: bool):
    ks = jax.random.split(key, 2)
    p = {"embedding": ninit(ks[0], (vocab, d), d ** -0.5, dtype)}
    if not tie:
        p["lm_head"] = ninit(ks[1], (d, vocab), d ** -0.5, dtype)
    return p


def embed(p, tokens):
    return jnp.take(p["embedding"], tokens, axis=0)


def unembed(p, x):
    if "lm_head" in p:
        logits = jnp.einsum("...d,dv->...v", x, p["lm_head"].astype(x.dtype))
    else:
        logits = jnp.einsum("...d,vd->...v", x,
                            p["embedding"].astype(x.dtype))
    return constrain(logits, "batch", "seq", "vocab")


def cross_entropy(logits, labels, mask=None):
    """Mean token CE in f32; labels < 0 are ignored."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    valid = (labels >= 0)
    if mask is not None:
        valid = valid & (mask > 0)
    valid = valid.astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)
