"""Attention: GQA/MQA with RoPE, qk-norm, optional QKV bias, sliding
window; chunked online-softmax for long sequences (memory-bounded), plus a
single-step decode path against a KV cache.

KV heads are never materialized to q-head count — scores are computed in
grouped form [B, Hkv, G, Sq, Sk].
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import ninit, rope, rms_norm, init_rms_norm
from repro.distributed.context import constrain

NEG_INF = -1e30


def init_attention(key, cfg, dtype):
    d, hq, hkv, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                      cfg.resolved_head_dim)
    ks = jax.random.split(key, 6)
    p = {
        "wq": ninit(ks[0], (d, hq * hd), d ** -0.5, dtype),
        "wk": ninit(ks[1], (d, hkv * hd), d ** -0.5, dtype),
        "wv": ninit(ks[2], (d, hkv * hd), d ** -0.5, dtype),
        "wo": ninit(ks[3], (hq * hd, d), (hq * hd) ** -0.5, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(hd)
        p["k_norm"] = init_rms_norm(hd)
    return p


def _project_qkv(p, x, cfg, positions, *, use_rope=True):
    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def chunked_attention(q, k, v, *, causal: bool, window: Optional[int],
                      chunk: int, q_offset=0, k_offset=0,
                      k_valid: Optional[int] = None):
    """Online-softmax attention, scanned over q and k chunks.

    q: [B, Sq, Hq, D]; k, v: [B, Sk, Hkv, D].  Positions are affine in the
    chunk index: q rows sit at ``q_offset + i``, k rows at ``k_offset + j``.
    Masks are (re)computed INSIDE the scan bodies from the loop counters —
    never passed as scan inputs — so XLA cannot hoist them into materialized
    [nq, nk, ...] mask stacks (a 100x HBM-traffic trap found in the §Perf
    baseline).  Memory: O(chunk^2) score blocks.
    """
    b, sq, hq, hd = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    scale = hd ** -0.5
    cq = min(chunk, sq)
    ck = min(chunk, sk)
    sq_orig = sq
    if k_valid is None:
        k_valid = sk
    # pad to chunk multiples; padded keys are masked via k_valid
    if sq % cq:
        pad = cq - sq % cq
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        sq += pad
    if sk % ck:
        pad = ck - sk % ck
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        sk += pad
    nq, nk = sq // cq, sk // ck

    qg = q.reshape(b, nq, cq, hkv, g, hd).transpose(1, 0, 3, 4, 2, 5)
    kc = k.reshape(b, nk, ck, hkv, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nk, ck, hkv, hd).transpose(1, 0, 3, 2, 4)

    iota_q = jax.lax.iota(jnp.int32, cq)
    iota_k = jax.lax.iota(jnp.int32, ck)

    def q_step(_, qin):
        qi, i = qin                                     # [B,Hkv,G,cq,D], idx
        qpi = q_offset + i * cq + iota_q                # [cq], from counter

        def attend(carry, ki, vi, j):
            m, l, acc = carry
            kpi = k_offset + j * ck + iota_k
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qi.astype(jnp.float32),
                           ki.astype(jnp.float32)) * scale
            mask = jnp.broadcast_to(kpi[None, :] < k_valid,
                                    (cq, ck))
            if causal:
                mask &= qpi[:, None] >= kpi[None, :]
            if window is not None:
                mask &= (qpi[:, None] - kpi[None, :]) < window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # masked entries hold -1e30: exp(-1e30 - m) underflows to
            # exactly 0, so no second mask pass is needed (§Perf I1).
            # NOTE: casting p to bf16 for the PV dot was tried and
            # REFUTED (+4..7% traffic): the convert adds an HBM boundary
            # on the XLA path; it only pays inside a fused flash kernel.
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc_new)

        def k_step(carry, kin):
            ki, vi, j = kin                             # [B,Hkv,ck,D], idx
            # §Perf I4: block-level causal/window skipping — chunks with
            # no live (q, k) pair take the identity branch (a real branch
            # on TPU: while-loop bodies execute per iteration).  ~Halves
            # attention fwd+bwd work for causal training shapes.
            live = None
            if causal:
                live = (q_offset + i * cq + cq - 1) >= (k_offset + j * ck)
            if window is not None:
                in_win = (q_offset + i * cq) - (k_offset + j * ck
                                                + ck - 1) < window
                live = in_win if live is None else live & in_win
            if live is None:
                return attend(carry, ki, vi, j), None
            return jax.lax.cond(live,
                                lambda c: attend(c, ki, vi, j),
                                lambda c: c, carry), None

        m0 = jnp.full((b, hkv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0), (kc, vc, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return None, out                                # [B,Hkv,G,cq,D]

    _, outs = jax.lax.scan(q_step, None, (qg, jnp.arange(nq)))
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, hq, hd)
    return out[:, :sq_orig].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, q_pos, cache_len, *,
                     window: Optional[int]):
    """q: [B, 1, Hq, D] vs cache [B, S, Hkv, D]; positions < cache_len valid."""
    b, _, hq, hd = q.shape
    _, s, hkv, _ = k_cache.shape
    g = hq // hkv
    scale = hd ** -0.5
    qg = q.reshape(b, hkv, g, hd)
    s_scores = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                          k_cache.astype(jnp.float32)) * scale
    k_pos = jnp.arange(s)
    mask = k_pos[None, :] <= q_pos[:, None]             # [B, S]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s_scores = jnp.where(mask[:, None, None, :], s_scores, NEG_INF)
    p = jax.nn.softmax(s_scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


def _cache_from_prefill(k, v, window, capacity=None, dtype=jnp.bfloat16):
    """Build a decode cache from prefill K/V, padded to ``capacity`` slots
    so subsequent decode steps can append.  Window layers use a ring buffer
    keyed by position % window."""
    b, s, hkv, hd = k.shape
    if window is not None and s > window:
        pos = jnp.arange(s - window, s)
        slots = pos % window
        kc = jnp.zeros((b, window, hkv, hd), dtype).at[:, slots].set(
            k[:, -window:].astype(dtype))
        vc = jnp.zeros((b, window, hkv, hd), dtype).at[:, slots].set(
            v[:, -window:].astype(dtype))
        return {"k": kc, "v": vc, "len": jnp.array(s, jnp.int32)}
    cap = max(capacity or s, s)
    pad = ((0, 0), (0, cap - s), (0, 0), (0, 0))
    return {"k": jnp.pad(k.astype(dtype), pad),
            "v": jnp.pad(v.astype(dtype), pad),
            "len": jnp.array(s, jnp.int32)}


def attention_block(p, x, cfg, positions, *, cache=None, layer_window=None,
                    causal=True, mode="train", cache_capacity=None):
    """Full attention sub-block.  With ``cache`` (dict k,v,len) performs
    one decode step and returns (out, new_cache); in prefill mode, builds
    the cache from the full-sequence K/V."""
    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    window = layer_window

    if cache is None:
        q, k, v = _project_qkv(p, x, cfg, positions)
        q = constrain(q, "batch", "seq", "heads", None)
        k = constrain(k, "batch", "seq", "heads", None)
        off = positions[0]
        use_flash = (cfg.attn_backend == "flash" and window is None
                     and s % 128 == 0)
        if use_flash:
            # fused Pallas kernel: scores/softmax state never leave VMEM
            from repro.kernels.flash_attention_kernel import \
                flash_attention_trainable
            out = flash_attention_trainable(
                q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), causal,
                jax.default_backend() != "tpu",
            ).transpose(0, 2, 1, 3)
        else:
            out = chunked_attention(q, k, v, causal=causal, window=window,
                                    chunk=cfg.attn_chunk, q_offset=off,
                                    k_offset=off)
        new_cache = (_cache_from_prefill(k, v, window, cache_capacity)
                     if mode == "prefill" else None)
    else:
        pos = cache["len"]                               # scalar int32
        positions = jnp.full((b,), pos, jnp.int32)
        q, k, v = _project_qkv(p, x, cfg, positions[:, None])
        k = k.astype(cache["k"].dtype)
        v = v.astype(cache["v"].dtype)
        if window is not None and cache["k"].shape[1] == window:
            # rolling window cache: write at pos % window
            idx = pos % window
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, idx, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, idx, 1)
            # positions of cache slots (ring)
            slot = jnp.arange(window)
            slot_pos = jnp.where(slot <= idx, pos - idx + slot,
                                 pos - idx - window + slot)
            s_scores = jnp.einsum(
                "bhgd,bshd->bhgs",
                q.reshape(b, hkv, hq // hkv, hd).astype(jnp.float32),
                k_cache.astype(jnp.float32)) * hd ** -0.5
            mask = (slot_pos >= 0) & (slot_pos <= pos)
            s_scores = jnp.where(mask[None, None, None, :], s_scores, NEG_INF)
            pr = jax.nn.softmax(s_scores, axis=-1)
            out = jnp.einsum("bhgs,bshd->bhgd", pr,
                             v_cache.astype(jnp.float32))
            out = out.reshape(b, 1, hq, hd).astype(x.dtype)
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k, pos, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v, pos, 1)
            k_cache = constrain(k_cache, "batch", "kv_seq", None, None)
            v_cache = constrain(v_cache, "batch", "kv_seq", None, None)
            out = decode_attention(q, k_cache, v_cache, positions, pos,
                                   window=window)
        new_cache = {"k": k_cache, "v": v_cache, "len": pos + 1}

    out = out.reshape(b, s, hq * hd)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))
    return constrain(y, "batch", "seq", "embed"), new_cache


def init_kv_cache(cfg, batch, seq_len, layer_window=None, dtype=jnp.bfloat16):
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    s = min(seq_len, layer_window) if layer_window else seq_len
    return {"k": jnp.zeros((batch, s, hkv, hd), dtype),
            "v": jnp.zeros((batch, s, hkv, hd), dtype),
            "len": jnp.zeros((), jnp.int32)}
