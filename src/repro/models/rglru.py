"""RG-LRU recurrent block (RecurrentGemma / Griffin).

TPU adaptation: the RG-LRU linear recurrence ``h_t = a_t h_{t-1} + b_t`` is
evaluated with ``jax.lax.associative_scan`` (log-depth, MXU-free but fully
parallel over time) instead of a sequential loop — the standard TPU
formulation.  Decode is a single O(1) state update.

Simplification vs the released model (documented in DESIGN.md): the
recurrence/input gates use dense [w, w] projections instead of per-head
block-diagonal ones.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ninit
from repro.distributed.context import constrain

_C = 8.0  # Griffin's fixed gate temperature


def init_rglru(key, cfg, dtype):
    d, w, cw = cfg.d_model, cfg.lru_width or cfg.d_model, cfg.conv_width
    ks = jax.random.split(key, 7)
    return {
        "w_x": ninit(ks[0], (d, w), d ** -0.5, dtype),       # value branch
        "w_y": ninit(ks[1], (d, w), d ** -0.5, dtype),       # gate branch
        "conv": ninit(ks[2], (cw, w), cw ** -0.5, dtype),
        "w_a": ninit(ks[3], (w, w), w ** -0.5, dtype),       # recurrence gate
        "w_i": ninit(ks[4], (w, w), w ** -0.5, dtype),       # input gate
        "lam": jnp.linspace(0.9, 5.0, w).astype(jnp.float32),  # a in (0,1)
        "w_out": ninit(ks[5], (w, d), w ** -0.5, dtype),
    }


def _gates(p, u):
    """u: [B, S, w] post-conv activations -> (log_a, gated input) f32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ p["w_i"].astype(jnp.float32))
    log_a_max = -_C * jax.nn.softplus(p["lam"])          # [w], < 0
    log_a = r * log_a_max[None, None, :]                 # [B,S,w]
    a2 = jnp.exp(2.0 * log_a)
    x_in = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * uf)
    return log_a, x_in


def _conv1d(p, x, conv_state=None):
    """Causal depthwise conv, width cw.  x: [B,S,w].
    With conv_state [B, cw-1, w] performs a streaming step."""
    kern = p["conv"].astype(jnp.float32)                 # [cw, w]
    cw = kern.shape[0]
    xf = x.astype(jnp.float32)
    if conv_state is not None:
        buf = jnp.concatenate([conv_state.astype(jnp.float32), xf], axis=1)
        out = jnp.einsum("btw,tw->bw", buf[:, -cw:], kern)[:, None]
        return out.astype(x.dtype), buf[:, -(cw - 1):].astype(x.dtype)
    pad = jnp.pad(xf, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1]] * kern[i] for i in range(cw))
    return out.astype(x.dtype), pad[:, -(cw - 1):].astype(x.dtype)


def rglru_apply(p, x, *, state=None):
    """x: [B, S, d].  state = None (train/prefill from scratch) or dict
    {h: [B,w], conv: [B,cw-1,w], } for streaming decode.
    Returns (y [B,S,d], new_state)."""
    b, s, d = x.shape
    xb = linear_f32(x, p["w_x"])                         # [B,S,w]
    yb = linear_f32(x, p["w_y"])
    conv_state = state["conv"] if state is not None else None
    u, new_conv = _conv1d(p, xb, conv_state)
    log_a, x_in = _gates(p, u)

    if state is not None and s == 1:
        h_prev = state["h"].astype(jnp.float32)
        a = jnp.exp(log_a[:, 0])
        h = a * h_prev + x_in[:, 0]
        hs = h[:, None]                                  # [B,1,w]
    else:
        # parallel linear recurrence: (a, b) composition via associative scan
        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 + a2, jnp.exp(a2) * b1 + b2        # log-space decay
        la, hb = jax.lax.associative_scan(combine, (log_a, x_in), axis=1)
        if state is not None:  # fold in carried state for chunked prefill
            hb = hb + jnp.exp(la) * state["h"].astype(jnp.float32)[:, None]
        hs = hb
        h = hs[:, -1]
    gate = jax.nn.gelu(yb.astype(jnp.float32))
    out = (gate * hs).astype(x.dtype)
    out = constrain(out, "batch", "seq", "mlp")
    y = jnp.einsum("bsw,wd->bsd", out, p["w_out"].astype(x.dtype))
    new_state = {"h": h.astype(jnp.float32), "conv": new_conv}
    return y, new_state


def linear_f32(x, w):
    return jnp.einsum("bsd,dw->bsw", x.astype(w.dtype), w)


def init_rglru_state(cfg, batch):
    w, cw = cfg.lru_width or cfg.d_model, cfg.conv_width
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cw - 1, w), jnp.bfloat16)}
