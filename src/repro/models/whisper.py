"""Whisper-style encoder-decoder transformer BACKBONE.

Per the assignment, the conv/mel frontend is a stub: the model consumes
precomputed frame embeddings [B, encoder_seq, d_model].  Encoder =
bidirectional attention + GELU MLP; decoder = causal self-attention +
cross-attention over encoder output + GELU MLP.  (Positional encoding uses
RoPE in this framework — a documented backbone substitution.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import context as dctx
from repro.models import attention as attn
from repro.models.layers import (init_rms_norm, rms_norm, init_mlp, mlp,
                                 init_embedding, embed, unembed,
                                 cross_entropy, ninit)


def _init_xattn(key, cfg, dtype):
    return attn.init_attention(key, cfg, dtype)


def _cross_attention(p, x, enc_kv, cfg, *, cache=None):
    """x: [B,S,d] queries; enc_kv: (k, v) [B,Se,Hkv,hd] precomputed."""
    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    q = q.reshape(b, s, hq, hd)
    k, v = enc_kv
    se = k.shape[1]
    out = attn.chunked_attention(q, k, v, causal=False, window=None,
                                 chunk=cfg.attn_chunk, k_valid=se)
    out = out.reshape(b, s, hq * hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))


def _enc_kv(p, enc_out, cfg):
    b, se, d = enc_out.shape
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k = jnp.einsum("bsd,dh->bsh", enc_out,
                   p["wk"].astype(enc_out.dtype)).reshape(b, se, hkv, hd)
    v = jnp.einsum("bsd,dh->bsh", enc_out,
                   p["wv"].astype(enc_out.dtype)).reshape(b, se, hkv, hd)
    return k, v


def init_whisper(key, cfg: ModelConfig):
    dtype = cfg.dtype
    ks = jax.random.split(key, 6)
    d = cfg.d_model

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": init_rms_norm(d), "ln2": init_rms_norm(d),
                "attn": attn.init_attention(k1, cfg, dtype),
                "mlp": init_mlp(k2, d, cfg.d_ff, "gelu", dtype)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": init_rms_norm(d), "ln2": init_rms_norm(d),
                "ln3": init_rms_norm(d),
                "attn": attn.init_attention(k1, cfg, dtype),
                "xattn": _init_xattn(k2, cfg, dtype),
                "mlp": init_mlp(k3, d, cfg.d_ff, "gelu", dtype)}

    return {
        "embed": init_embedding(ks[0], cfg.vocab_size, d, dtype, False),
        "final_norm": init_rms_norm(d),
        "enc_final_norm": init_rms_norm(d),
        "enc_layers": jax.vmap(enc_layer)(
            jax.random.split(ks[1], cfg.encoder_layers)),
        "layers": jax.vmap(dec_layer)(
            jax.random.split(ks[2], cfg.num_layers)),
    }


def whisper_encode(params, frames, cfg: ModelConfig):
    """frames: [B, Se, d_model] precomputed embeddings (stub frontend)."""
    x = frames.astype(cfg.dtype)
    x = dctx.constrain(x, "batch", "seq", "embed")
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(x, lp):
        h, _ = attn.attention_block(lp["attn"],
                                    rms_norm(lp["ln1"], x, cfg.norm_eps),
                                    cfg, positions, causal=False)
        x = x + h
        x = x + mlp(lp["mlp"], rms_norm(lp["ln2"], x, cfg.norm_eps), "gelu",
                    precision=cfg.precision, backend=cfg.gemm_backend,
                    config=cfg.resolved_kernel_config)
        return x, None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn, x, params["enc_layers"])
    return rms_norm(params["enc_final_norm"], x, cfg.norm_eps)


def whisper_forward(params, tokens, frames, cfg: ModelConfig, *,
                    mode="train", cache=None, cache_capacity=None):
    """Returns (logits, new_cache, aux).  cache carries per-layer self-attn
    KV plus precomputed cross KV and encoder output reuse for decode."""
    enc_out = (cache["enc_out"] if cache is not None and "enc_out" in cache
               else whisper_encode(params, frames, cfg))
    b, s = tokens.shape
    x = embed(params["embed"], tokens)
    x = dctx.constrain(x, "batch", "seq", "embed")
    positions = jnp.arange(s, dtype=jnp.int32)
    new_cache = {} if mode in ("prefill", "decode") else None

    def body(carry, layer_in):
        x = carry
        lp, lcache = layer_in
        c = lcache["self"] if lcache is not None else None
        h, nc = attn.attention_block(lp["attn"],
                                     rms_norm(lp["ln1"], x, cfg.norm_eps),
                                     cfg, positions, cache=c, mode=mode,
                                     cache_capacity=cache_capacity)
        x = x + h
        xk = (lcache["xkv"] if lcache is not None and "xkv" in lcache
              else _enc_kv(lp["xattn"], enc_out, cfg))
        h2 = _cross_attention(lp["xattn"],
                              rms_norm(lp["ln2"], x, cfg.norm_eps), xk, cfg)
        x = x + h2
        x = x + mlp(lp["mlp"], rms_norm(lp["ln3"], x, cfg.norm_eps), "gelu",
                    precision=cfg.precision, backend=cfg.gemm_backend,
                    config=cfg.resolved_kernel_config)
        out_cache = None
        if mode != "train":
            out_cache = {"self": nc, "xkv": xk}
        return x, out_cache

    fn = body
    if cfg.remat and mode == "train":
        fn = jax.checkpoint(body,
                            policy=jax.checkpoint_policies.nothing_saveable)

    layer_cache = cache.get("layers") if cache else None
    if layer_cache is not None:
        x, caches = jax.lax.scan(fn, x, (params["layers"], layer_cache))
    else:
        x, caches = jax.lax.scan(lambda c, lp: fn(c, (lp, None)), x,
                                 params["layers"])
    if new_cache is not None:
        new_cache["layers"] = caches
        new_cache["enc_out"] = enc_out

    if mode == "prefill":
        x = x[:, -1:]        # serving prefill needs only the last position
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x)
    return logits, new_cache, jnp.zeros((), jnp.float32)


def whisper_init_cache(params, frames, cfg: ModelConfig, batch, seq_len):
    """Decode cache: encoder output + per-layer self KV + cross KV."""
    enc_out = whisper_encode(params, frames, cfg)

    def one_layer(lp):
        return {"self": attn.init_kv_cache(cfg, batch, seq_len),
                "xkv": _enc_kv(lp["xattn"], enc_out, cfg)}

    layers = jax.vmap(one_layer)(params["layers"])
    return {"layers": layers, "enc_out": enc_out}


def whisper_loss(params, batch, cfg: ModelConfig, *, aux_weight=0.0):
    logits, _, _ = whisper_forward(params, batch["tokens"], batch["frames"],
                                   cfg, mode="train")
    loss = cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
    return loss, {"ce": loss, "aux": jnp.zeros(())}
