"""xLSTM blocks: mLSTM (matrix memory) + sLSTM (scalar memory).

TPU adaptation (DESIGN.md): the mLSTM recurrence is evaluated in the
*chunkwise-parallel* form — within a chunk the contribution is a masked,
decay-weighted attention-like matmul (MXU work); across chunks a scan
carries the (C, n) state.  This is the standard TPU-native formulation of
matrix-memory RNNs; a per-timestep sequential scan would serialize the MXU.

Numerics simplification (documented): sigmoid input/forget gates (GLA-style)
instead of the paper's exponential gating + stabilizer; decays stay in
log-space and are <= 0 so no overflow is possible.  Decode is an O(1) state
update; the long_500k cell runs with constant memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ninit
from repro.distributed.context import constrain


def init_mlstm(key, cfg, dtype):
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 7)
    return {
        "wq": ninit(ks[0], (d, h * hd), d ** -0.5, dtype),
        "wk": ninit(ks[1], (d, h * hd), d ** -0.5, dtype),
        "wv": ninit(ks[2], (d, h * hd), d ** -0.5, dtype),
        "w_if": ninit(ks[3], (d, 2 * h), d ** -0.5, jnp.float32),
        "w_og": ninit(ks[4], (d, h * hd), d ** -0.5, dtype),
        "wo": ninit(ks[5], (h * hd, d), (h * hd) ** -0.5, dtype),
    }


def mlstm_apply(p, x, *, state=None, chunk=256):
    """x: [B,S,d] -> (y, state={C:[B,H,dk,dv], n:[B,H,dk]})."""
    b, s, d = x.shape
    hhd = p["wq"].shape[1]
    h = p["w_if"].shape[1] // 2
    hd = hhd // h
    scale = hd ** -0.5

    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(x.dtype))
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)     # [B,H,S,D]
    k = k.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    gates = (x.astype(jnp.float32) @ p["w_if"])          # [B,S,2H]
    log_f = -jax.nn.softplus(-gates[..., :h]).transpose(0, 2, 1)  # log σ
    i_g = jax.nn.sigmoid(gates[..., h:]).transpose(0, 2, 1)       # [B,H,S]

    if state is None:
        state = init_mlstm_state_like(b, h, hd)

    if s == 1:  # decode: O(1) recurrent update
        c_prev, n_prev = state["C"], state["n"]
        f = jnp.exp(log_f[..., 0])[..., None]            # [B,H,1]
        i0 = i_g[..., 0][..., None]
        kv = jnp.einsum("bhk,bhv->bhkv", k[:, :, 0].astype(jnp.float32),
                        v[:, :, 0].astype(jnp.float32))
        c_new = f[..., None] * c_prev + i0[..., None] * kv
        n_new = f * n_prev + i0 * k[:, :, 0].astype(jnp.float32)
        qf = q[:, :, 0].astype(jnp.float32) * scale
        num = jnp.einsum("bhk,bhkv->bhv", qf, c_new)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n_new))
        y = num / jnp.maximum(den, 1.0)[..., None]
        ys = y[:, :, None]                               # [B,H,1,D]
        new_state = {"C": c_new, "n": n_new}
    else:
        t = min(chunk, s)
        assert s % t == 0, (s, t)
        nc = s // t

        def chunk_step(carry, xs):
            c_prev, n_prev = carry                       # [B,H,dk,dv],[B,H,dk]
            qc, kc, vc, lfc, ic = xs                     # [B,H,T,...]
            kcf = kc.astype(jnp.float32)
            vcf = vc.astype(jnp.float32)
            qf = qc.astype(jnp.float32) * scale
            bcum = jnp.cumsum(lfc, axis=-1)              # [B,H,T], <= 0
            btot = bcum[..., -1:]
            # intra-chunk: decay-weighted causal linear attention (MXU)
            rel = bcum[..., :, None] - bcum[..., None, :]    # b_j - b_k
            causal = jnp.tril(jnp.ones((t, t), bool))
            # mask BEFORE exp: acausal rel is positive and can overflow;
            # inf * 0 in the VJP would poison gradients
            rel = jnp.where(causal, rel, 0.0)
            w_jk = jnp.where(causal, jnp.exp(rel) * ic[..., None, :], 0.0)
            sjk = jnp.einsum("bhjd,bhkd->bhjk", qf, kcf)
            intra = jnp.einsum("bhjk,bhkd->bhjd", sjk * w_jk, vcf)
            # inter-chunk: read carried state with per-position decay
            dec = jnp.exp(bcum)                          # <= 1
            inter = jnp.einsum("bhjk,bhkv->bhjv", qf * dec[..., None], c_prev)
            # normalizer at each position
            n_intra = jnp.einsum("bhjk,bhkd->bhjd", w_jk, kcf)
            n_j = dec[..., None] * n_prev[:, :, None, :] + n_intra
            den = jnp.abs(jnp.einsum("bhjd,bhjd->bhj", qf, n_j))
            yc = (intra + inter) / jnp.maximum(den, 1.0)[..., None]
            # carry state to end of chunk
            wk_end = jnp.exp(btot - bcum) * ic           # [B,H,T], <= 1
            kv = jnp.einsum("bhtk,bhtv->bhkv", kcf * wk_end[..., None], vcf)
            c_new = jnp.exp(btot)[..., None] * c_prev + kv
            n_new = jnp.exp(btot) * n_prev + jnp.sum(
                kcf * wk_end[..., None], axis=2)
            return (c_new, n_new), yc

        def split(a):  # [B,H,S,...] -> [nc,B,H,T,...]
            return jnp.moveaxis(a.reshape(b, h, nc, t, *a.shape[3:]), 2, 0)

        xs = (split(q), split(k), split(v), split(log_f), split(i_g))
        (c_new, n_new), ys = jax.lax.scan(
            chunk_step, (state["C"], state["n"]), xs)    # ys: [nc,B,H,T,D]
        ys = jnp.moveaxis(ys, 0, 2).reshape(b, h, s, hd)
        new_state = {"C": c_new, "n": n_new}

    merged = ys.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    og = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                                   p["w_og"].astype(jnp.float32)))
    out = (og * merged.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bse,ed->bsd", out, p["wo"].astype(x.dtype))
    return constrain(y, "batch", "seq", "embed"), new_state


def init_mlstm_state_like(b, h, hd):
    return {"C": jnp.zeros((b, h, hd, hd), jnp.float32),
            "n": jnp.zeros((b, h, hd), jnp.float32)}


def init_mlstm_state(cfg, batch):
    return init_mlstm_state_like(batch, cfg.num_heads, cfg.resolved_head_dim)


# ---------------------------------------------------------------------------
# sLSTM — scalar memory, sequential scan (elementwise; cheap)
# ---------------------------------------------------------------------------

def init_slstm(key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    return {
        "w_z": ninit(ks[0], (d, d), d ** -0.5, dtype),
        "w_if": ninit(ks[1], (d, 2 * d), d ** -0.5, jnp.float32),
        "w_og": ninit(ks[2], (d, d), d ** -0.5, dtype),
        "wo": ninit(ks[3], (d, d), d ** -0.5, dtype),
    }


def slstm_apply(p, x, *, state=None):
    """x: [B,S,d] -> (y, state={c:[B,d], n:[B,d]})."""
    b, s, d = x.shape
    z = jnp.tanh(jnp.einsum("bsd,de->bse", x, p["w_z"].astype(x.dtype))
                 .astype(jnp.float32))
    gates = x.astype(jnp.float32) @ p["w_if"]
    f = jax.nn.sigmoid(gates[..., :d])
    i = jax.nn.sigmoid(gates[..., d:])
    if state is None:
        state = init_slstm_state_like(b, d)

    def step(carry, xs):
        c, n = carry
        ft, it, zt = xs
        c = ft * c + it * zt
        n = ft * n + it
        h = c / jnp.maximum(n, 1.0)
        return (c, n), h

    (c_f, n_f), hs = jax.lax.scan(
        step, (state["c"], state["n"]),
        (f.swapaxes(0, 1), i.swapaxes(0, 1), z.swapaxes(0, 1)))
    hs = hs.swapaxes(0, 1)                               # [B,S,d]
    og = jax.nn.sigmoid(x.astype(jnp.float32) @ p["w_og"].astype(jnp.float32))
    out = (og * hs).astype(x.dtype)
    y = jnp.einsum("bse,ed->bsd", out, p["wo"].astype(x.dtype))
    return constrain(y, "batch", "seq", "embed"), {"c": c_f, "n": n_f}


def init_slstm_state_like(b, d):
    return {"c": jnp.zeros((b, d), jnp.float32),
            "n": jnp.zeros((b, d), jnp.float32)}


def init_slstm_state(cfg, batch):
    return init_slstm_state_like(batch, cfg.d_model)
