"""Process-wide distribution context.

The launcher (or dryrun) sets the mesh once; model code calls
:func:`constrain` to attach logical-axis sharding constraints to
activations.  With no mesh set, everything is a no-op so the same model
code runs single-device in tests.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None

# logical activation axis -> mesh axes (None = replicated)
_DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,          # activations replicated over `model` between ops
    "heads": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "kv_seq": "model",      # decode KV caches: sequence-sharded (flash-decode)
}
_RULES = dict(_DEFAULT_RULES)


def set_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None) -> None:
    global _MESH, _RULES
    _MESH = mesh
    _RULES = dict(_DEFAULT_RULES)
    if rules:
        _RULES.update(rules)


def get_mesh() -> Optional[Mesh]:
    return _MESH


def model_axis_size() -> int:
    if _MESH is None or "model" not in _MESH.axis_names:
        return 1
    return _MESH.shape["model"]


def _axes_for(logical: Optional[str]):
    if logical is None:
        return None
    return _RULES.get(logical)


def spec_for(shape, logical_axes) -> P:
    """PartitionSpec for `shape` given per-dim logical names, dropping any
    axis that does not divide the dim (GQA kv-head replication etc.).
    A mesh axis is used at most once per spec; feature axes (heads/mlp/
    vocab/...) take priority over "seq" (sequence parallelism is applied
    only where it doesn't conflict)."""
    if _MESH is None:
        return P()
    parts = [None] * len(shape)
    used: set = set()

    def try_assign(i, name):
        axes = _axes_for(name)
        if axes is None:
            return
        tup = axes if isinstance(axes, tuple) else (axes,)
        tup = tuple(a for a in tup if a in _MESH.axis_names
                    and a not in used)
        size = 1
        for a in tup:
            size *= _MESH.shape[a]
        if size > 1 and shape[i] % size == 0:
            parts[i] = tup if len(tup) > 1 else tup[0]
            used.update(tup)

    order = [i for i, n in enumerate(logical_axes) if n not in (None, "seq")]
    order += [i for i, n in enumerate(logical_axes) if n == "seq"]
    for i in order:
        try_assign(i, logical_axes[i])
    return P(*parts)


def constrain(x, *logical_axes):
    """with_sharding_constraint by logical axis names; no-op without mesh."""
    if _MESH is None:
        return x
    spec = spec_for(x.shape, logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))
