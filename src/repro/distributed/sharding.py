"""Parameter partition rules (logical-name based, MaxText-style).

``build_param_specs`` walks a parameter pytree and assigns a PartitionSpec
per leaf from its path + rank, with a divisibility guard (dims that don't
divide the axis are replicated — e.g. 4 KV heads on a 16-way model axis).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (path regex, spec for the logical [unstacked] shape)
# weight naming is a repo-wide convention (models/layers.py)
_RULES_2D = [
    (r"(^|/)(wq|wk|wv)$", P(None, "model")),
    (r"(^|/)wo$", P("model", None)),
    (r"(^|/)(w_gate|w_up)$", P(None, "model")),
    (r"(^|/)w_down$", P("model", None)),
    (r"(^|/)shared_(gate|up)$", P(None, "model")),
    (r"(^|/)shared_down$", P("model", None)),
    (r"(^|/)embedding$", P("model", None)),
    (r"(^|/)lm_head$", P(None, "model")),
    (r"(^|/)router$", P()),
    (r"(^|/)vision_proj$", P()),
    (r"(^|/)(w_in|w_x|w_y)$", P(None, "model")),     # recurrent in-projs
    (r"(^|/)w_out$", P("model", None)),              # recurrent out-proj
]
_RULES_1D = [
    (r"(^|/)b[qkv]$", P("model")),
    (r"(^|/)(b_in|b_x|b_y)$", P("model")),
]
# MoE 3-D experts tensors: EP shards dim0 (experts); TP shards the d_ff dim
_MOE_3D = {
    "w_gate": {"ep": P("model", None, None), "tp": P(None, None, "model")},
    "w_up": {"ep": P("model", None, None), "tp": P(None, None, "model")},
    "w_down": {"ep": P("model", None, None), "tp": P(None, "model", None)},
}


def _leaf_spec(path: str, ndim: int, moe_mode: str) -> P:
    last = path.rsplit("/", 1)[-1]
    if "/moe/" in path or path.startswith("moe/"):
        if last in _MOE_3D and ndim >= 3:
            return _MOE_3D[last][moe_mode]
        for pat, spec in _RULES_2D + _RULES_1D:
            if re.search(pat, path):
                return spec
        return P()
    rules = _RULES_2D if ndim >= 2 else _RULES_1D
    for pat, spec in rules:
        if re.search(pat, path):
            return spec
    return P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def build_param_specs(params: Any, mesh: Mesh, *, moe_mode: str = "ep",
                      fsdp: bool = False, fsdp_min_size: int = 1 << 20):
    """PartitionSpec pytree for `params` (STORAGE sharding).

    Leaves under a path component named ``layers``/``enc_layers`` etc. are
    scan-stacked: their leading dim is the layer axis -> spec gets a leading
    None.  Dims that don't divide their assigned axes get replicated.

    ``fsdp=True`` additionally shards the largest remaining unsharded dim
    of every big weight over the ``data`` axis (ZeRO-3 storage: params,
    grads and optimizer state all live data-sharded; GSPMD inserts the
    per-layer all-gather at use and reduce-scatter on the gradients).
    shard_map consumers (the MoE block) declare their own compute specs, so
    the boundary resharding is automatic.
    """
    stack_markers = ("layers",)

    def spec_of(path, leaf):
        p = _path_str(path)
        stacked = any(f"{m}" in p.split("/") for m in stack_markers)
        ndim = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
        logical_ndim = ndim - (1 if stacked else 0)
        spec = _leaf_spec(p, logical_ndim, moe_mode)
        parts = list(spec) + [None] * (logical_ndim - len(spec))
        if stacked:
            parts = [None] + parts
        shape = leaf.shape
        out = []
        for dim, ax in zip(shape, parts):
            if ax is None:
                out.append(None)
                continue
            tup = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in tup:
                size *= mesh.shape[a]
            out.append(ax if dim % size == 0 else None)
        out += [None] * (ndim - len(out))
        # FSDP: prefer extending an already model-sharded dim with 'data'
        # (keeps activation-facing dims unsharded -> no involuntary
        # resharding at the embedding gather); else shard the largest
        # still-unsharded divisible dim.
        if fsdp and "data" in mesh.axis_names and ndim >= 2 and \
                np.prod(shape) >= fsdp_min_size:
            dsz = mesh.shape["data"]
            ext = [i for i in range(ndim)
                   if out[i] == "model"
                   and shape[i] % (dsz * mesh.shape["model"]) == 0]
            if ext:
                out[ext[0]] = ("model", "data")
            else:
                cands = sorted((i for i in range(ndim)
                                if out[i] is None and shape[i] % dsz == 0),
                               key=lambda i: -shape[i])
                if cands:
                    out[cands[0]] = "data"
        return P(*out)

    return jax.tree_util.tree_map_with_path(spec_of, params)


def named_shardings(params, mesh: Mesh, *, moe_mode: str = "ep"):
    specs = build_param_specs(params, mesh, moe_mode=moe_mode)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
