"""Training step: microbatch gradient accumulation (lax.scan), AdamW,
and jit with parameter donation.

The global batch [G, S] is reshaped to [accum, G/accum, S]; grads
accumulate in f32 across the scan — one optimizer apply and (under GSPMD)
one gradient all-reduce per step, overlapped by XLA's latency-hiding
scheduler with the last microbatch's backward.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels import plan as plan_mod
from repro.optim import adamw


def make_train_step(loss_fn: Callable, opt_cfg: adamw.OptConfig,
                    grad_accum: int = 1, donate: bool = True,
                    kernel_config: Optional[plan_mod.KernelConfig] = None,
                    wgrad_precision: Optional[str] = None):
    """loss_fn(params, batch) -> (loss, metrics dict of scalars).

    ``kernel_config`` pins tuned tile shapes (an autotuned
    :class:`~repro.kernels.plan.KernelConfig`) for every grouped/linear
    GEMM traced under this step — models that don't carry an explicit
    config resolve to it via the plan module's default-config seam.

    ``wgrad_precision`` selects the training recipe from the run config:
    ``"fp8"`` opts every fp8 grouped GEMM's backward into the all-fp8
    wgrad (arXiv 2505.20524); ``None``/``"bf16"`` keeps the DeepSeek
    default.  It folds into ``kernel_config`` (or the installed/per-device
    default when none is pinned) through the same seam — models that pin
    an explicit ``ModelConfig.kernel_config``/``wgrad_precision`` keep
    their own setting.
    """
    if kernel_config is not None or wgrad_precision is not None:
        inner_loss = loss_fn

        def loss_fn(params, batch):
            cfg = plan_mod.resolve_config(kernel_config,
                                          wgrad_precision=wgrad_precision)
            with plan_mod.default_config(cfg):
                return inner_loss(params, batch)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def reshape(x):
                b = x.shape[0]
                return x.reshape(grad_accum, b // grad_accum, *x.shape[1:])
            micro = jax.tree.map(reshape, batch)

            def micro_step(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                gsum = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (gsum, lsum), _ = jax.lax.scan(micro_step, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
            metrics = {}

        params, opt_state, opt_metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return params, opt_state, metrics

    return train_step


def jit_train_step(train_step, mesh=None, param_shardings=None,
                   opt_shardings=None, batch_shardings=None):
    donate = (0, 1)
    if mesh is None:
        return jax.jit(train_step, donate_argnums=donate)
    return jax.jit(
        train_step,
        in_shardings=(param_shardings, opt_shardings, batch_shardings),
        out_shardings=(param_shardings, opt_shardings, None),
        donate_argnums=donate)
