#!/usr/bin/env bash
# Tier-1 regression gate: the full suite on CPU.
#
# Runs everywhere (no accelerator needed): the Pallas kernels execute in
# interpret mode, TPU-only backends are refused via capability probes (and
# their tests select CPU-runnable backends), and repro.compat absorbs JAX
# API drift across the supported range (see README.md).
#
#   scripts/ci_tier1.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q "$@"

# Bench entry points must not rot: one tiny interpret-mode shape through
# bench_grouped_gemm's CLI (exercises the autotuner pool selection + the
# JSON cache write path; cache goes to a throwaway location).
REPRO_TILEPLAN_CACHE="$(mktemp -d)/tileplan_cache.json" \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_grouped_gemm --smoke --backend pallas_interpret
