#!/usr/bin/env bash
# Tier-1 regression gate: the full suite on CPU.
#
# Runs everywhere (no accelerator needed): the Pallas kernels execute in
# interpret mode, TPU-only backends are refused via capability probes (and
# their tests select CPU-runnable backends), and repro.compat absorbs JAX
# API drift across the supported range (see README.md).
#
#   scripts/ci_tier1.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q "$@"

# Bench entry points must not rot: one tiny interpret-mode shape through
# bench_grouped_gemm's CLI (exercises the autotuner pool selection + the
# JSON cache write path for BOTH op families — gemm and wgrad; cache goes
# to a throwaway location).
REPRO_TILEPLAN_CACHE="$(mktemp -d)/tileplan_cache.json" \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_grouped_gemm --smoke --backend pallas_interpret

# Backward regression gate: jax.grad through grouped_linear on the kernel
# path (both precisions) with a partially-filled capacity buffer — the fp8
# VJP must keep dgrad AND wgrad padding-free and its dx tail exactly zero
# (the unowned-row corruption this repo once shipped).
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'EOF'
import numpy as np, jax, jax.numpy as jnp
from repro.core.grouped_gemm import grouped_linear

rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
w = jnp.asarray(rng.standard_normal((3, 128, 128)), jnp.float32)
gs = jnp.asarray([60, 0, 30], jnp.int32)          # sum=90 < 256

gw_fp8 = None
for precision in ("fp8", "bf16"):
    kw = {"backend": "pallas_interpret"} if precision == "fp8" else {}
    def loss(x, w):
        y = grouped_linear(x, w, gs, precision=precision, **kw)
        return jnp.sum(y.astype(jnp.float32) ** 2)
    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert bool(jnp.isfinite(gx).all()) and bool(jnp.isfinite(gw).all()), precision
    if precision == "fp8":
        assert np.all(np.asarray(gx[90:]) == 0.0), "fp8 tail dx must be zero"
        gw_fp8 = gw          # fp8 forward + bf16 wgrad: the recipe baseline
    assert float(jnp.abs(gw[1]).max()) == 0.0, f"{precision}: empty-group dw"
    print(f"grad smoke [{precision}] OK")

# All-fp8 step: the fp8-operand wgrad (wgrad_precision="fp8") must stay
# finite, keep the tail-dx/empty-group guarantees, and agree with the
# SAME fp8 forward's bf16 wgrad within fp8 quantization tolerance — the
# baseline is gw_fp8 (fp8 fwd + bf16 wgrad), so the deviation isolates
# the wgrad's operand precision, not the forward's quantization noise.
def loss8(x, w):
    y = grouped_linear(x, w, gs, precision="fp8", backend="pallas_interpret",
                       wgrad_precision="fp8")
    return jnp.sum(y.astype(jnp.float32) ** 2)
gx8, gw8 = jax.grad(loss8, argnums=(0, 1))(x, w)
assert bool(jnp.isfinite(gx8).all()) and bool(jnp.isfinite(gw8).all())
assert np.all(np.asarray(gx8[90:]) == 0.0), "fp8-wgrad tail dx must be zero"
assert float(jnp.abs(gw8[1]).max()) == 0.0, "fp8-wgrad empty-group dw"
rel = (np.abs(np.asarray(gw8) - np.asarray(gw_fp8)).max()
       / max(np.abs(np.asarray(gw_fp8)).max(), 1e-6))
assert rel < 0.1, f"fp8 wgrad deviates {rel:.3f} from bf16 wgrad"
print("grad smoke [fp8 wgrad_precision=fp8] OK")
EOF

# Contract gate: the static-analysis subsystem replaces the historical
# monkeypatch-count gates (quantize-once, producer-fusion, decode plan
# discipline) with declarative contracts + registry/AST lint:
#   layer 1 — jaxpr contracts over grouped_linear{,_fused,_ffn}, moe_apply
#             and one real Engine generate (REPRO-C01..C06)
#   layer 2 — operator-registry + tile-pool alignment lint (REPRO-R01..R07)
#   layer 3 — AST lint over src/repro (REPRO-A01..A03)
#   layer 4 — static kernel-resource lint: VMEM/alignment budget proofs
#             for every operator family x pool entry x device
#             (REPRO-V01..V07, kernels/resources.py)
#   layer 5 — retrace detector: compile contracts proving the jitted hot
#             paths (grouped_linear{,_ffn} steps, Engine.generate, the
#             padded baseline) compile exactly once per shape/phase/bucket
#             (REPRO-T01..T03)
# Fails on any finding not in the checked-in (empty) baseline.
REPRO_TILEPLAN_CACHE="$(mktemp -d)/tileplan_cache.json" \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.analysis --all --baseline scripts/analysis_baseline.json

# Fused-epilogue gate: the (act_quant, fp8) pass must stay bitwise
# identical to the jitted unfused composition (activation, then the
# tilewise quantize kernel), for BOTH activation variants, and the fused
# grouped linear's value+grad must match the unfused pair exactly.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'EOF'
import numpy as np, jax, jax.numpy as jnp
from repro.core.grouped_gemm import grouped_linear, grouped_linear_fused
from repro.kernels.epilogue_kernel import _act_f32, act_quantize_pallas
from repro.kernels.plan import KernelConfig
from repro.kernels.quant_kernel import quantize_tilewise_pallas

rng = np.random.default_rng(0)
g = jnp.asarray(rng.standard_normal((200, 256)), jnp.float32)
u = jnp.asarray(rng.standard_normal((200, 256)), jnp.float32)
for act, uu in (("silu_mul", u), ("gelu", None)):
    q8, s = act_quantize_pallas(g, uu, act=act, interpret=True)
    h = jax.jit(lambda *a: _act_f32(*a, act))(g, uu)
    q8c, sc = quantize_tilewise_pallas(h, interpret=True)
    assert np.array_equal(np.asarray(q8, np.float32),
                          np.asarray(q8c, np.float32)), act
    assert np.array_equal(np.asarray(s), np.asarray(sc)), act
    print(f"fused epilogue bitwise [{act}] OK")

gs = jnp.asarray([60, 0, 130], jnp.int32)
w = jnp.asarray(rng.standard_normal((3, 256, 128)), jnp.float32)
cfg = KernelConfig(backend="pallas_interpret", wgrad_precision="fp8")
lf, gf = jax.value_and_grad(lambda g, u, w: jnp.sum(
    grouped_linear_fused(g, u, w, gs, config=cfg) ** 2), (0, 1, 2))(g, u, w)
lu, gu = jax.value_and_grad(lambda g, u, w: jnp.sum(
    grouped_linear(_act_f32(g, u, "silu_mul"), w, gs, precision="fp8",
                   config=cfg) ** 2), (0, 1, 2))(g, u, w)
assert float(lf) == float(lu), (float(lf), float(lu))
for a, b, name in zip(gf, gu, ("dg", "du", "dw")):
    assert np.array_equal(np.asarray(a), np.asarray(b)), name
print("fused grouped linear value+grad parity OK")
EOF

# Tiny-M decode bench path must not rot either (cost-model selection —
# the CI gate exercises the CLI + decode pool, not kernel timing).
REPRO_TILEPLAN_CACHE="$(mktemp -d)/tileplan_cache.json" \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_grouped_gemm --decode --smoke \
        --backend pallas_interpret

# Producer bench path: the fused gemm_quant CLI (autotune pool for the
# gemm_quant op family + the fused-vs-unfused comparison columns).
REPRO_TILEPLAN_CACHE="$(mktemp -d)/tileplan_cache.json" \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_grouped_gemm --gemm-quant --smoke \
        --backend pallas_interpret

# Full pinned suite (smoke shapes) + regression diff against the
# committed snapshot.  --smoke row names are a strict subset of the full
# suite's, so bench_diff matches by name; the generous threshold makes
# this a rot gate across heterogeneous CI machines (every suite must
# still produce its measured rows, and none may be catastrophically
# slower) — same-machine perf trajectories use the default 10%.
BENCH_SMOKE_JSON="$(mktemp -d)/bench_smoke.json"
REPRO_TILEPLAN_CACHE="$(mktemp -d)/tileplan_cache.json" \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run --smoke --json "$BENCH_SMOKE_JSON"
python scripts/bench_diff.py BENCH_2026-08-08.json "$BENCH_SMOKE_JSON" \
    --threshold 3.0
