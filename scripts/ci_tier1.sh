#!/usr/bin/env bash
# Tier-1 regression gate: the full suite on CPU.
#
# Runs everywhere (no accelerator needed): the Pallas kernels execute in
# interpret mode, TPU-only backends are refused via capability probes (and
# their tests select CPU-runnable backends), and repro.compat absorbs JAX
# API drift across the supported range (see README.md).
#
#   scripts/ci_tier1.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q "$@"

# Bench entry points must not rot: one tiny interpret-mode shape through
# bench_grouped_gemm's CLI (exercises the autotuner pool selection + the
# JSON cache write path for BOTH op families — gemm and wgrad; cache goes
# to a throwaway location).
REPRO_TILEPLAN_CACHE="$(mktemp -d)/tileplan_cache.json" \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_grouped_gemm --smoke --backend pallas_interpret

# Backward regression gate: jax.grad through grouped_linear on the kernel
# path (both precisions) with a partially-filled capacity buffer — the fp8
# VJP must keep dgrad AND wgrad padding-free and its dx tail exactly zero
# (the unowned-row corruption this repo once shipped).
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'EOF'
import numpy as np, jax, jax.numpy as jnp
from repro.core.grouped_gemm import grouped_linear

rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
w = jnp.asarray(rng.standard_normal((3, 128, 128)), jnp.float32)
gs = jnp.asarray([60, 0, 30], jnp.int32)          # sum=90 < 256

for precision in ("fp8", "bf16"):
    kw = {"backend": "pallas_interpret"} if precision == "fp8" else {}
    def loss(x, w):
        y = grouped_linear(x, w, gs, precision=precision, **kw)
        return jnp.sum(y.astype(jnp.float32) ** 2)
    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert bool(jnp.isfinite(gx).all()) and bool(jnp.isfinite(gw).all()), precision
    if precision == "fp8":
        assert np.all(np.asarray(gx[90:]) == 0.0), "fp8 tail dx must be zero"
    assert float(jnp.abs(gw[1]).max()) == 0.0, f"{precision}: empty-group dw"
    print(f"grad smoke [{precision}] OK")
EOF
