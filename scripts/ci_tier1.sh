#!/usr/bin/env bash
# Tier-1 regression gate: the full suite on CPU.
#
# Runs everywhere (no accelerator needed): the Pallas kernels execute in
# interpret mode, TPU-only backends are refused via capability probes (and
# their tests select CPU-runnable backends), and repro.compat absorbs JAX
# API drift across the supported range (see README.md).
#
#   scripts/ci_tier1.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q "$@"

# Bench entry points must not rot: one tiny interpret-mode shape through
# bench_grouped_gemm's CLI (exercises the autotuner pool selection + the
# JSON cache write path for BOTH op families — gemm and wgrad; cache goes
# to a throwaway location).
REPRO_TILEPLAN_CACHE="$(mktemp -d)/tileplan_cache.json" \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_grouped_gemm --smoke --backend pallas_interpret

# Backward regression gate: jax.grad through grouped_linear on the kernel
# path (both precisions) with a partially-filled capacity buffer — the fp8
# VJP must keep dgrad AND wgrad padding-free and its dx tail exactly zero
# (the unowned-row corruption this repo once shipped).
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'EOF'
import numpy as np, jax, jax.numpy as jnp
from repro.core.grouped_gemm import grouped_linear

rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
w = jnp.asarray(rng.standard_normal((3, 128, 128)), jnp.float32)
gs = jnp.asarray([60, 0, 30], jnp.int32)          # sum=90 < 256

gw_fp8 = None
for precision in ("fp8", "bf16"):
    kw = {"backend": "pallas_interpret"} if precision == "fp8" else {}
    def loss(x, w):
        y = grouped_linear(x, w, gs, precision=precision, **kw)
        return jnp.sum(y.astype(jnp.float32) ** 2)
    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert bool(jnp.isfinite(gx).all()) and bool(jnp.isfinite(gw).all()), precision
    if precision == "fp8":
        assert np.all(np.asarray(gx[90:]) == 0.0), "fp8 tail dx must be zero"
        gw_fp8 = gw          # fp8 forward + bf16 wgrad: the recipe baseline
    assert float(jnp.abs(gw[1]).max()) == 0.0, f"{precision}: empty-group dw"
    print(f"grad smoke [{precision}] OK")

# All-fp8 step: the fp8-operand wgrad (wgrad_precision="fp8") must stay
# finite, keep the tail-dx/empty-group guarantees, and agree with the
# SAME fp8 forward's bf16 wgrad within fp8 quantization tolerance — the
# baseline is gw_fp8 (fp8 fwd + bf16 wgrad), so the deviation isolates
# the wgrad's operand precision, not the forward's quantization noise.
def loss8(x, w):
    y = grouped_linear(x, w, gs, precision="fp8", backend="pallas_interpret",
                       wgrad_precision="fp8")
    return jnp.sum(y.astype(jnp.float32) ** 2)
gx8, gw8 = jax.grad(loss8, argnums=(0, 1))(x, w)
assert bool(jnp.isfinite(gx8).all()) and bool(jnp.isfinite(gw8).all())
assert np.all(np.asarray(gx8[90:]) == 0.0), "fp8-wgrad tail dx must be zero"
assert float(jnp.abs(gw8[1]).max()) == 0.0, "fp8-wgrad empty-group dw"
rel = (np.abs(np.asarray(gw8) - np.asarray(gw_fp8)).max()
       / max(np.abs(np.asarray(gw_fp8)).max(), 1e-6))
assert rel < 0.1, f"fp8 wgrad deviates {rel:.3f} from bf16 wgrad"
print("grad smoke [fp8 wgrad_precision=fp8] OK")

# Quantize-once gate: ONE tilewise quantization of the shared activation
# buffer serves the MoE gate+up forward, the down projection's silu·mul+
# quantize runs as a fused (act_quant, fp8) pass (zero standalone
# quantizes of h), and the backward's fp8 wgrad reuses the residuals
# instead of re-quantizing.
from repro.core import moe as moe_mod
from repro.core import quantization as qz
from repro.kernels.plan import KernelConfig
cfg = moe_mod.MoEConfig(num_experts=4, top_k=2, d_model=128, d_ff_expert=256,
                        precision="fp8", backend="pallas_interpret",
                        kernel_config=KernelConfig(wgrad_precision="fp8"))
params = moe_mod.init_moe_params(jax.random.PRNGKey(0), cfg)
xt = jax.random.normal(jax.random.PRNGKey(1), (32, 128))
cap = moe_mod._capacity(32 * cfg.top_k, 1, cfg.capacity_factor)
calls, real = [], qz.quantize_tilewise
qz.quantize_tilewise = lambda a, **kw: calls.append(a.shape) or real(a, **kw)
try:
    jax.grad(lambda p, x: jnp.mean(
        moe_mod.moe_apply(p, x, cfg)[0].astype(jnp.float32) ** 2),
        argnums=(0, 1))(params, xt)
finally:
    qz.quantize_tilewise = real
xs_like = [s for s in calls if s == (cap, cfg.d_model)]
# 4 = the shared xs once (forward) + one dy per GEMM backward (gate, up,
# down).  The silu·mul activation h is NEVER tilewise-quantized standalone
# — the fused epilogue emits q+scales in one pass and the fp8 wgrad reuses
# them as its residual.  (cap, d_model): the xs once + the down dy once.
assert len(calls) == 4 and len(xs_like) == 2, \
    f"quantize-once violated: {calls}"
print("quantize-once count OK")
EOF

# Producer-fusion gate: with KernelConfig(fuse_producer=True) the gate/up
# projections run as (gemm_quant, fp8) — the GEMM's store phase emits the
# fp8 payload + 1x128 scales directly, so g and u are NEVER standalone
# tilewise-quantized, in the forward OR the backward.  This tightens the
# PR 6 pin above: same 4 total quantizes over fwd+bwd, but the forward is
# now exactly ONE (the shared xs) with zero (cap, d_ff)-shaped calls, and
# the fused path must actually route through grouped_gemm_quant.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'EOF'
import jax, jax.numpy as jnp
from repro.core import moe as moe_mod
from repro.core import quantization as qz
from repro.kernels import dispatch
from repro.kernels.plan import KernelConfig

cfg = moe_mod.MoEConfig(num_experts=4, top_k=2, d_model=128, d_ff_expert=256,
                        precision="fp8", backend="pallas_interpret",
                        kernel_config=KernelConfig(wgrad_precision="fp8",
                                                   fuse_producer=True))
params = moe_mod.init_moe_params(jax.random.PRNGKey(0), cfg)
xt = jax.random.normal(jax.random.PRNGKey(1), (32, 128))
cap = moe_mod._capacity(32 * cfg.top_k, 1, cfg.capacity_factor)

calls, quant_gemms = [], []
real_q, real_gq = qz.quantize_tilewise, dispatch.grouped_gemm_quant
qz.quantize_tilewise = lambda a, **kw: calls.append(a.shape) or real_q(a, **kw)
dispatch.grouped_gemm_quant = lambda *a, **kw: quant_gemms.append(()) or \
    real_gq(*a, **kw)
try:
    moe_mod.moe_apply(params, xt, cfg)
    ff_like = [s for s in calls if s == (cap, cfg.d_ff_expert)]
    # forward: ONE standalone quantize (the shared xs), zero of g/u — the
    # producer GEMM's epilogue emits their fp8 form in the store phase
    assert calls == [(cap, cfg.d_model)], \
        f"fused-producer forward must quantize ONCE (xs): {calls}"
    assert not ff_like, f"standalone quantize of g/u leaked: {calls}"
    assert len(quant_gemms) == 2, \
        f"gate+up must route through grouped_gemm_quant: {len(quant_gemms)}"
    calls.clear(); quant_gemms.clear()
    jax.grad(lambda p, x: jnp.mean(
        moe_mod.moe_apply(p, x, cfg)[0].astype(jnp.float32) ** 2),
        argnums=(0, 1))(params, xt)
    # fwd+bwd: xs + the down dy (d_model) and the activation cotangents
    # dg, du (d_ff) — g/u themselves still never re-quantized
    assert sorted(calls) == [(cap, cfg.d_model), (cap, cfg.d_model),
                             (cap, cfg.d_ff_expert), (cap, cfg.d_ff_expert)], \
        f"fused-producer fwd+bwd quantize floor violated: {calls}"
finally:
    qz.quantize_tilewise, dispatch.grouped_gemm_quant = real_q, real_gq
print("producer-fusion quantize floor OK")
EOF

# Serving decode gate: one Engine resolves ONE decode-specialized
# (block_m<=16) config at construction, and a full generate (prefill +
# >=4 decode steps) builds plan metadata exactly once per phase — the
# decode loop replays its traced plan every step instead of re-planning.
REPRO_TILEPLAN_CACHE="$(mktemp -d)/tileplan_cache.json" \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'EOF'
import dataclasses
import jax
from repro.configs import smoke_config
from repro.kernels import plan as plan_mod
from repro.models.model_zoo import make_model, synthetic_batch
from repro.serve.engine import Engine

cfg = dataclasses.replace(smoke_config("qwen2-moe-a2.7b"),
                          precision="fp8", gemm_backend="pallas_interpret")
model = make_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))

selections, builds = [], []
real_select, real_meta = plan_mod.decode_config, plan_mod.make_group_metadata
plan_mod.decode_config = lambda *a, **kw: selections.append(a) or \
    real_select(*a, **kw)
plan_mod.make_group_metadata = lambda *a, **kw: builds.append(a) or \
    real_meta(*a, **kw)
try:
    engine = Engine(model, params, max_new_tokens=6, decode_batch_size=2)
    assert len(selections) == 1, "decode config must resolve ONCE per engine"
    assert engine.decode_config is not None \
        and engine.decode_config.block_m <= 16, engine.decode_config
    batch = synthetic_batch(jax.random.PRNGKey(1), cfg, 16, 2)
    res = engine.generate(batch, key=jax.random.PRNGKey(42))
    assert res.tokens.shape == (2, 6)
    # two builds per phase: the routed experts' plan + the shared-expert
    # FFN's G=1 plan (the shared FFN runs fp8 since the precision bugfix)
    assert len(builds) == 4, \
        f"expected two plan builds per phase (routed+shared), saw {builds}"
    decode_build = builds[2]
    assert int(decode_build[2]) == engine.decode_config.block_m, decode_build
finally:
    plan_mod.decode_config, plan_mod.make_group_metadata = \
        real_select, real_meta
print(f"decode smoke OK: decode_config=bm{engine.decode_config.block_m}, "
      f"plan builds={len(builds)} (routed+shared per phase)")
EOF

# Fused-epilogue gate: the (act_quant, fp8) pass must stay bitwise
# identical to the jitted unfused composition (activation, then the
# tilewise quantize kernel), for BOTH activation variants, and the fused
# grouped linear's value+grad must match the unfused pair exactly.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'EOF'
import numpy as np, jax, jax.numpy as jnp
from repro.core.grouped_gemm import grouped_linear, grouped_linear_fused
from repro.kernels.epilogue_kernel import _act_f32, act_quantize_pallas
from repro.kernels.plan import KernelConfig
from repro.kernels.quant_kernel import quantize_tilewise_pallas

rng = np.random.default_rng(0)
g = jnp.asarray(rng.standard_normal((200, 256)), jnp.float32)
u = jnp.asarray(rng.standard_normal((200, 256)), jnp.float32)
for act, uu in (("silu_mul", u), ("gelu", None)):
    q8, s = act_quantize_pallas(g, uu, act=act, interpret=True)
    h = jax.jit(lambda *a: _act_f32(*a, act))(g, uu)
    q8c, sc = quantize_tilewise_pallas(h, interpret=True)
    assert np.array_equal(np.asarray(q8, np.float32),
                          np.asarray(q8c, np.float32)), act
    assert np.array_equal(np.asarray(s), np.asarray(sc)), act
    print(f"fused epilogue bitwise [{act}] OK")

gs = jnp.asarray([60, 0, 130], jnp.int32)
w = jnp.asarray(rng.standard_normal((3, 256, 128)), jnp.float32)
cfg = KernelConfig(backend="pallas_interpret", wgrad_precision="fp8")
lf, gf = jax.value_and_grad(lambda g, u, w: jnp.sum(
    grouped_linear_fused(g, u, w, gs, config=cfg) ** 2), (0, 1, 2))(g, u, w)
lu, gu = jax.value_and_grad(lambda g, u, w: jnp.sum(
    grouped_linear(_act_f32(g, u, "silu_mul"), w, gs, precision="fp8",
                   config=cfg) ** 2), (0, 1, 2))(g, u, w)
assert float(lf) == float(lu), (float(lf), float(lu))
for a, b, name in zip(gf, gu, ("dg", "du", "dw")):
    assert np.array_equal(np.asarray(a), np.asarray(b)), name
print("fused grouped linear value+grad parity OK")
EOF

# Tiny-M decode bench path must not rot either (cost-model selection —
# the CI gate exercises the CLI + decode pool, not kernel timing).
REPRO_TILEPLAN_CACHE="$(mktemp -d)/tileplan_cache.json" \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_grouped_gemm --decode --smoke \
        --backend pallas_interpret

# Producer bench path: the fused gemm_quant CLI (autotune pool for the
# gemm_quant op family + the fused-vs-unfused comparison columns).
REPRO_TILEPLAN_CACHE="$(mktemp -d)/tileplan_cache.json" \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_grouped_gemm --gemm-quant --smoke \
        --backend pallas_interpret
