#!/usr/bin/env python3
"""Compare two ``BENCH_*.json`` snapshots and flag measured-row time
regressions.

  python scripts/bench_diff.py OLD.json NEW.json [--threshold 0.10]

Rows are matched by ``name``.  Only rows that are *measured* in BOTH
snapshots are compared on time (``us_per_call``); derived-only rows (and
rows measured on different backends) are reported but never fail the
diff — a backend change or a cost-model drift is visible, not a
regression.  A measured common row whose time grew by more than
``--threshold`` (fractional, default 0.10 = +10%) is a regression; any
regression makes the exit status nonzero so CI can gate on it.

stdlib only — runs in the jax-free static CI step.
"""
from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> "tuple[dict, dict[str, dict]]":
    with open(path) as f:
        snap = json.load(f)
    rows = {}
    for row in snap.get("rows", []):
        rows[row["name"]] = row
    return snap, rows


def is_measured(row: dict) -> bool:
    # pre-protocol snapshots have no `measured` key: the presence of a
    # recorded timing is the fallback signal
    if "measured" in row:
        return bool(row["measured"]) and "us_per_call" in row
    return "us_per_call" in row


def diff(old_path: str, new_path: str, threshold: float,
         out=sys.stdout) -> int:
    old_snap, old_rows = load_rows(old_path)
    new_snap, new_rows = load_rows(new_path)

    common = [n for n in new_rows if n in old_rows]
    regressions, improved, compared, skipped = [], 0, 0, 0

    print(f"# old: {old_path} ({old_snap.get('date', '?')}, "
          f"device={old_snap.get('device', '?')}, "
          f"{len(old_rows)} rows)", file=out)
    print(f"# new: {new_path} ({new_snap.get('date', '?')}, "
          f"device={new_snap.get('device', '?')}, "
          f"{len(new_rows)} rows)", file=out)
    print(f"# common rows: {len(common)}; threshold: +{threshold:.0%}",
          file=out)

    for name in common:
        o, n = old_rows[name], new_rows[name]
        if not (is_measured(o) and is_measured(n)):
            skipped += 1
            continue
        if o.get("backend") and n.get("backend") \
                and o["backend"] != n["backend"]:
            print(f"SKIP {name}: backend changed "
                  f"{o['backend']} -> {n['backend']}", file=out)
            skipped += 1
            continue
        t_old, t_new = float(o["us_per_call"]), float(n["us_per_call"])
        if t_old <= 0.0:
            skipped += 1
            continue
        compared += 1
        ratio = t_new / t_old
        if ratio > 1.0 + threshold:
            regressions.append((name, t_old, t_new, ratio))
            print(f"REGRESSION {name}: {t_old:.1f}us -> {t_new:.1f}us "
                  f"({(ratio - 1) * 100:+.1f}%)", file=out)
        elif ratio < 1.0 - threshold:
            improved += 1
            print(f"improved {name}: {t_old:.1f}us -> {t_new:.1f}us "
                  f"({(ratio - 1) * 100:+.1f}%)", file=out)

    print(f"# compared {compared} measured rows: "
          f"{len(regressions)} regressed, {improved} improved, "
          f"{skipped} skipped (unmeasured/backend-change/zero)", file=out)
    return 1 if regressions else 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("old", help="baseline BENCH_*.json")
    ap.add_argument("new", help="candidate BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="fractional time-regression threshold "
                         "(default 0.10 = +10%%)")
    args = ap.parse_args()
    sys.exit(diff(args.old, args.new, args.threshold))


if __name__ == "__main__":
    main()
